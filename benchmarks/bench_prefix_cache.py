"""Prefix-cache-aware routing on a multi-turn session stream.

Serves the SAME open-loop conversation-session scenario (follow-up
turns extend prior context; tenants share system-prompt blocks) through
the gateway with a per-instance radix/LRU prefix cache, under
cache-blind and cache-aware routing policies:

  * ``rr``           -- round robin (blind),
  * ``mixing``       -- r_mixing workload-impact heuristic (blind),
  * ``sticky``       -- pure prefix affinity, load tiebreak,
  * ``mixing+cache`` -- r_mixing with the cache-hit-fraction term.

Emits per-policy windowed P95/P50 E2E, TTFT P95, and the realized
cache hit rate (hit tokens / looked-up tokens across instances), plus
one cache-off control.  Acceptance (asserted): ``mixing+cache`` beats
cache-blind ``mixing`` on P95 E2E, and routing cache-aware lifts the
hit rate over round robin.

``PREFIX_CACHE_SCALE=paper`` (the nightly workflow) lengthens the
stream and adds a cache-aware RL router (cache-hit-fraction state
feature + cache-weighted guidance, trained on session scenarios with
the batched trainer) against the sticky and r_mixing arms.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import time

from benchmarks.common import emit
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.policies import make_gateway_policy

PAPER_SCALE = os.environ.get("PREFIX_CACHE_SCALE", "") == "paper"
PROF = V100_LLAMA2_7B
M = 3
N = 1000 if PAPER_SCALE else 200
# the long paper-scale stream saturates 3x V100 at the smoke rate
# (makespan-bound: routing deltas compress); serve it loaded-but-stable
RATE = 20.0 if PAPER_SCALE else 30.0
STREAM_SEED = 7
CACHE_TOKENS = 4096
BLOCK = 16
TRAIN_EPISODES = 8
POLICIES = ("rr", "mixing", "sticky", "mixing+cache")


def _stream():
    """Fresh copy of the one session-workload evaluation stream."""
    return wl.make_tenant_scenario(
        seed=STREAM_SEED, n_requests=N, rate=RATE, pattern="poisson",
        profiles=(PROF,) * M,
        sessions=wl.SessionConfig(block=BLOCK))


def _rl_policy():
    """A cache-aware RL router trained on session scenarios: the
    cache-hit-fraction state feature + cache-weighted guidance."""
    from repro.core import rl_router as rl
    from repro.serving.policies import RLPolicy
    from repro.training.train_loop import train_router
    cfg = rl.RouterConfig(variant="guided", n_instances=M,
                          explore_episodes=max(TRAIN_EPISODES - 2, 2),
                          q_arch="decomposed", seed=0,
                          include_cache_features=True,
                          prefix_cache_tokens=CACHE_TOKENS,
                          prefix_block=BLOCK, cache_weight=0.5)

    def scenario(ep):
        return wl.make_tenant_scenario(
            seed=1000 + ep, n_requests=min(N, 400), rate=RATE,
            pattern="poisson", profiles=(PROF,) * M,
            sessions=wl.SessionConfig(block=BLOCK))

    t0 = time.time()
    out = train_router(cfg, scenario, TRAIN_EPISODES)
    emit("prefix_cache_rl_train", (time.time() - t0) * 1e6,
         f"episodes={TRAIN_EPISODES} cache_features=1")
    return RLPolicy(out["agent"], cfg)


def _serve(policy, cache_tokens: int):
    gw = Gateway(GatewayConfig(prefix_cache_tokens=cache_tokens,
                               prefix_block=BLOCK),
                 (PROF,) * M, policy)
    t0 = time.time()
    stats = gw.run(_stream())
    wall = time.time() - t0
    caches = [getattr(i, "prefix_cache", None)
              for i in gw.cluster.instances]
    hit = sum(c.hit_tokens for c in caches if c is not None)
    look = sum(c.lookup_tokens for c in caches if c is not None)
    return stats, wall, (hit / look if look else 0.0)


def main():
    arms = {name: make_gateway_policy(name) for name in POLICIES}
    if PAPER_SCALE:
        arms["rl"] = _rl_policy()
    p95, hits = {}, {}
    for name, policy in arms.items():
        stats, wall, hit_rate = _serve(policy, CACHE_TOKENS)
        snap = stats["snapshot"]
        e2e, ttft = snap["e2e"], snap["ttft"]
        p95[name], hits[name] = e2e["p95"], hit_rate
        key = name.replace("+", "_")
        emit(f"prefix_cache_{key}",
             wall / max(stats["n"], 1) * 1e6,
             f"p95_e2e={e2e['p95']:.2f} p50_e2e={e2e['p50']:.2f} "
             f"p95_ttft={ttft['p95']:.2f} hit_rate={hit_rate:.3f} "
             f"n={stats['n']} preempt={stats['preemptions']}")

    # control: same stream, cache model off (every prefill pays full)
    stats, wall, _ = _serve(make_gateway_policy("mixing"), 0)
    snap = stats["snapshot"]
    emit("prefix_cache_off_mixing",
         wall / max(stats["n"], 1) * 1e6,
         f"p95_e2e={snap['e2e']['p95']:.2f} "
         f"p50_e2e={snap['e2e']['p50']:.2f} n={stats['n']}")

    # acceptance: the cache-hit routing term pays for itself on the
    # tail, and affinity routing realizes more hits than round robin
    assert p95["mixing+cache"] < p95["mixing"], \
        (p95["mixing+cache"], p95["mixing"])
    assert hits["mixing+cache"] > hits["rr"], \
        (hits["mixing+cache"], hits["rr"])
    assert hits["sticky"] > hits["rr"], (hits["sticky"], hits["rr"])


if __name__ == "__main__":
    main()
