"""Perf-trend gate: compare a ``benchmarks/run.py --json`` result
against the committed ``benchmarks/baseline.json`` and fail on
regressions beyond a tolerance band.

  python -m benchmarks.run --json bench.json fig4 table1 gateway
  python -m benchmarks.trend bench.json

Two classes of check:

  * **derived metrics** (deterministic, machine-independent: accuracies,
    simulated latencies, SLO rates, preemption counts): any ``key=value``
    numeric pair in a row's derived column whose key has a known
    direction is gated at ``--tol`` relative change (plus an absolute
    floor so zero-baselines don't trip on noise);
  * **wall time** (machine-dependent: per-bench seconds): gated only at
    ``--time-factor`` x the baseline, generous enough for runner
    variance but a backstop against order-of-magnitude blowups.

Unknown metric keys and benches absent from the baseline are reported
but never fail -- the gate only defends what the baseline records.
Update the baseline deliberately:
``python -m benchmarks.run --json benchmarks/baseline.json <benches>``.
"""
from __future__ import annotations

import argparse
import json
import re
import sys
from typing import Dict, List, Optional, Tuple

# metric-name prefixes -> direction ("low" = lower is better)
LOWER_IS_BETTER = ("p50", "p95", "p99", "e2e", "ttft", "tbt", "us",
                   "seconds", "preempt", "shed", "loss", "wait",
                   "makespan", "spikes", "overhead")
HIGHER_IS_BETTER = ("acc", "bucket_acc", "slo", "speedup", "eps",
                    "throughput", "attain", "r2", "within",
                    "fairness", "goodput")

_NUM = re.compile(r"([A-Za-z_][\w.]*)=(-?\d+(?:\.\d+)?(?:e-?\d+)?)")


def direction(key: str) -> Optional[str]:
    k = key.lower()
    if any(k.startswith(p) for p in HIGHER_IS_BETTER):
        return "high"
    if any(k.startswith(p) for p in LOWER_IS_BETTER):
        return "low"
    return None


def entry_direction(dirs: Dict[str, str], key: str) -> Optional[str]:
    """Direction from a bench entry's own ``directions`` map (written
    by ``emit_direction`` via run.py): exact key first, then the
    LONGEST declared prefix.  Per-entry metadata beats the global
    prefix lists, so a bench introducing e.g. ``episodes_per_sec_*``
    keys declares their direction instead of hoping the append-only
    global lists happen to match."""
    if key in dirs:
        return dirs[key]
    best = None
    for prefix, d in dirs.items():
        if key.startswith(prefix) and \
                (best is None or len(prefix) > len(best[0])):
            best = (prefix, d)
    return best[1] if best else None


def row_direction(row_name: str) -> Optional[str]:
    """Direction for a BARE-value row (derived is a single number, no
    key=value pairs), inferred from the row name's ``_``-tokens -- e.g.
    ``table1_ours_hint_unequal_acc`` gates as an accuracy."""
    for tok in row_name.lower().split("_"):
        d = direction(tok)
        if d is not None:
            return d
    return None


def parse_metrics(derived: str) -> Dict[str, float]:
    out = {k: float(v) for k, v in _NUM.findall(derived or "")}
    if not out:
        try:
            out["_value"] = float(derived)
        except (TypeError, ValueError):
            pass
    return out


def _index(report: dict) -> Tuple[Dict[str, dict], Dict[str, dict]]:
    """-> ({bench: result}, {"bench/row": metrics})."""
    benches, rows = {}, {}
    for res in report.get("results", []):
        benches[res["bench"]] = res
        for row in res.get("rows", []):
            rows[f"{res['bench']}/{row['name']}"] = parse_metrics(
                row.get("derived", ""))
    return benches, rows


def compare(current: dict, baseline: dict, tol: float = 0.35,
            time_factor: float = 4.0, abs_floor: float = 1.0,
            frac_tol: float = 0.15) -> Tuple[List[str], List[str]]:
    """-> (regressions, notes).  Empty regressions = gate passes.

    Fraction-scale metrics (baseline in [0, 1]: accuracies, SLO/shed
    rates) are gated at the tighter ``frac_tol`` band -- a generic
    relative ``tol`` wide enough for latency jitter would let an
    accuracy collapse to half its value pass silently.  ``speedup``
    metrics are wall-clock RATIOS, not fractions: they may
    legitimately sit below 1.0 and carry runner noise, so they always
    take the generous ``tol`` band."""
    regressions: List[str] = []
    notes: List[str] = []
    cur_b, cur_r = _index(current)
    base_b, base_r = _index(baseline)
    for name, base in base_b.items():
        cur = cur_b.get(name)
        if cur is None:
            notes.append(f"bench {name}: in baseline but not run "
                         "(not gated)")
            continue
        if not cur.get("ok", False):
            regressions.append(f"bench {name}: FAILED in current run")
            continue
        bs, cs = base.get("seconds"), cur.get("seconds")
        if bs and cs and cs > bs * time_factor:
            regressions.append(
                f"bench {name}: wall time {cs:.1f}s > "
                f"{time_factor:g}x baseline {bs:.1f}s")
    for key, base_m in base_r.items():
        cur_m = cur_r.get(key)
        if cur_m is None:
            bench = key.split("/", 1)[0]
            if bench in cur_b:
                regressions.append(f"row {key}: missing from current run")
            continue
        bench_dirs = base_b.get(key.split("/", 1)[0], {}) \
            .get("directions") or {}
        for metric, base_v in base_m.items():
            name = (key.rsplit("/", 1)[-1] if metric == "_value"
                    else metric)
            d = entry_direction(bench_dirs, name) or \
                (row_direction(name) if metric == "_value"
                 else direction(name))
            if d is None or metric not in cur_m:
                continue
            cur_v = cur_m[metric]
            delta = cur_v - base_v
            is_ratio = (metric if metric != "_value"
                        else key.rsplit("/", 1)[-1]).lower() \
                .startswith("speedup")
            if 0.0 <= base_v <= 1.0 and not is_ratio:
                band = frac_tol * max(base_v, 0.05)
            else:
                band = max(tol * abs(base_v), abs_floor * tol)
            if (d == "low" and delta > band) or \
                    (d == "high" and -delta > band):
                regressions.append(
                    f"{key}: {metric} {base_v:g} -> {cur_v:g} "
                    f"(band +-{band:g}, {d}er is better)")
        for metric in cur_m:
            # new metric on a known row: report, never fail -- the
            # gate only defends what the baseline records
            if metric not in base_m:
                notes.append(f"{key}: new metric {metric} "
                             "(not in baseline)")
    for key in cur_r:
        if key not in base_r:
            notes.append(f"row {key}: new (not in baseline)")
    return regressions, notes


def main() -> None:
    ap = argparse.ArgumentParser(
        description="gate a bench run against the committed baseline")
    ap.add_argument("current", help="run.py --json output to check")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--tol", type=float, default=0.35,
                    help="relative tolerance on derived metrics")
    ap.add_argument("--time-factor", type=float, default=4.0,
                    help="allowed wall-time blowup per bench")
    ap.add_argument("--abs-floor", type=float, default=1.0,
                    help="absolute scale floor for near-zero baselines")
    ap.add_argument("--frac-tol", type=float, default=0.15,
                    help="band for fraction-scale metrics (rates, accs)")
    args = ap.parse_args()
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    regressions, notes = compare(current, baseline, tol=args.tol,
                                 time_factor=args.time_factor,
                                 abs_floor=args.abs_floor,
                                 frac_tol=args.frac_tol)
    for n in notes:
        print(f"note: {n}")
    if regressions:
        print(f"\nPERF-TREND GATE FAILED ({len(regressions)}):")
        for r in regressions:
            print(f"  REGRESSION {r}")
        print("\nIf intentional, refresh the baseline: "
              "python -m benchmarks.run --json benchmarks/baseline.json "
              "<benches>")
        sys.exit(1)
    print("perf-trend gate: OK "
          f"({len(_index(baseline)[1])} baseline rows checked)")


if __name__ == "__main__":
    main()
