"""Python-vs-vectorized simulator stepper throughput at m in {4,16,64}.

Both backends run the SAME span-aware driver (identical decisions --
asserted): route whenever the central queue is non-empty, otherwise
jump ahead to the next arrival (or in bounded drain windows).  The
Python backend advances tick by tick inside a span; the vec backend
advances the whole span in fused rounds (``VecSimPool.advance_span``),
which is where its O(rounds) structure shows: lanes at staggered
iteration phases -- an engine iteration is several router ticks long --
coincide in shared vector rounds instead of being touched one tick at
a time.

Emitted ``speedup`` values are same-process ratios (machine-normalized,
so the perf-trend gate can band them); wall times are reported as
ungated ``t_py``/``t_vec`` keys.  Expect sub-1x at m=4 (numpy dispatch
overhead dominates a 4-lane cluster) growing past 1x by m=64 -- the
vectorization pays off with width, which is exactly the regime the
paper's cluster-scale evaluations need.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster
from repro.core.workload import generate, to_requests
from repro.serving.request import summarize

PROF = V100_LLAMA2_7B
WIDTHS = (4, 16, 64)
REQS_PER_INSTANCE = 100
RATE_PER_INSTANCE = 5.0
TRIALS = 3
SPAN_CAP = 256


def _reqs(n, seed, rate):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


def drive(cluster, requests, policy, max_time=36_000.0,
          routes_per_tick=64):
    """Span-aware heuristic driver: identical to simulator.run_heuristic
    decision for decision, but advances multi-tick spans when no
    routing decision is possible (empty central queue)."""
    pending = sorted(requests, key=lambda r: r.arrival)
    i, n = 0, len(pending)
    pool = getattr(cluster, "pool", None)
    while len(cluster.completed) < n and cluster.t < max_time:
        while i < n and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            i += 1
        for _ in range(routes_per_tick):
            if not cluster.central:
                break
            act = policy.act(cluster)
            if act is None or act >= cluster.m:
                break
            cluster.route(act)
        if cluster.central:
            k = 1                        # a decision is pending next tick
        elif i >= n:
            k = SPAN_CAP                 # drain to completion in windows
        else:
            k = max(1, min(SPAN_CAP, int(np.ceil(
                (pending[i].arrival - cluster.t) / cluster.dt))))
        if pool is not None and k > 1:
            t = cluster.t
            bounds = []
            for _ in range(k):
                t = t + cluster.dt
                bounds.append(t)
            out = pool.advance_span([(cluster.ep, bounds)])
            cluster.collect_span(out[cluster.ep][0], k)
        else:
            for _ in range(k):
                cluster.advance()
    return summarize(requests)


def main():
    for m in WIDTHS:
        n = REQS_PER_INSTANCE * m
        rate = RATE_PER_INSTANCE * m
        best = {"py": 9e9, "vec": 9e9}
        stats = {}
        reqs = {}
        for _ in range(TRIALS):
            for backend in ("py", "vec"):
                rs = _reqs(n, 7, rate)
                cluster = Cluster(PROF, m, backend=backend)
                t0 = time.perf_counter()
                stats[backend] = drive(cluster, rs,
                                       make_policy("jsq", PROF))
                best[backend] = min(best[backend],
                                    time.perf_counter() - t0)
                reqs[backend] = rs
        # decision-for-decision parity between the two backends
        for a, b in zip(reqs["py"], reqs["vec"]):
            assert a.finished == b.finished, (m, a.rid)
            assert a.instance == b.instance
            assert a.preemptions == b.preemptions
        assert stats["py"]["n"] == stats["vec"]["n"] == n
        speedup = best["py"] / best["vec"]
        emit(f"vecsim_stepper_m{m}", best["vec"] / n * 1e6,
             f"speedup={speedup:.2f} t_py={best['py']:.2f} "
             f"t_vec={best['vec']:.2f} n={n}")


if __name__ == "__main__":
    main()
