"""Gateway policy comparison on one bursty multi-tenant stream.

Serves the SAME open-loop bursty multi-tenant scenario through the
serving gateway under all four routing policies -- round-robin, JSQ
(least outstanding work), the r_mixing workload-impact heuristic, and
the trained RL router -- with the LEARNED length predictor (micro-batch
wrapper, LRU cache) in the routing hot path; no oracle decode lengths
anywhere.  The RL agent itself is trained with the predictor's d-hat in
the loop (``train_router(length_predictor=...)``).

Emits per-policy windowed P95/P50 E2E, TTFT P95, SLO attainment,
predictor-service counters, and decision-attribution metrics (regret
vs the r_mixing yardstick, agree-rate, predictor drift).  A final
traced re-run of the mixing policy measures tracing overhead:
simulated P95 E2E must be bit-identical-or-better within 5%
(asserted -- tracing must not perturb decisions) and the run honors
``REPRO_TRACE`` / ``REPRO_METRICS_OUT`` by writing the Chrome
trace-event JSON and the metrics registry (CI's trace-smoke
artifacts).  Acceptance (asserted): the workload-aware policies
(mixing, rl) beat round-robin on P95 E2E.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import time

from benchmarks.common import emit
from repro.core import rl_router as rl
from repro.core import workload as wl
from repro.core.predictor import quick_bucket_predictor
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving.gateway import (Gateway, GatewayConfig,
                                   MicroBatchPredictor)
from repro.serving import obs
from repro.serving.metrics import SLO
from repro.serving.obs import MetricsRegistry
from repro.serving.policies import RLPolicy, make_gateway_policy
from repro.serving.trace import TraceRecorder
from repro.training.train_loop import train_router

PROF = V100_LLAMA2_7B
M = 4
N = 300
# loaded-but-serviceable (the paper's operating point): beyond ~6 rps
# the 4x V100 cluster saturates into a makespan-bound regime where no
# routing decision matters; at ~2.5 rps bursty, placement quality
# dominates the tail
RATE = 2.5
PROBE_RATE = 10.0          # deliberately saturating (backpressure probe)
STREAM_SEED = 42
TRAIN_EPISODES = 6
POLICIES = ("rr", "jsq", "mixing", "rl")


def _stream(rate=RATE):
    """Fresh copy of the one bursty multi-tenant evaluation stream."""
    return wl.make_tenant_scenario(seed=STREAM_SEED, n_requests=N,
                                   rate=rate, pattern="bursty",
                                   profiles=(PROF,) * M)


def _train_scenario(ep: int):
    samples = wl.generate(120, seed=1000 + ep)
    reqs = wl.to_requests(samples, rate=RATE, seed=2000 + ep)
    return wl.Scenario.homogeneous(PROF, M, reqs, name=f"train-{ep}",
                                   samples=samples)


def main():
    t0 = time.time()
    predictor = quick_bucket_predictor(PROF, n_train=2000, epochs=2,
                                       seed=0)
    acc = predictor.accuracy(wl.generate(500, seed=77))
    emit("gateway_predictor", (time.time() - t0) * 1e6,
         f"bucket_acc={acc:.3f} n_train=2000")

    t0 = time.time()
    cfg = rl.RouterConfig(variant="guided", n_instances=M,
                          explore_episodes=max(TRAIN_EPISODES - 2, 2),
                          q_arch="decomposed", seed=0)
    out = train_router(cfg, _train_scenario, TRAIN_EPISODES,
                       length_predictor=predictor)
    emit("gateway_rl_train", (time.time() - t0) * 1e6,
         f"episodes={TRAIN_EPISODES} predictor_in_loop=1")

    slo = SLO(ttft_s=10.0, tbt_s=0.5, e2e_s=60.0)
    p95 = {}
    walls = {}
    registry = MetricsRegistry()
    for name in POLICIES:
        policy = (RLPolicy(out["agent"], cfg) if name == "rl"
                  else make_gateway_policy(name, cfg))
        length = MicroBatchPredictor(predictor)
        gw = Gateway(GatewayConfig(slo=slo, attribution=True),
                     (PROF,) * M, policy, length=length)
        t0 = time.time()
        stats = gw.run(_stream())
        wall = time.time() - t0
        walls[name] = wall
        snap = stats["snapshot"]
        e2e, ttft = snap["e2e"], snap["ttft"]
        p95[name] = e2e["p95"]
        at = snap["attribution"]
        registry.ingest_snapshot(snap, prefix=f"gateway_{name}")
        emit(f"gateway_{name}", wall / max(stats["n"], 1) * 1e6,
             f"p95_e2e={e2e['p95']:.2f} p50_e2e={e2e['p50']:.2f} "
             f"p95_ttft={ttft['p95']:.2f} slo={snap['slo_rate']:.3f} "
             f"n={stats['n']} preempt={stats['preemptions']} "
             f"pred_forwards={length.forwards} "
             f"pred_hit={length.hits}")
        emit(f"gateway_{name}_attrib", 0.0,
             f"agree={at['agree_rate']:.3f} "
             f"regret_p95={at['regret']['p95']:.4f} "
             f"drift_p50={at['drift']['abs_err']['p50']:.1f} "
             f"bucket_acc={at['drift']['bucket_accuracy']:.3f} "
             f"joined={at['drift']['joined']}")
    registry.ingest_rl(out["agent"].telemetry())

    # backpressure probe: bounded queue on a deliberately saturating
    # stream, shed mode
    gw = Gateway(GatewayConfig(queue_cap=16, on_full="shed", slo=slo),
                 (PROF,) * M, make_gateway_policy("mixing", cfg),
                 length=MicroBatchPredictor(predictor))
    stats = gw.run(_stream(rate=PROBE_RATE))
    emit("gateway_backpressure", 0.0,
         f"queue_cap=16 probe_rate={PROBE_RATE:g} shed={stats['shed']} "
         f"admitted={stats['admitted']} "
         f"shed_rate={stats['snapshot']['shed_rate']:.3f}")

    # tracing-overhead probe: the SAME mixing run, fully traced
    # (sample=1.0, explain() on every decision, counter sampling).
    # Tracing must be an observer: simulated latency may only move by
    # the 5% band the CI trend gate also enforces, and on the virtual
    # clock the traced run should be bit-identical (events don't
    # advance time).  Wall-clock ratio is informational (runner noise).
    recorder = TraceRecorder()
    gw = Gateway(GatewayConfig(slo=slo, attribution=True), (PROF,) * M,
                 make_gateway_policy("mixing", cfg),
                 length=MicroBatchPredictor(predictor), trace=recorder)
    t0 = time.time()
    stats = gw.run(_stream())
    wall_traced = time.time() - t0
    traced_p95 = stats["snapshot"]["e2e"]["p95"]
    overhead = traced_p95 / p95["mixing"]
    wall_ratio = wall_traced / max(walls["mixing"], 1e-9)
    emit("gateway_trace_overhead", 0.0,
         f"overhead_p95={overhead:.4f} wall_ratio={wall_ratio:.2f} "
         f"events={len(recorder)} dropped={recorder.dropped}")
    assert overhead <= 1.05, (
        f"tracing perturbed the simulated tail: P95 E2E "
        f"{p95['mixing']:.3f} -> {traced_p95:.3f} ({overhead:.3f}x)")

    trace_path = os.environ.get("REPRO_TRACE")
    if trace_path:
        doc = obs.write_trace(recorder, trace_path,
                              title="bench_gateway mixing")
        emit("gateway_trace_export", 0.0,
             f"events={len(doc['traceEvents'])} path_set=1")
    metrics_path = os.environ.get("REPRO_METRICS_OUT")
    if metrics_path:
        registry.ingest_snapshot(stats["snapshot"],
                                 prefix="gateway_mixing_traced")
        registry.save(metrics_path)

    # acceptance: workload-aware routing beats round robin on P95 E2E
    # with the learned predictor (not the oracle) in the loop
    assert p95["mixing"] < p95["rr"], (p95["mixing"], p95["rr"])
    assert p95["rl"] < p95["rr"], (p95["rl"], p95["rr"])


if __name__ == "__main__":
    main()
