"""Fig. 4 reproduction: batch execution time vs prompt/decode tokens.

Measures SimInstance iteration times over sweeps and fits the two
gradients; asserts the linear structure the paper profiles (prefill fast
and linear, decode slow growth)."""
from __future__ import annotations


from benchmarks.common import emit, timed
from repro.core.profiles import V100_LLAMA2_7B, fit
from repro.core.simulator import SimInstance
from repro.serving.request import Request
from repro.serving.scheduler import get_scheduler

PROF = V100_LLAMA2_7B


def main():
    with timed() as t:
        prefill_pts = []
        for p in range(50, 1001, 50):
            inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
            inst.submit(Request(prompt_tokens=p, decode_tokens=2))
            inst.run_until(1e-9)
            prefill_pts.append((p, inst.clock))
        decode_pts = []
        for resident in range(200, 3800, 200):
            inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
            # co-resident context, then measure a decode-only iteration
            r = Request(prompt_tokens=resident, decode_tokens=50)
            inst.submit(r)
            inst.run_until(1e-9)
            t0 = inst.clock
            inst.run_until(t0 + 1e-9)
            decode_pts.append((resident, inst.clock - t0))
        fitted = fit(prefill_pts, decode_pts)
    emit("fig4_grad1_s_per_prompt_tok", t["us"] / len(prefill_pts),
         f"fit={fitted.grad1:.2e}_true={PROF.grad1:.2e}")
    emit("fig4_grad2_s_per_context_tok", t["us"] / len(decode_pts),
         f"fit={fitted.grad2:.2e}_true={PROF.grad2:.2e}")
    r1 = abs(fitted.grad1 - PROF.grad1) / PROF.grad1
    assert r1 < 0.05, r1


if __name__ == "__main__":
    main()
