"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run fig1b ...  # subset
"""
from __future__ import annotations

import sys
import time
import traceback

MODULES = [
    ("fig4", "benchmarks.bench_fig4_profiles"),
    ("fig2", "benchmarks.bench_fig2_partition"),
    ("table2", "benchmarks.bench_table2_grid"),
    ("table1", "benchmarks.bench_table1_predictor"),
    ("fig1b", "benchmarks.bench_fig1b_rl"),
    ("fig5", "benchmarks.bench_fig5_metrics"),
    ("table3", "benchmarks.bench_table3_chunking"),
    ("scale_trace", "benchmarks.bench_scale_trace"),
    ("roofline", "benchmarks.bench_roofline"),
]


def main() -> None:
    only = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failures = []
    for key, mod_name in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["main"])
            mod.main()
            print(f"# {key} ok in {time.time()-t0:.1f}s", flush=True)
        except Exception as e:
            traceback.print_exc()
            failures.append((key, repr(e)))
    if failures:
        print("# FAILURES:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
