"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run                 # all
  PYTHONPATH=src python -m benchmarks.run fig1b ...       # subset
  PYTHONPATH=src python -m benchmarks.run --json out.json # machine-readable

Each bench module runs in a FRESH interpreter so (a) one bench's crash
cannot poison the rest, (b) per-bench env (e.g. bench_batched_rl's
XLA_FLAGS) applies cleanly, and (c) wall time is attributed honestly.
Any failing module makes the harness exit non-zero.  ``--json PATH``
additionally writes {results: [{bench, ok, seconds, rows: [...]}],
failures: [{bench, reason, stderr_tail}]} for perf-trajectory tracking
across commits.

``--trace PATH`` / ``--metrics-out PATH`` are forwarded to the child
benches as ``REPRO_TRACE`` / ``REPRO_METRICS_OUT``; benches that serve
through the gateway (bench_gateway) honor them by writing a Chrome
trace-event JSON and a metrics-registry JSON (see ``repro.serving.obs``
-- this is CI's trace-smoke artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

MODULES = [
    ("fig4", "benchmarks.bench_fig4_profiles"),
    ("fig2", "benchmarks.bench_fig2_partition"),
    ("table2", "benchmarks.bench_table2_grid"),
    ("table1", "benchmarks.bench_table1_predictor"),
    ("fig1b", "benchmarks.bench_fig1b_rl"),
    ("gateway", "benchmarks.bench_gateway"),
    ("vecsim", "benchmarks.bench_vecsim"),
    ("jaxsim", "benchmarks.bench_jaxsim"),
    ("fidelity", "benchmarks.bench_fidelity"),
    ("batched_rl", "benchmarks.bench_batched_rl"),
    ("fig5", "benchmarks.bench_fig5_metrics"),
    ("table3", "benchmarks.bench_table3_chunking"),
    ("scale_trace", "benchmarks.bench_scale_trace"),
    ("prefix_cache", "benchmarks.bench_prefix_cache"),
    ("roofline", "benchmarks.bench_roofline"),
    ("chaos", "benchmarks.bench_chaos"),
    ("online_drift", "benchmarks.bench_online_drift"),
]


def _parse_rows(stdout: str):
    rows = []
    for line in stdout.splitlines():
        parts = line.split(",", 2)
        if len(parts) == 3 and not line.startswith(("#", "name,")):
            rows.append({"name": parts[0], "us_per_call": parts[1],
                         "derived": parts[2]})
    return rows


def _parse_directions(stdout: str):
    """Collect ``#direction key=low|high ...`` declarations (see
    benchmarks.common.emit_direction) into one per-bench map."""
    dirs = {}
    for line in stdout.splitlines():
        if not line.startswith("#direction "):
            continue
        for pair in line[len("#direction "):].split():
            key, _, d = pair.partition("=")
            if d in ("low", "high"):
                dirs[key] = d
    return dirs


def _pop_opt(args, flag):
    if flag not in args:
        return None
    i = args.index(flag)
    try:
        val = args[i + 1]
    except IndexError:
        print("usage: run.py [--json PATH] [--trace PATH] "
              "[--metrics-out PATH] [bench ...]", file=sys.stderr)
        sys.exit(2)
    del args[i:i + 2]
    return val


def main() -> None:
    args = sys.argv[1:]
    json_path = _pop_opt(args, "--json")
    trace_path = _pop_opt(args, "--trace")
    metrics_path = _pop_opt(args, "--metrics-out")
    only = set(args)
    unknown = only - {k for k, _ in MODULES}
    if unknown:
        print(f"unknown benches: {sorted(unknown)} "
              f"(known: {[k for k, _ in MODULES]})", file=sys.stderr)
        sys.exit(2)
    print("name,us_per_call,derived")
    results = []
    failures = []
    env = dict(os.environ)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    src = os.path.join(repo, "src")
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    if trace_path:
        env["REPRO_TRACE"] = os.path.abspath(trace_path)
    if metrics_path:
        env["REPRO_METRICS_OUT"] = os.path.abspath(metrics_path)
    for key, mod_name in MODULES:
        if only and key not in only:
            continue
        t0 = time.time()
        proc = subprocess.run(
            [sys.executable, "-m", mod_name],
            env=env, cwd=repo, capture_output=True, text=True)
        dt = time.time() - t0
        sys.stdout.write(proc.stdout)
        ok = proc.returncode == 0
        if ok:
            print(f"# {key} ok in {dt:.1f}s", flush=True)
        else:
            sys.stderr.write(proc.stderr)
            tail = "\n".join(proc.stderr.splitlines()[-15:])
            failures.append({"bench": key,
                             "reason": f"exit {proc.returncode}",
                             "stderr_tail": tail})
            print(f"# {key} FAILED in {dt:.1f}s", flush=True)
        result = {"bench": key, "ok": ok, "seconds": round(dt, 2),
                  "rows": _parse_rows(proc.stdout)}
        dirs = _parse_directions(proc.stdout)
        if dirs:
            result["directions"] = dirs
        results.append(result)
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"results": results, "failures": failures}, f,
                      indent=2)
    if failures:
        print("# FAILURES:", [(f["bench"], f["reason"])
                              for f in failures])
        sys.exit(1)


if __name__ == "__main__":
    main()
