"""Table 3 / §A.12 reproduction: router x Sarathi-style chunked prefill on
the A100/Llama-3.1-8B profile with the long-prompt production-trace
workload (mean prompt ~5.5k tokens -- where a 1024-token chunk actually
binds).  Chunking's purpose is TBT smoothing, not E2E (paper: RR gains
only 0.45% E2E from chunking); the router must keep its standing under it.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import A100_LLAMA31_8B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import generate_trace
from repro.serving.request import Request

PROF = A100_LLAMA31_8B
N, RATE, M = 400, 10.0, 4


def _reqs(seed):
    samples = generate_trace(N, seed=seed)
    rng = np.random.default_rng(seed + 9)
    arr = np.cumsum(rng.exponential(1 / RATE, len(samples)))
    return [Request(prompt_tokens=s.prompt_tokens,
                    decode_tokens=s.decode_tokens, arrival=float(a),
                    task=s.task) for s, a in zip(samples, arr)]


def _tbt_p99(reqs):
    """p99 of raw inter-token gaps pooled over all requests (per-request
    means would average the prefill-induced stalls away)."""
    gaps = []
    for r in reqs:
        gaps.extend(b - a for a, b in zip(r.token_times,
                                          r.token_times[1:]))
    return float(np.percentile(gaps, 99)) if gaps else 0.0


def main():
    rows, tbt = {}, {}
    with timed() as t:
        for chunk in (0, 1024):
            reqs = _reqs(991)
            rows[("rr", chunk)] = run_heuristic(
                Cluster(PROF, M, chunked_prefill=chunk), reqs,
                make_policy("round_robin", PROF))["e2e_mean"]
            tbt[("rr", chunk)] = _tbt_p99(reqs)
            cfg = rl.RouterConfig(variant="guided", n_instances=M,
                                  chunked_prefill=chunk,
                                  explore_episodes=5, seed=0,
                                  q_arch="decomposed")
            out = rl.train(cfg, PROF, lambda ep: _reqs(100 + ep), 7,
                           valid_fn=lambda: _reqs(555))
            reqs = _reqs(991)
            rows[("guided", chunk)] = rl.evaluate(
                cfg, PROF, out["agent"], reqs)["e2e_mean"]
            tbt[("guided", chunk)] = _tbt_p99(reqs)
    per = t["us"] / 4
    for (pol, chunk), e2e in rows.items():
        base = rows[("rr", chunk)]
        emit(f"table3_{pol}_chunk{chunk}_e2e_s", per,
             f"{e2e:.2f}({(base-e2e)/base*100:+.1f}%vsRR)")
        emit(f"table3_{pol}_chunk{chunk}_tbt_p99_ms", per,
             f"{tbt[(pol, chunk)]*1e3:.0f}")
    # chunked prefill's raison d'etre: smoother decode (lower TBT tail)
    assert tbt[("rr", 1024)] < tbt[("rr", 0)]
    # the guided router keeps its standing when chunking is enabled
    assert rows[("guided", 1024)] <= rows[("rr", 1024)] * 1.15


if __name__ == "__main__":
    main()
