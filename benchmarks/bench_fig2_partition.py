"""Fig. 2 reproduction: optimal vs random assignment of 8 requests (1/s,
lengths 10..100) over 2 instances by exhaustive set partitioning.

Paper: best 27.03 s, worst 32.34 s, random ~29.81 s (~10% optimality gap).
"""
from __future__ import annotations

import itertools

import numpy as np

from benchmarks.common import emit, timed
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster
from repro.serving.request import Request

PROF = V100_LLAMA2_7B


def episode(assignment):
    rng = np.random.default_rng(7)
    lengths = rng.integers(100, 1001, size=(8, 2))
    reqs = [Request(prompt_tokens=int(p), decode_tokens=int(d),
                    arrival=float(i))
            for i, (p, d) in enumerate(lengths)]
    cluster = Cluster(PROF, 2, dt=0.01)
    pending = list(reqs)
    i = 0
    while len(cluster.completed) < 8 and cluster.t < 600:
        while i < 8 and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            cluster.route(assignment[i])
            i += 1
        cluster.advance()
    return max(r.finished for r in reqs) - min(r.arrival for r in reqs)


def main():
    with timed() as t:
        results = {a: episode(a)
                   for a in itertools.product((0, 1), repeat=8)}
        vals = np.array(list(results.values()))
    best, worst, mean = vals.min(), vals.max(), vals.mean()
    emit("fig2_partition_best_s", t["us"] / len(vals), f"{best:.2f}")
    emit("fig2_partition_worst_s", t["us"] / len(vals), f"{worst:.2f}")
    emit("fig2_partition_random_s", t["us"] / len(vals), f"{mean:.2f}")
    emit("fig2_optimality_gap_pct", t["us"] / len(vals),
         f"{(mean - best) / mean * 100:.1f}")
    assert worst > best


if __name__ == "__main__":
    main()
