"""Chaos drill: routing policies x seeded fault scenarios, with the
gateway failover layer on vs off under the IDENTICAL schedule.

Serves one bursty multi-tenant stream through the gateway while a
deterministic ``FaultSchedule`` crashes, restarts, and slows instances
(and, in one scenario, bursts a tenant's arrival rate).  Every run is
on the virtual clock, so all emitted latencies are machine-independent
and trend-gated.

Acceptance (asserted):

  * **conservation** -- every admitted request reaches exactly one
    terminal phase (DONE / SHED / CANCELLED); completed rids are
    unique; nothing is lost or served twice, with or without failover;
  * **failover pays** -- on the straggler schedule, where hedged
    re-dispatch is the causally operative mechanism, the failover
    layer gives strictly better P95 E2E than plain requeue for the
    workload-aware mixing policy.  (Crash-scenario P95 deltas are
    placement-cascade noise in both directions at this operating
    point -- a crash reshuffles every later placement, and the P95 of
    ~240 completions rides on a dozen tail samples -- so those rows
    are emitted and trend-gated against the committed baseline rather
    than cross-mode asserted.);
  * **bit-exact parity** -- the py and vec backends agree bit-for-bit
    on every request outcome under crash + restart + straggler faults.

Honors ``REPRO_TRACE`` / ``REPRO_METRICS_OUT`` (CI's chaos-smoke
artifacts): a traced re-run exports the Chrome trace (fail / recover /
retry / hedge instants included) and the metrics registry.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import time

from benchmarks.common import emit
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving import obs
from repro.serving.chaos import (Crash, FaultSchedule, Straggler,
                                 TenantBurst, inject_bursts)
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.obs import MetricsRegistry
from repro.serving.policies import make_gateway_policy
from repro.serving.request import Phase
from repro.serving.trace import TraceRecorder

PROF = V100_LLAMA2_7B
M = 4
N = 240
RATE = 2.5                 # loaded-but-serviceable (see bench_gateway)
STREAM_SEED = 42
POLICIES = ("rr", "jsq", "mixing")
TERMINAL = (Phase.DONE, Phase.SHED, Phase.CANCELLED)

SCENARIOS = {
    # one instance dies mid-stream and comes back; a second follows
    "crash_restart": FaultSchedule(
        crashes=(Crash(10.0, 0, restart_after=12.0),
                 Crash(30.0, 2, restart_after=10.0))),
    # a long straggler window: 3.5x slowdown on one instance
    "straggler": FaultSchedule(
        stragglers=(Straggler(8.0, 45.0, 1, factor=3.5),)),
    # a crash correlated with a tenant arrival burst
    "crash_burst": FaultSchedule(
        crashes=(Crash(15.0, 0, restart_after=15.0),),
        bursts=(TenantBurst(10.0, 30.0, "chat", rate=2.0),)),
}


def _stream(schedule: FaultSchedule):
    reqs = wl.make_tenant_scenario(seed=STREAM_SEED, n_requests=N,
                                   rate=RATE, pattern="bursty",
                                   profiles=(PROF,) * M).requests
    return inject_bursts(reqs, schedule, seed=STREAM_SEED)


def _run(schedule, policy_name, failover, backend="py", trace=None):
    reqs = _stream(schedule)
    cfg = GatewayConfig(backend=backend, chaos=schedule,
                        failover=failover, max_retries=3,
                        hedge_after_s=6.0 if failover else None,
                        max_time=3600.0)
    gw = Gateway(cfg, (PROF,) * M, make_gateway_policy(policy_name),
                 trace=trace)
    stats = gw.run(reqs)
    _assert_conserved(reqs, stats)
    done = [r for r in reqs if r.phase is Phase.DONE]
    e2e = sorted(r.e2e for r in done)
    makespan = (max(r.finished for r in done)
                - min(r.arrival for r in done))
    return {
        "reqs": reqs,
        "stats": stats,
        "p95": e2e[int(0.95 * (len(e2e) - 1))],
        "p99": e2e[int(0.99 * (len(e2e) - 1))],
        "goodput": len(done) / makespan,
    }


def _assert_conserved(reqs, stats):
    """The hard invariant: no request lost, none duplicated."""
    assert all(r.phase in TERMINAL for r in reqs), \
        [r.phase for r in reqs if r.phase not in TERMINAL][:5]
    done = [r for r in reqs if r.phase is Phase.DONE]
    assert len({r.rid for r in done}) == len(done), "duplicate serve"
    assert len(done) + stats["shed"] + stats["cancelled"] == len(reqs)
    assert all(r.finished is not None for r in done)
    assert all(r.finished is None for r in reqs
               if r.phase is not Phase.DONE)


def main():
    ref_p95 = None          # crash_restart/mixing, for the traced run
    for scn_name, schedule in SCENARIOS.items():
        p95 = {}
        for pol in POLICIES:
            t0 = time.time()
            over = _run(schedule, pol, failover=True)
            plain = _run(schedule, pol, failover=False)
            wall = (time.time() - t0) * 1e6
            p95[pol] = (over["p95"], plain["p95"])
            if scn_name == "crash_restart" and pol == "mixing":
                ref_p95 = over["p95"]
            st = over["stats"]
            emit(f"chaos_{scn_name}_{pol}", wall,
                 f"p95_e2e={over['p95']:.3f} "
                 f"p99_e2e={over['p99']:.3f} "
                 f"p95_e2e_plain={plain['p95']:.3f} "
                 f"goodput={over['goodput']:.3f} "
                 f"shed={st['shed']} orphaned={st['orphaned']} "
                 f"retried={st['retried']} hedged={st['hedged']} "
                 f"breaker_trips={st['breaker_trips']}")
        # failover must strictly beat plain requeue where its
        # mechanism is causally exercised (see module docstring)
        if scn_name == "straggler":
            fo, pl = p95["mixing"]
            assert fo < pl, (scn_name, fo, pl)

    # py-vs-vec bit-exactness under crash + restart + straggler
    sched = FaultSchedule(
        crashes=(Crash(10.0, 0, restart_after=12.0),),
        stragglers=(Straggler(8.0, 40.0, 1, factor=3.0),))
    t0 = time.time()
    a = _run(sched, "mixing", failover=True, backend="py")
    b = _run(sched, "mixing", failover=True, backend="vec")
    mismatch = sum(
        1 for ra, rb in zip(a["reqs"], b["reqs"])
        if (ra.finished, ra.first_token, ra.instance, ra.phase,
            ra.retries, ra.hedges)
        != (rb.finished, rb.first_token, rb.instance, rb.phase,
            rb.retries, rb.hedges))
    emit("chaos_parity", (time.time() - t0) * 1e6,
         f"mismatches={mismatch} n={len(a['reqs'])} "
         f"orphaned_py={a['stats']['orphaned']} "
         f"orphaned_vec={b['stats']['orphaned']}")
    assert mismatch == 0, f"{mismatch} py-vs-vec mismatches under chaos"
    assert a["stats"]["orphaned"] == b["stats"]["orphaned"]
    assert a["stats"]["hedged"] == b["stats"]["hedged"]

    # traced chaos run: CI's chaos-smoke artifact
    from repro.serving import trace as tr_lib
    recorder = TraceRecorder()
    traced = _run(SCENARIOS["crash_restart"], "mixing", failover=True,
                  trace=recorder)
    kinds = {e[1] for e in recorder.events()}
    assert tr_lib.EV_FAIL in kinds and tr_lib.EV_RECOVER in kinds
    emit("chaos_trace", 0.0,
         f"events={len(recorder)} "
         f"p95_e2e_traced={traced['p95']:.3f}")
    assert abs(traced["p95"] - ref_p95) < 1e-9, \
        "tracing perturbed chaos decisions"
    trace_path = os.environ.get("REPRO_TRACE")
    if trace_path:
        doc = obs.write_trace(recorder, trace_path,
                              title="bench_chaos mixing crash_restart")
        assert obs.validate_chrome_trace(doc) == []
        emit("chaos_trace_export", 0.0,
             f"events={len(doc['traceEvents'])} path_set=1")
    metrics_path = os.environ.get("REPRO_METRICS_OUT")
    if metrics_path:
        registry = MetricsRegistry()
        registry.ingest_snapshot(traced["stats"]["snapshot"],
                                 prefix="chaos_crash_restart_mixing")
        registry.save(metrics_path)


if __name__ == "__main__":
    main()
