"""§A.11 (8-instance scalability) + §A.12 (production-trace workload with
the content-free length predictor)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import predictor as pred
from repro.core import rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import A100_LLAMA31_8B, V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import (TRACE_APPS, generate, generate_trace,
                                 to_requests)
from repro.serving.request import Request


def main():
    # --- A.11: 8 instances, doubled load -------------------------------
    prof = V100_LLAMA2_7B
    with timed() as t:
        def reqs8(seed):
            return to_requests(generate(600, seed=seed), rate=40.0,
                               seed=seed + 1)
        rr = run_heuristic(Cluster(prof, 8), reqs8(991),
                           make_policy("round_robin", prof))
        cfg = rl.RouterConfig(variant="guided", n_instances=8,
                              explore_episodes=5, seed=0,
                              q_arch="decomposed")
        out = rl.train(cfg, prof, lambda ep: reqs8(100 + ep), 7,
                       valid_fn=lambda: reqs8(555))
        st = rl.evaluate(cfg, prof, out["agent"], reqs8(991))
    gain = (rr["e2e_mean"] - st["e2e_mean"]) / rr["e2e_mean"] * 100
    emit("a11_8inst_rr_e2e_s", t["us"] / 2, f"{rr['e2e_mean']:.2f}")
    emit("a11_8inst_guided_e2e_s", t["us"] / 2,
         f"{st['e2e_mean']:.2f}({gain:+.1f}%)")

    # --- A.12: production trace + content-free predictor ----------------
    prof = A100_LLAMA31_8B
    with timed() as t:
        train = generate_trace(3000, seed=1)
        test = generate_trace(800, seed=2)
        tp = pred.TracePredictor(prof, n_apps=len(TRACE_APPS))
        tp.fit(train, epochs=80)
        acc = tp.accuracy(test)

        def trace_reqs(seed):
            samples = generate_trace(500, seed=seed)
            rng = np.random.default_rng(seed + 9)
            arr = np.cumsum(rng.exponential(1 / 40.0, len(samples)))
            return [Request(prompt_tokens=s.prompt_tokens,
                            decode_tokens=s.decode_tokens,
                            arrival=float(a), task=s.task)
                    for s, a in zip(samples, arr)]
        rr = run_heuristic(Cluster(prof, 4), trace_reqs(991),
                           make_policy("round_robin", prof))
        cfg = rl.RouterConfig(variant="guided", n_instances=4,
                              explore_episodes=5, seed=0,
                              q_arch="decomposed")
        out = rl.train(cfg, prof, lambda ep: trace_reqs(100 + ep), 7,
                       valid_fn=lambda: trace_reqs(555))
        st = rl.evaluate(cfg, prof, out["agent"], trace_reqs(991))
    gain = (rr["e2e_mean"] - st["e2e_mean"]) / rr["e2e_mean"] * 100
    emit("a12_trace_predictor_acc", t["us"] / 3, f"{acc:.3f}")
    emit("a12_trace_rr_e2e_s", t["us"] / 3, f"{rr['e2e_mean']:.2f}")
    emit("a12_trace_guided_e2e_s", t["us"] / 3,
         f"{st['e2e_mean']:.2f}({gain:+.1f}%)")
    assert acc > 0.4


if __name__ == "__main__":
    main()
