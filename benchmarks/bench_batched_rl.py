"""Batched multi-episode RL training throughput vs the sequential baseline.

Trains the guided router over the SAME 16-episode schedule (V100 x4,
Table-1 mixture, 200 requests @ 20 rps, identical workload seeds and
exploration decay) with (a) the sequential per-decision loop
(`rl_router.train`), (b) the batched runner at 8 parallel episodes on
the Python stepper (`batched_rl.train_batched`), and (c) the batched
runner on the vectorized structure-of-arrays simulator
(`backend="vec"`: all episodes' instances packed into one vecsim
pool, fused span stepping -- decision-for-decision identical to (b),
gated by tests/test_vecsim.py).  Reports episodes/sec for each plus
speedups, heterogeneous-scenario throughput (mixed hardware,
bursty/diurnal arrivals), and a held-out quality check of the
batched-trained policy against round robin.

Acceptance: the batched runner must be >= 3x the sequential baseline at
8 parallel episodes on CPU, and the vec backend must be >= 1.5x the
sequential baseline (a conservative floor -- the vec/py ratio is
numpy-dispatch-bound and machine-dependent at this m=4 width; the
speedup rows report what this machine achieves).
"""
from __future__ import annotations

import os

# One intra-op XLA thread: the batched runner overlaps the async learner
# with simulator Python, so XLA must not fight the Python thread for
# cores.  Must be set before jax initializes -- benchmarks/run.py runs
# each bench in a fresh interpreter, so this only affects this bench.
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import time

import numpy as np

from benchmarks.common import emit
from repro.core import batched_rl, rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import Scenario, generate, scenario_stream, \
    to_requests

PROF = V100_LLAMA2_7B
N, RATE, M = 200, 20.0, 4
EPISODES = 16
N_ENVS = 8
EVAL_SEEDS = (991, 992)


def _reqs(seed):
    return to_requests(generate(N, seed=seed), rate=RATE, seed=seed + 5000)


def _scenario(ep):
    return Scenario.homogeneous(PROF, M, _reqs(100 + ep),
                                name=f"paper-{ep}")


def _cfg():
    return rl.RouterConfig(variant="guided", n_instances=M,
                           explore_episodes=8, q_arch="decomposed", seed=0)


def main():
    bcfg = batched_rl.BatchedRLConfig(n_envs=N_ENVS, m_max=M)
    vcfg = batched_rl.BatchedRLConfig(n_envs=N_ENVS, m_max=M,
                                      backend="vec")
    # warmup: compile q_values (batch 1 and N_ENVS) + both learner shapes
    rl.train(_cfg(), PROF, lambda ep: _reqs(900 + ep), 1)
    batched_rl.train_batched(_cfg(), _scenario, N_ENVS, bcfg=bcfg)

    t0 = time.time()
    rl.train(_cfg(), PROF, lambda ep: _reqs(100 + ep), EPISODES)
    dt_seq = time.time() - t0
    seq_eps = EPISODES / dt_seq

    t0 = time.time()
    out = batched_rl.train_batched(_cfg(), _scenario, EPISODES, bcfg=bcfg)
    dt_bat = time.time() - t0
    bat_eps = EPISODES / dt_bat

    t0 = time.time()
    out_vec = batched_rl.train_batched(_cfg(), _scenario, EPISODES,
                                       bcfg=vcfg)
    dt_vec = time.time() - t0
    vec_eps = EPISODES / dt_vec

    speedup = bat_eps / seq_eps
    vec_speedup = vec_eps / seq_eps
    emit("batched_rl_sequential_eps_per_s", dt_seq / EPISODES * 1e6,
         f"{seq_eps:.2f}")
    emit("batched_rl_batched8_eps_per_s", dt_bat / EPISODES * 1e6,
         f"{bat_eps:.2f}")
    emit("batched_rl_speedup_at_8", 0.0, f"{speedup:.2f}x")
    emit("batched_rl_vec8_eps_per_s", dt_vec / EPISODES * 1e6,
         f"{vec_eps:.2f}")
    emit("batched_rl_vec_speedup_vs_seq", 0.0, f"{vec_speedup:.2f}x")
    emit("batched_rl_vec_vs_py_batched", 0.0,
         f"{vec_eps / bat_eps:.2f}x")
    # the vec run made the SAME training decisions (same completions)
    n_py = sum(h["n"] for h in out["history"])
    n_vec = sum(h["n"] for h in out_vec["history"])
    assert n_py == n_vec == EPISODES * N, (n_py, n_vec)

    # quality guard: the batched-trained guided policy must stay
    # competitive with round robin on held-out episodes (the sequential
    # path's quality is gated separately by bench_fig1b_rl)
    rr = float(np.mean([run_heuristic(
        Cluster(PROF, M), _reqs(sd),
        make_policy("round_robin", PROF))["e2e_mean"]
        for sd in EVAL_SEEDS]))
    bat = float(np.mean([batched_rl.evaluate_scenarios(
        _cfg(), out["agent"],
        [Scenario.homogeneous(PROF, M, _reqs(sd))])[0]["e2e_mean"]
        for sd in EVAL_SEEDS]))
    emit("batched_rl_quality_e2e_s", 0.0,
         f"{bat:.2f}(rr={rr:.2f})")

    # heterogeneous stream throughput (mixed hardware + arrival
    # patterns), on the vec backend: wider pooled clusters (m up to 6)
    # are vecsim's favourable regime
    t0 = time.time()
    het = batched_rl.train_batched(
        _cfg(), scenario_stream(0, n_requests=N), EPISODES,
        bcfg=batched_rl.BatchedRLConfig(n_envs=N_ENVS, m_max=6,
                                        backend="vec"))
    dt_het = time.time() - t0
    n_done = sum(h["n"] for h in het["history"])
    emit("batched_rl_hetero_vec_eps_per_s", dt_het / EPISODES * 1e6,
         f"{EPISODES / dt_het:.2f}({n_done}reqs)")

    assert speedup >= 3.0, (
        f"batched runner speedup {speedup:.2f}x < 3x at {N_ENVS} envs")
    assert vec_speedup >= 1.5, (
        f"vec-backend batched runner speedup {vec_speedup:.2f}x < 1.5x "
        "over the sequential Python stepper")
    assert bat <= rr * 1.25, (
        f"batched-trained policy collapsed: e2e {bat:.2f} vs RR {rr:.2f}")


if __name__ == "__main__":
    main()
