"""Engine calibration + sim-vs-engine fidelity gate.

Two halves of the sim<->engine loop (ROADMAP: "calibrate a real-engine
profile and compare simulator-vs-engine gateway percentiles"):

  1. ``core.calibrate`` sweeps the real jitted engine (reduced
     qwen3-0.6b on CPU) and fits grad1/grad2/t_decode_base/
     t_prefill_base; emits the fit R^2s (trend-gated: a calibration
     that stops being linear is a regression).
  2. ``serving.fidelity`` replays ONE arrival stream through the py
     simulator, the vec simulator, and real engines under the same
     mixing policy, and emits per-percentile deltas:
       * on the fixed V100 paper profile every clock is virtual, so the
         deltas are machine-independent and trend-gated tightly;
       * on the just-calibrated profile (machine-dependent timings) the
         gate is the TOLERANCE BAND itself: within_band=1 iff the
         engine's P95 E2E is within BAND of the simulator's.

Asserted: vec is bit-identical to py, and |P95 E2E rel delta| <= BAND
on both profiles.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import jax

from benchmarks.common import emit, timed
from repro.configs import get_config
from repro.core import calibrate as cal
from repro.core.profiles import V100_LLAMA2_7B
from repro.models import params as params_lib
from repro.serving import fidelity as fid

BAND = 0.35          # |engine vs sim| P95 E2E relative tolerance


def _emit_fidelity(tag: str, report: dict, us: float):
    for metric in ("e2e", "ttft"):
        d = report["deltas"]["engine_vs_py"][metric]
        parts = []
        for pct in ("p50", "p95"):
            rel = d[pct]["rel"]
            if rel is not None:
                parts.append(f"{pct}_absrel={abs(rel):.4f}")
        emit(f"fidelity_{tag}_{metric}", us, " ".join(parts))


def main():
    model_cfg = get_config("qwen3-0.6b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), model_cfg)

    with timed() as t_cal:
        res = cal.calibrate(model_cfg, params)
    emit("fidelity_calibration", t_cal["us"],
         f"r2_prefill={res.prefill_fit.r2:.4f} "
         f"r2_decode={res.decode_fit.r2:.4f} "
         f"grad1={res.profile.grad1:.3e} grad2={res.profile.grad2:.3e}")
    assert res.ok, "calibration sanity (grad1 > grad2 > 0) failed"
    assert min(res.prefill_fit.r2, res.decode_fit.r2) >= 0.90, \
        "calibration fit degraded far below the 0.95 CI gate"

    fcfg = fid.FidelityConfig()
    # 1) machine-independent: the paper's V100 profile, virtual clocks
    with timed() as t_v100:
        rep_v100 = fid.run_fidelity(V100_LLAMA2_7B, fcfg,
                                    model_cfg=model_cfg, params=params)
    _emit_fidelity("v100", rep_v100, t_v100["us"] / len(fcfg.backends))

    # 2) the just-calibrated profile: the band IS the gate
    with timed() as t_calp:
        rep_cal = fid.run_fidelity(res.profile, fcfg,
                                   model_cfg=model_cfg, params=params)
    cal_rel = rep_cal["deltas"]["engine_vs_py"]["e2e"]["p95"]["rel"]
    v100_rel = rep_v100["deltas"]["engine_vs_py"]["e2e"]["p95"]["rel"]
    emit("fidelity_calibrated", t_calp["us"] / len(fcfg.backends),
         f"within_band={int(abs(cal_rel) <= BAND)} "
         f"cal_e2e_p95_rel={cal_rel:+.4f}")

    # 3) saturating stream: preemption fidelity.  Bursts overflow the
    # KV budget so BOTH sim and engine must preempt -- the gate is that
    # the preemption path itself agrees, not just uncontended latency.
    sat_cfg = fid.FidelityConfig(
        backends=("py", "vec", "engine"), n_requests=24, n_instances=1,
        n_slots=2, cache_len=64, capacity_tokens=80,
        prompt_lengths=(16, 32), decode_range=(4, 12), rate=6.0,
        saturate=True)
    with timed() as t_sat:
        rep_sat = fid.run_fidelity(V100_LLAMA2_7B, sat_cfg,
                                   model_cfg=model_cfg, params=params)
    sat_d = rep_sat["deltas"]["engine_vs_py"]["preemptions"]
    emit("fidelity_saturate", t_sat["us"] / len(sat_cfg.backends),
         f"py_preempt={sat_d['a']} engine_preempt={sat_d['b']} "
         f"both={int(sat_d['both_preempt'])}")
    assert rep_sat["backends"]["vec"] == rep_sat["backends"]["py"], \
        "vec diverged from py under saturation"
    assert sat_d["both_preempt"], \
        f"saturating stream failed to preempt both sides: {sat_d}"

    # vec and jax must reproduce py bit for bit on the same stream
    for rep in (rep_v100, rep_cal):
        assert rep["backends"]["vec"] == rep["backends"]["py"], \
            "vec backend diverged from the py stepper"
        assert rep["backends"]["jax"] == rep["backends"]["py"], \
            "jax backend diverged from the py stepper"
    assert abs(v100_rel) <= BAND, \
        f"V100 fidelity outside band: {v100_rel:+.4f}"
    assert abs(cal_rel) <= BAND, \
        f"calibrated fidelity outside band: {cal_rel:+.4f}"


if __name__ == "__main__":
    main()
