"""Roofline summary: reads the dry-run artifacts and prints the per-cell
three-term roofline table (§Roofline deliverable)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit


def main():
    base = os.environ.get("DRYRUN_DIR", "artifacts/dryrun/single")
    files = sorted(glob.glob(os.path.join(base, "*.json")))
    if not files:
        emit("roofline_missing_artifacts", 0.0,
             "run_python_-m_repro.launch.dryrun_--all_first")
        return
    for f in files:
        d = json.load(open(f))
        if "skipped" in d:
            continue
        name = f"{d['arch']}__{d['shape']}"
        dom = d["dominant"].replace("_s", "")
        frac = d["useful_flop_ratio"]
        emit(f"roofline_{name}",
             d.get("compile_s", 0) * 1e6,
             f"c={d['compute_s']*1e3:.2f}ms_m={d['memory_s']*1e3:.2f}ms_"
             f"x={d['collective_s']*1e3:.2f}ms_dom={dom}_"
             f"useful={frac:.2f}"
             f"_peak={d['peak_bytes_per_device']/2**30:.1f}GiB")


if __name__ == "__main__":
    main()
