"""Fig. 1b/1c reproduction: intelligent-router variants vs heuristics.

Trains baseline / workload-aware / workload-guided RL routers (short
schedule sized for CPU) and evaluates all policies on held-out episodes:
end-to-end latency, TTFT, router wait, preemptions.

``FIG1B_SCALE=paper`` (the nightly workflow) switches the RL variants to
the BATCHED trainer (`core.batched_rl`, vec simulator backend) on a
paper-sized schedule -- the guided-vs-baseline gate at a scale too slow
for per-PR CI."""
from __future__ import annotations

import os

import numpy as np

from benchmarks.common import emit, timed
from repro.core import batched_rl, rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import Scenario, generate, to_requests

PROF = V100_LLAMA2_7B
PAPER_SCALE = os.environ.get("FIG1B_SCALE", "") == "paper"
N, RATE, M = 400, 20.0, 4
EPISODES = 60 if PAPER_SCALE else 12
EVAL_SEEDS = (991, 992, 993)


def _reqs(seed):
    return to_requests(generate(N, seed=seed), rate=RATE, seed=seed + 5000)


def eval_policy(fn):
    stats = [fn(_reqs(sd)) for sd in EVAL_SEEDS]
    keys = ("e2e_mean", "ttft_mean", "tbt_mean", "preemptions")
    return {k: float(np.mean([s[k] for s in stats])) for k in keys}


def main():
    rows = {}
    with timed() as t:
        for name in ("round_robin", "jsq", "max_capacity", "min_min",
                     "decode_balancer", "impact_greedy"):
            rows[name] = eval_policy(
                lambda r, n=name: run_heuristic(
                    Cluster(PROF, M), r, make_policy(n, PROF)))
        for variant in ("baseline", "aware", "guided"):
            cfg = rl.RouterConfig(
                variant=variant, n_instances=M, seed=0,
                explore_episodes=24 if PAPER_SCALE else 8,
                q_arch="decomposed")
            if PAPER_SCALE:
                # the batched trainer at paper scale: N concurrent
                # episodes on the fused vec simulator, one shared buffer
                out = batched_rl.train_batched(
                    cfg,
                    lambda ep: Scenario.homogeneous(PROF, M,
                                                    _reqs(100 + ep)),
                    EPISODES,
                    bcfg=batched_rl.BatchedRLConfig(
                        n_envs=8, m_max=M, backend="vec"),
                    valid_fn=lambda: Scenario.homogeneous(
                        PROF, M, _reqs(555)))
            else:
                out = rl.train(cfg, PROF,
                               lambda ep: _reqs(100 + ep), EPISODES,
                               valid_fn=lambda: _reqs(555))
            rows[f"rl_{variant}"] = eval_policy(
                lambda r, c=cfg, a=out["agent"]: rl.evaluate(c, PROF, a, r))
        if PAPER_SCALE:
            # uniform-vs-PER quality gate (Fig. 1b carry-over): the
            # guided variant again with prioritized replay on the jax
            # registry backend (hybrid pool: long spans on the jitted
            # kernel, short ones on the numpy fast path)
            cfg_per = rl.RouterConfig(
                variant="guided", n_instances=M, seed=0,
                explore_episodes=24, q_arch="decomposed")
            out_per = batched_rl.train_batched(
                cfg_per,
                lambda ep: Scenario.homogeneous(PROF, M,
                                                _reqs(100 + ep)),
                EPISODES,
                bcfg=batched_rl.BatchedRLConfig(
                    n_envs=8, m_max=M, backend="jax",
                    pool_kwargs={"min_span_ticks": 32},
                    prioritized=True),
                valid_fn=lambda: Scenario.homogeneous(
                    PROF, M, _reqs(555)))
            rows["rl_guided_per"] = eval_policy(
                lambda r, c=cfg_per, a=out_per["agent"]:
                    rl.evaluate(c, PROF, a, r))
    rr = rows["round_robin"]["e2e_mean"]
    per = t["us"] / len(rows)
    for name, row in rows.items():
        gain = (rr - row["e2e_mean"]) / rr * 100
        emit(f"fig1b_{name}_e2e_s", per,
             f"{row['e2e_mean']:.2f}({gain:+.1f}%vsRR)")
        emit(f"fig1c_{name}_ttft_s", per, f"{row['ttft_mean']:.2f}")
    # the guided variant must be the best RL variant (paper ordering) and
    # competitive with round robin
    assert rows["rl_guided"]["e2e_mean"] <= \
        min(rows["rl_baseline"]["e2e_mean"],
            rows["rl_aware"]["e2e_mean"]) + 1e-6
    assert rows["rl_guided"]["e2e_mean"] <= rr * 1.15
    if PAPER_SCALE:
        # PER must not degrade the guided router's held-out quality
        # (Schaul et al.: prioritization helps or ties at this scale)
        assert rows["rl_guided_per"]["e2e_mean"] <= \
            rows["rl_guided"]["e2e_mean"] * 1.10


if __name__ == "__main__":
    main()
