"""Shared benchmark helpers."""
from __future__ import annotations

import time
from contextlib import contextmanager

ROWS = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def emit_direction(**directions):
    """Declare trend-gate directions for this bench's metric keys:
    ``emit_direction(episodes_per_sec="high", us="low")``.  Keys match
    exactly or as prefixes.  run.py folds these into the bench's
    ``--json`` entry, so a refreshed ``baseline.json`` carries its own
    direction metadata and ``trend.py`` never has to guess a new key's
    direction from its global prefix lists (which would let e.g. an
    ``episodes_per_sec_*`` collapse gate in the wrong direction)."""
    pairs = " ".join(f"{k}={v}" for k, v in sorted(directions.items()))
    print(f"#direction {pairs}", flush=True)


@contextmanager
def timed():
    t0 = time.time()
    box = {}
    yield box
    box["s"] = time.time() - t0
    box["us"] = (time.time() - t0) * 1e6
