"""Fig. 5 reproduction: TBT distribution, instance queue depth, and router
wait for round-robin vs the workload-guided router."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import generate, to_requests

PROF = V100_LLAMA2_7B
N, RATE, M = 400, 20.0, 4


def _reqs(seed):
    return to_requests(generate(N, seed=seed), rate=RATE, seed=seed + 5000)


def tbt_stats(reqs):
    tbts = [r.tbt for r in reqs if r.tbt is not None]
    return (float(np.mean(tbts)), float(np.percentile(tbts, 99)),
            float(np.var(tbts)))


def main():
    with timed() as t:
        reqs_rr = _reqs(991)
        run_heuristic(Cluster(PROF, M), reqs_rr,
                      make_policy("round_robin", PROF))
        cfg = rl.RouterConfig(variant="guided", n_instances=M,
                              explore_episodes=6, seed=0,
                              q_arch="decomposed")
        out = rl.train(cfg, PROF, lambda ep: _reqs(100 + ep), 8,
                       valid_fn=lambda: _reqs(555))
        reqs_g = _reqs(991)
        st_g = rl.evaluate(cfg, PROF, out["agent"], reqs_g)
    mean_rr, p99_rr, var_rr = tbt_stats(reqs_rr)
    mean_g, p99_g, var_g = tbt_stats(reqs_g)
    emit("fig5_tbt_mean_ms(rr/guided)", t["us"] / 2,
         f"{mean_rr*1e3:.1f}/{mean_g*1e3:.1f}")
    emit("fig5_tbt_p99_ms(rr/guided)", t["us"] / 2,
         f"{p99_rr*1e3:.1f}/{p99_g*1e3:.1f}")
    emit("fig5_tbt_var(rr/guided)", t["us"] / 2,
         f"{var_rr:.4f}/{var_g:.4f}")
    emit("fig5_router_wait_s_guided", t["us"] / 2,
         f"{st_g['router_wait_mean']:.2f}")


if __name__ == "__main__":
    main()
