"""Online-vs-frozen router under nonstationary drift.

One seeded ``make_drift_scenario`` stream: mid-flight the tenant mix
flips (chat-dominated -> heavy ingest, tenant churn included) while the
chaos layer straggles one instance and crash/restarts another.  Three
arms serve the IDENTICAL stream through identically-configured
gateways:

  * ``frozen``  -- RLPolicy with a Q-head offline-trained on the
    PRE-flip mix only (health features enabled but never excited during
    stationary training: the frozen head cannot know what a straggler
    looks like);
  * ``online``  -- ``training.OnlineTrainer`` warm-started from the SAME
    checkpoint, learning on its own transition stream between arrival
    windows, guided exploration + the r_mixing safe-fallback guardrail;
  * ``mixing``  -- the workload-aware heuristic, the guardrail's
    yardstick.

Acceptance (asserted, and trend-gated via the emitted keys):

  * **online adapts, frozen doesn't** -- post-flip P95 E2E of the
    online arm is strictly below the frozen arm's;
  * **the guardrail holds** -- in every arrival window the online arm's
    P95 E2E stays within GUARD_BAND of the mixing heuristic's (worst
    case is heuristic parity, never an unhinged Q-head).

``ONLINE_DRIFT_SCALE=paper`` runs the nightly-sized configuration
(longer stream, more offline episodes); the default ``smoke`` fits CI.
Every clock is virtual, so all emitted latencies are
machine-independent.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import tempfile

import numpy as np

from benchmarks.common import emit, emit_direction, timed
from repro.core import rl_router as rl
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving.gateway import Gateway, GatewayConfig, OracleLength
from repro.serving.policies import RLPolicy, make_gateway_policy
from repro.serving.request import Request
from repro.training.checkpoint import restore_learner, save_learner
from repro.training.online import OnlineConfig, OnlineTrainer

PROF = V100_LLAMA2_7B
M = 4
DRIFT_SEED = 23
GUARD_BAND = 1.05            # online P95 <= 1.05x mixing, every window

SCALES = {
    # n_requests, rate, offline episodes, offline reqs/ep, windows
    "smoke": (500, 3.0, 5, 220, 3),
    "paper": (1600, 3.5, 14, 400, 5),
}


def _rcfg() -> rl.RouterConfig:
    return rl.RouterConfig(variant="guided", n_instances=M,
                           q_arch="decomposed", seed=0,
                           include_health_features=True)


def _pretrain(rcfg, episodes: int, n_req: int, rate: float, ckpt: str):
    """Offline-train the frozen head on the PRE-flip tenant mix and
    checkpoint the full learner state (the online arm's warm start)."""

    def stream(ep: int):
        return wl.make_tenant_scenario(
            seed=1000 + ep, tenants=wl.DRIFT_PRE_TENANTS,
            n_requests=n_req, rate=rate, pattern="poisson",
            profiles=(PROF,) * M).requests

    out = rl.train(rcfg, PROF, stream, n_episodes=episodes)
    save_learner(ckpt, step=episodes, agent=out["agent"])
    return out["agent"]


def _clone(reqs):
    return [Request(prompt_tokens=r.prompt_tokens,
                    decode_tokens=r.decode_tokens, arrival=r.arrival,
                    task=r.task, tenant=r.tenant) for r in reqs]


def _p95(vals):
    return float(np.quantile(np.asarray(vals, float), 0.95)) \
        if len(vals) else float("nan")


def _serve(scn, policy, trainer=None):
    reqs = _clone(scn.requests)
    # breaker_factor high: the circuit breaker must NOT mask the
    # straggler (that would hand every arm the same avoidance for
    # free) -- the health FEATURES stay live for the RL state, but
    # acting on them is each policy's own job
    gcfg = GatewayConfig(chaos=scn.meta["chaos"], failover=True,
                         max_retries=3, max_time=7200.0,
                         breaker_factor=50.0)
    gw = Gateway(gcfg, scn.profiles, policy, length=OracleLength())
    stats = gw.run(reqs)
    done = [r for r in reqs if r.finished is not None]
    flip = scn.meta["flip_time"]
    post = [r.e2e for r in done if r.arrival >= flip]
    t0 = min(r.arrival for r in done)
    t1 = max(r.arrival for r in done) + 1e-9
    return {"stats": stats, "done": done,
            "p95": _p95([r.e2e for r in done]),
            "post_p95": _p95(post),
            "bounds": (t0, t1)}


def _windows(res, n_windows: int):
    """Per-arrival-window P95 E2E over ``n_windows`` equal spans."""
    t0, t1 = res["bounds"]
    edges = np.linspace(t0, t1, n_windows + 1)
    out = []
    for i in range(n_windows):
        vals = [r.e2e for r in res["done"]
                if edges[i] <= r.arrival < edges[i + 1]]
        out.append(_p95(vals))
    return out


def main():
    scale = os.environ.get("ONLINE_DRIFT_SCALE", "smoke")
    n_req, rate, episodes, ep_req, n_windows = SCALES[scale]
    rcfg = _rcfg()
    scn = wl.make_drift_scenario(seed=DRIFT_SEED, n_requests=n_req,
                                 rate=rate, profiles=(PROF,) * M,
                                 straggler_factor=4.0)

    with tempfile.TemporaryDirectory() as tmp:
        ckpt = os.path.join(tmp, "warm")
        with timed() as t_off:
            _pretrain(rcfg, episodes, ep_req, rate, ckpt)
        emit("online_drift_pretrain", t_off["us"] / max(episodes, 1),
             f"episodes={episodes} reqs_per_ep={ep_req}")

        frozen_agent = rl.make_agent(rcfg)
        restore_learner(ckpt, frozen_agent)
        with timed() as t_frozen:
            frozen = _serve(scn, RLPolicy(frozen_agent, rcfg))

        trainer = OnlineTrainer(rcfg, OnlineConfig(
            warm_start=ckpt, eps=0.03, guard=True,
            guard_window=48, guard_regret=0.12, guard_cooldown=20.0,
            seed=0))
        with timed() as t_online:
            online = _serve(scn, trainer.policy, trainer)

    with timed() as t_mix:
        mixing = _serve(scn, make_gateway_policy("mixing", rcfg))

    emit_direction(postflip_p95="low", p95="low", window_ratio="low",
                   online_beats_frozen="high", adapt_gain="high",
                   fallback_entries="low", learner_steps="high",
                   transitions="high")

    emit("online_drift_frozen", t_frozen["us"],
         f"postflip_p95={frozen['post_p95']:.3f} "
         f"p95_e2e={frozen['p95']:.3f} "
         f"n={frozen['stats']['n']}")
    tel = trainer.telemetry()
    emit("online_drift_online", t_online["us"],
         f"postflip_p95={online['post_p95']:.3f} "
         f"p95_e2e={online['p95']:.3f} "
         f"n={online['stats']['n']} "
         f"learner_steps={trainer.agent.steps} "
         f"transitions={int(tel['transitions'])} "
         f"fallback_entries={int(tel['fallback_entries'])} "
         f"explored={int(tel['explored'])}")
    emit("online_drift_mixing", t_mix["us"],
         f"postflip_p95={mixing['post_p95']:.3f} "
         f"p95_e2e={mixing['p95']:.3f} "
         f"n={mixing['stats']['n']}")

    wins_online = _windows(online, n_windows)
    wins_mixing = _windows(mixing, n_windows)
    ratios = [o / m for o, m in zip(wins_online, wins_mixing)]
    gain = (frozen["post_p95"] - online["post_p95"]) \
        / frozen["post_p95"]
    emit("online_drift_gate", t_online["us"],
         f"adapt_gain={gain:.4f} "
         f"online_beats_frozen={int(online['post_p95'] < frozen['post_p95'])} "
         f"window_ratio_max={max(ratios):.4f} "
         + " ".join(f"window_ratio_{i}={r:.4f}"
                    for i, r in enumerate(ratios)))

    # gate 1: the online arm adapts past the flip, the frozen one can't
    assert online["post_p95"] < frozen["post_p95"], (
        f"online post-flip P95 {online['post_p95']:.3f} not below "
        f"frozen {frozen['post_p95']:.3f}")
    # gate 2: the guardrail keeps every window within the mixing band
    assert max(ratios) <= GUARD_BAND, (
        f"online fell outside {GUARD_BAND}x of mixing in a window: "
        f"{[f'{r:.3f}' for r in ratios]}")
    # every arm served the whole stream (chaos conservation)
    for arm in (frozen, online, mixing):
        assert arm["stats"]["n"] + arm["stats"]["shed"] \
            + arm["stats"]["cancelled"] == n_req


if __name__ == "__main__":
    main()
