"""Table 1 reproduction: decode-bucket predictor accuracy per task.

Ours (task hint + time-aligned unequal buckets) vs the S^3-style baseline
(no hint) vs equal 250-token buckets, plus the §A.7 task classifier."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core import predictor as pred
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B

PROF = V100_LLAMA2_7B


def main():
    train = wl.generate(3500, seed=1)
    test = wl.generate(900, seed=2)
    with timed() as t:
        ours = pred.BucketPredictor(
            pred.PredictorConfig(use_hint=True), PROF, seed=0)
        ours.fit(train, epochs=3)
        acc = ours.accuracy(test)
        nohint = pred.BucketPredictor(
            pred.PredictorConfig(use_hint=False), PROF, seed=0)
        nohint.fit(train, epochs=3)
        acc_nh = nohint.accuracy(test)
        equal = pred.BucketPredictor(
            pred.PredictorConfig(use_hint=True), PROF, seed=0,
            equal_buckets=True, n_out=16)
        equal.fit(train, epochs=3)
        acc_eq = equal.accuracy(test)
        tc = pred.TaskClassifier(PROF, seed=0)
        tc.fit(train, epochs=3)
        acc_task = tc.accuracy(test)
    labels = [ours.label(s) for s in test]
    maj = float(np.bincount(labels).max() / len(labels))
    emit("table1_ours_hint_unequal_acc", t["s"] * 1e6 / 4, f"{acc:.3f}")
    emit("table1_no_hint_acc", t["s"] * 1e6 / 4, f"{acc_nh:.3f}")
    emit("table1_equal_buckets_acc", t["s"] * 1e6 / 4, f"{acc_eq:.3f}")
    emit("table1_task_classifier_acc(A7)", t["s"] * 1e6 / 4,
         f"{acc_task:.3f}")
    emit("table1_majority_baseline", 0.0, f"{maj:.3f}")
    # per-task accuracy (the Table 1 'Ours' column layout)
    preds = ours.predict(test)
    for task in wl.TASKS:
        idx = [i for i, s in enumerate(test) if s.task == task]
        if idx:
            a = float(np.mean([preds[i] == labels[i] for i in idx]))
            emit(f"table1_acc_{task}", 0.0, f"{a:.3f}")
    assert acc > maj + 0.1, "predictor must beat majority class"
    assert acc > acc_nh, "task hint must improve accuracy (paper §5.1)"


if __name__ == "__main__":
    main()
