"""Table 2 reproduction: batching x routing grid over the four arrival
scenarios (LH/HL random; all-4 random; LH then HL; HL then LH).

Key paper claims checked: the routing choice moves E2E more than the
batching choice; 'dedicated small-large' is severely worse; for the
sequenced scenarios III/IV all batching algorithms tie and only routing
matters."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.serving.request import Request

PROF = V100_LLAMA2_7B

# class templates (tokens) tuned to the paper's heavy/light thresholds:
# heavy prompt: grad1*p >= 0.5s -> p >= 1563; heavy decode: d*base >= 5s
# -> d >= 300.
CLASSES = {
    "LL": (200, 60), "LH": (200, 900), "HL": (1800, 60), "HH": (1800, 900)
}
# prompts are capped at 1000 in the dataset; scenario requests use the
# paper's synthetic classes directly (they exceed the cap deliberately).
N = 100
RATE = 0.6


def scenario(name, seed=0):
    rng = np.random.default_rng(seed)
    if name == "lh_hl_random":
        kinds = rng.choice(["LH", "HL"], N)
    elif name == "random":
        kinds = rng.choice(list(CLASSES), N)
    elif name == "lh_then_hl":
        kinds = ["LH"] * (N // 2) + ["HL"] * (N - N // 2)
    elif name == "hl_then_lh":
        kinds = ["HL"] * (N // 2) + ["LH"] * (N - N // 2)
    arrivals = np.cumsum(rng.exponential(1 / RATE, N))
    reqs = []
    for k, at in zip(kinds, arrivals):
        p, d = CLASSES[k]
        p = int(p * rng.uniform(0.8, 1.2))
        d = int(d * rng.uniform(0.8, 1.2))
        reqs.append(Request(prompt_tokens=p, decode_tokens=d,
                            arrival=float(at)))
    return reqs


SCENARIOS = ("lh_hl_random", "random", "lh_then_hl", "hl_then_lh")
BATCHING = ("bin_packing", "least_work_left", "fcfs")
ROUTING = ("dedicated", "round_robin", "decode_balancer")


def main():
    results = {}
    with timed() as t:
        for sc in SCENARIOS:
            for b in BATCHING:
                for r in ROUTING:
                    reqs = scenario(sc, seed=11)
                    cluster = Cluster(PROF, 2, scheduler=b)
                    stats = run_heuristic(cluster, reqs,
                                          make_policy(r, PROF))
                    results[(sc, b, r)] = stats["e2e_mean"]
    n = len(results)
    for sc in SCENARIOS:
        for b in BATCHING:
            row = "/".join(f"{results[(sc, b, r)]:.1f}" for r in ROUTING)
            emit(f"table2_{sc}_{b}_e2e_s(ded/rr/bal)", t["us"] / n, row)
    # claim 1: routing spread > batching spread (averaged)
    route_spread = np.mean([
        max(results[(sc, b, r)] for r in ROUTING)
        - min(results[(sc, b, r)] for r in ROUTING)
        for sc in SCENARIOS for b in BATCHING])
    batch_spread = np.mean([
        max(results[(sc, b, r)] for b in BATCHING)
        - min(results[(sc, b, r)] for b in BATCHING)
        for sc in SCENARIOS for r in ROUTING])
    emit("table2_routing_vs_batching_spread_s", t["us"] / n,
         f"{route_spread:.2f}_vs_{batch_spread:.2f}")
    # paper claim: the routing choice materially moves E2E for a fixed
    # batcher.  (In our simulator the batching spread is ALSO large --
    # bin-packing admission degrades badly under overload -- which is a
    # recorded deviation from the paper's Table 2; see EXPERIMENTS.md.)
    mean_e2e = np.mean(list(results.values()))
    assert route_spread > 0.05 * mean_e2e
    # claim 2: dedicated small-large is worse than round robin on the
    # mixed-arrival scenarios under the paper's default FCFS batcher.
    # (Under bin-packing/LWL in heavy overload the segregation can win --
    # a recorded deviation, see EXPERIMENTS.md.)
    for sc in SCENARIOS[:2]:
        assert results[(sc, "fcfs", "dedicated")] >= \
            results[(sc, "fcfs", "round_robin")] - 1e-6


if __name__ == "__main__":
    main()
