"""Pooled-episode throughput of the jitted JAX backend vs the numpy
vec pool and the sequential Python stepper, parity-asserted.

All backends serve the SAME seeded per-episode workloads; before any
ratio is reported, every jax episode is checked request-for-request
against its python twin (completion clock, instance, preemptions) --
a ratio from a diverged simulation would be meaningless.

Measured honestly on this runner: on 2-core CPU XLA the jitted round
loop is DISPATCH-BOUND -- each `while_loop` round costs ~0.7 ms of
thunk dispatch + carry traffic against ~0.1 ms for the whole numpy
round, so ``episodes_per_sec_jax`` sits well below the vec pool and
the ≥5x target is out of reach off-accelerator (see docs/BACKENDS.md
for the accelerator story).  The hybrid pool (``min_span_ticks=8``,
the registry default) keeps short spans on the numpy path and is the
configuration real CPU training uses.  The trend gate bands whatever
values this box produces via the per-entry direction metadata below,
so a silent collapse (or a silent direction flip on a new key) still
fails.

``JAXSIM_SCALE=nightly`` doubles the episode pool to n_envs=64.
"""
from __future__ import annotations

import os

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import time

import numpy as np

from benchmarks.bench_vecsim import drive
from benchmarks.common import emit, emit_direction
from repro.core.backends import make_backend
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster
from repro.core.vecsim import VecCluster
from repro.core.workload import generate, to_requests

PROF = V100_LLAMA2_7B
NIGHTLY = os.environ.get("JAXSIM_SCALE", "") == "nightly"
N_ENVS = 64 if NIGHTLY else 32
M = 4                        # instances per episode
N_REQS = 60                  # requests per episode
RATE = 20.0
TRIALS = 2
SPAN_CAP = 256
MAX_T = 36_000.0


def _reqs(ep):
    return to_requests(generate(N_REQS, seed=900 + ep), rate=RATE,
                       seed=1900 + ep)


def drive_pooled(pool, all_reqs, policy):
    """Drive one episode per pool slot to completion, all episodes
    advancing in SHARED fused spans (the batched trainer's shape):
    per episode, route while the central queue has work, then advance
    to its next arrival (or a bounded drain window); every episode's
    span lands in the same ``advance_span`` call."""
    clusters = [VecCluster(PROF, M, pool=pool, ep=e)
                for e in range(len(all_reqs))]
    pend = [sorted(rs, key=lambda r: r.arrival) for rs in all_reqs]
    idx = [0] * len(clusters)
    live = set(range(len(clusters)))
    while live:
        spans = []
        for e in sorted(live):
            c, rs = clusters[e], pend[e]
            while idx[e] < len(rs) and rs[idx[e]].arrival <= c.t:
                c.enqueue(rs[idx[e]])
                idx[e] += 1
            for _ in range(64):
                if not c.central:
                    break
                act = policy.act(c)
                if act is None or act >= c.m:
                    break
                c.route(act)
            if len(c.completed) >= len(rs) or c.t >= MAX_T:
                live.discard(e)
                continue
            if c.central:
                k = 1
            elif idx[e] >= len(rs):
                k = SPAN_CAP
            else:
                k = max(1, min(SPAN_CAP, int(np.ceil(
                    (rs[idx[e]].arrival - c.t) / c.dt))))
            t, bounds = c.t, []
            for _ in range(k):
                t += c.dt
                bounds.append(t)
            spans.append((e, bounds))
        if spans:
            out = pool.advance_span(spans)
            for e, bounds in spans:
                clusters[e].collect_span(out[e][0], len(bounds))
    for c in clusters:
        c.sync_all()


def _assert_parity(ref, got, tag):
    for e, (ra, rb) in enumerate(zip(ref, got)):
        for a, b in zip(ra, rb):
            assert a.finished == b.finished, (tag, e, a.rid)
            assert a.first_token == b.first_token, (tag, e, a.rid)
            assert a.instance == b.instance, (tag, e, a.rid)
            assert a.preemptions == b.preemptions, (tag, e, a.rid)


def main():
    emit_direction(episodes_per_sec="high", speedup="high",
                   jax_rounds="high")
    policy = make_policy("jsq", PROF)
    times = {}
    streams = {}
    counters = {}

    def timed_run(tag, fn):
        best = 9e9
        for _ in range(TRIALS):
            rs = [_reqs(e) for e in range(N_ENVS)]
            t0 = time.perf_counter()
            fn(rs)
            best = min(best, time.perf_counter() - t0)
            streams[tag] = rs
        times[tag] = best

    def run_py(all_reqs):
        for rs in all_reqs:
            drive(Cluster(PROF, M, backend="py"), rs, policy)

    def make_pool_runner(backend, tag, **kw):
        def run(all_reqs):
            pool = make_backend(backend).make_pool(N_ENVS, **kw)
            drive_pooled(pool, all_reqs, policy)
            if hasattr(pool, "n_jax_calls"):
                counters[tag] = (pool.n_jax_calls, pool.n_numpy_calls)
        return run

    timed_run("py", run_py)
    timed_run("vec", make_pool_runner("vec", "vec"))
    # everything through the jitted kernel (min_span_ticks=0) and the
    # registry-default hybrid (short spans on the numpy fast path)
    timed_run("jax", make_pool_runner("jax", "jax", min_span_ticks=0))
    timed_run("hyb", make_pool_runner("jax", "hyb"))

    _assert_parity(streams["py"], streams["vec"], "vec")
    _assert_parity(streams["py"], streams["jax"], "jax")
    _assert_parity(streams["py"], streams["hyb"], "hyb")
    # the kernel must carry essentially the whole run; the only numpy
    # dispatches a min_span_ticks=0 pool may take are empty-arena spans
    # (before the first arrival lands)
    jax_calls, jax_np = counters["jax"]
    assert jax_calls > 0 and jax_np <= jax_calls * 0.01, \
        (jax_calls, jax_np)

    eps = {k: N_ENVS / v for k, v in times.items()}
    emit(f"jaxsim_pool_n{N_ENVS}",
         times["jax"] / N_ENVS * 1e6,
         f"episodes_per_sec_jax={eps['jax']:.2f} "
         f"episodes_per_sec_vec={eps['vec']:.2f} "
         f"episodes_per_sec_py={eps['py']:.2f} "
         f"jax_rounds={jax_calls}")
    emit(f"jaxsim_speedups_n{N_ENVS}",
         times["hyb"] / N_ENVS * 1e6,
         f"speedup_jax_vs_vec={times['vec'] / times['jax']:.3f} "
         f"speedup_hybrid_vs_vec={times['vec'] / times['hyb']:.3f} "
         f"speedup_vec_vs_py={times['py'] / times['vec']:.3f}")


if __name__ == "__main__":
    main()
