"""Optional-hypothesis shim.

Tier-1 must collect and pass on a bare interpreter (no ``hypothesis``):
property tests import ``given``/``settings``/``st`` from here.  When
hypothesis is available this is a transparent re-export; when it is not,
``@given`` replaces the test with a zero-argument stub marked skip (the
strategy-valued parameters would otherwise be collected as fixtures).
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        callable returning None (the value is never used -- the test body
        is replaced by a skip stub)."""

        def __getattr__(self, _name):
            def _strategy(*_args, **_kwargs):
                return None
            return _strategy

    st = _AnyStrategy()

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    def given(*_args, **_kwargs):
        def deco(fn):
            @pytest.mark.skip(reason="hypothesis not installed")
            def _skipped():
                pass
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
