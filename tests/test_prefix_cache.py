"""Prefix/KV cache model: radix-LRU semantics, cache-aware routing
policies, the RL cache feature, session workloads, and py-vs-vec
bit-exact parity on cached-prefill scenarios."""
import numpy as np
import pytest

from repro.core import rl_router as rl
from repro.core import state as state_lib
from repro.core.policies import make_policy
from repro.core.prefix_cache import PrefixCache, hit_fractions
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import SessionConfig, make_tenant_scenario
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.policies import make_gateway_policy
from repro.serving.request import Request

PROF = V100_LLAMA2_7B


# -- the cache model ---------------------------------------------------------

def _chain(*idx):
    return tuple(("t", i) for i in idx)


def test_match_is_longest_prefix_and_read_only():
    pc = PrefixCache(capacity_tokens=1024, block=16)
    pc.insert(_chain(0, 1, 2))
    assert pc.match(_chain(0, 1, 2, 3)) == 3
    assert pc.match(_chain(0, 1)) == 2
    assert pc.match(_chain(9)) == 0
    assert pc.match(None) == 0
    before = list(pc._blocks)
    pc.match(_chain(0))               # queries must not touch LRU order
    pc.cached_tokens(100, _chain(0, 1))
    pc.hit_fraction(100, _chain(0, 1))
    assert list(pc._blocks) == before


def test_cached_tokens_capped_below_prompt():
    pc = PrefixCache(capacity_tokens=1024, block=16)
    pc.insert(_chain(0, 1, 2, 3))
    # a fully-cached prompt still prefills >= 1 token (first logits)
    assert pc.cached_tokens(64, _chain(0, 1, 2, 3)) == 63
    assert pc.cached_tokens(100, _chain(0, 1, 2, 3)) == 64
    assert pc.cached_tokens(0, _chain(0, 1)) == 0


def test_lru_eviction_removes_leaves_before_prefixes():
    pc = PrefixCache(capacity_tokens=4 * 16, block=16)
    pc.insert(_chain(0, 1, 2, 3))       # exactly at budget
    pc.insert(_chain(0, 9))             # one block over -> one eviction
    # the deepest old leaf dies first; shared parent (block 0) survives
    assert pc.match(_chain(0, 9)) == 2
    assert pc.match(_chain(0, 1, 2, 3)) == 3   # block 3 was evicted
    assert len(pc) == 4


def test_admit_updates_stats_and_clear_keeps_them():
    pc = PrefixCache(capacity_tokens=1024, block=16)
    assert pc.admit(48, _chain(0, 1, 2)) == 0       # cold
    assert pc.admit(48, _chain(0, 1, 2)) == 47      # warm, capped
    assert (pc.hit_tokens, pc.lookup_tokens) == (47, 96)
    pc.clear()
    assert len(pc) == 0
    assert (pc.hit_tokens, pc.lookup_tokens) == (47, 96)
    assert pc.admit(48, None) == 0                  # opt-out requests


def test_block_validation():
    with pytest.raises(ValueError):
        PrefixCache(100, block=0)


# -- session workloads -------------------------------------------------------

def _session_scn(seed=7, n=160, rate=24.0, m=3, block=16):
    return make_tenant_scenario(seed=seed, n_requests=n, rate=rate,
                                pattern="poisson",
                                profiles=(PROF,) * m,
                                sessions=SessionConfig(block=block))


def test_session_scenario_shape():
    scn = _session_scn()
    rs = scn.requests
    assert len(scn.samples) == len(rs)
    assert all(a.arrival <= b.arrival for a, b in zip(rs, rs[1:]))
    for r, s in zip(rs, scn.samples):
        assert r.prompt_tokens == 16 * len(r.prefix_hashes)
        assert r.prompt_tokens + r.decode_tokens \
            == 16 * len(r.full_hashes)
        assert r.full_hashes[:len(r.prefix_hashes)] == r.prefix_hashes
        assert s.prompt_tokens == r.prompt_tokens
        assert s.decode_tokens == r.decode_tokens
    # follow-up turns extend prior context; tenants share system blocks
    assert any(len(r.prefix_hashes) > 3 for r in rs)
    chat = [r for r in rs if r.tenant == "chat"]
    assert len({r.prefix_hashes[0] for r in chat}) == 1


def test_session_follow_ups_hit_the_serving_cache():
    scn = _session_scn()
    gw = Gateway(GatewayConfig(prefix_cache_tokens=4096,
                               prefix_block=16),
                 (PROF,) * 3, make_gateway_policy("sticky"))
    gw.run(scn)
    hit = sum(i.prefix_cache.hit_tokens for i in gw.cluster.instances)
    look = sum(i.prefix_cache.lookup_tokens
               for i in gw.cluster.instances)
    assert hit / look > 0.4
    # hit_tokens also counts re-admissions after preemption, so it
    # dominates the per-request last-admission credit
    assert 0 < sum(r.cached_prefix for r in scn.requests) <= hit


# -- routing policies --------------------------------------------------------

def test_sticky_routes_follow_up_to_warm_instance():
    cluster = Cluster(PROF, 3, prefix_cache_tokens=4096,
                      prefix_block=16)
    cluster.instances[1].prefix_cache.insert(_chain(0, 1, 2))
    req = Request(prompt_tokens=64, decode_tokens=16,
                  prefix_hashes=_chain(0, 1, 2, 3))
    assert make_gateway_policy("sticky").route(cluster, req, 16) == 1
    fr = hit_fractions(cluster, req)
    assert fr[1] == 48 / 64 and fr[0] == fr[2] == 0.0


def test_sticky_cold_falls_back_to_least_outstanding():
    cluster = Cluster(PROF, 2, prefix_cache_tokens=4096)
    cluster.enqueue(Request(prompt_tokens=100, decode_tokens=50))
    cluster.route(0)
    req = Request(prompt_tokens=32, decode_tokens=8,
                  prefix_hashes=_chain(5))
    assert make_gateway_policy("sticky").route(cluster, req, 8) == 1


def test_mixing_cache_weight_breaks_toward_warm_instance():
    cluster = Cluster(PROF, 2, prefix_cache_tokens=4096,
                      prefix_block=16)
    cluster.instances[1].prefix_cache.insert(_chain(0, 1, 2, 3))
    req = Request(prompt_tokens=64, decode_tokens=16,
                  prefix_hashes=_chain(0, 1, 2, 3))
    blind = rl.mixing_scores(cluster, req, 16, 0.5)
    aware = rl.mixing_scores(cluster, req, 16, 0.5, cache_weight=0.5)
    assert blind[0] == blind[1]
    assert aware[1] > aware[0]
    assert aware[1] - blind[1] == pytest.approx(0.5 * 63 / 64)
    assert make_gateway_policy("mixing+cache").route(cluster, req,
                                                     16) == 1


# -- RL state feature --------------------------------------------------------

def test_cache_feature_dims_and_values():
    assert state_lib.instance_dims(True, False, True) \
        == state_lib.instance_dims(True, False) + state_lib.CACHE_DIMS
    cluster = Cluster(PROF, 2, prefix_cache_tokens=4096,
                      prefix_block=16)
    cluster.instances[0].prefix_cache.insert(_chain(0, 1))
    cluster.enqueue(Request(prompt_tokens=64, decode_tokens=16,
                            prefix_hashes=_chain(0, 1, 2, 3)))
    s = state_lib.featurize(cluster, PROF, include_cache=True)
    dims = state_lib.instance_dims(True, False, True)
    assert s.shape[0] == state_lib.state_dim(2, True, False, True)
    cb = state_lib.INSTANCE_DIMS + 1
    assert s[cb] == np.float32(32 / 64)
    assert s[dims + cb] == 0.0


def test_cache_feature_bit_exact_py_vs_vec():
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0,
                          include_cache_features=True,
                          prefix_cache_tokens=2048, prefix_block=16,
                          cache_weight=0.5)
    scn_p, scn_v = _session_scn(seed=11, n=90), _session_scn(seed=11,
                                                             n=90)
    env_p = rl.RoutingEnv(cfg, PROF)
    env_v = rl.RoutingEnv(cfg, PROF, backend="vec")
    s_p = env_p.reset(scn_p.requests)
    s_v = env_v.reset(scn_v.requests)
    done, steps = False, 0
    while not done and steps < 600:
        np.testing.assert_array_equal(s_p, s_v)
        np.testing.assert_array_equal(env_p.guidance_bonus(),
                                      env_v.guidance_bonus())
        a = (int(np.argmax(env_p.guidance_bonus()[:3]))
             if env_p.cluster.central else 3)
        s_p, r_p, done, _ = env_p.step(a)
        s_v, r_v, done_v, _ = env_v.step(a)
        assert done == done_v
        assert r_v == pytest.approx(r_p, rel=1e-9, abs=1e-9)
        steps += 1
    assert done
    for a, b in zip(scn_p.requests, scn_v.requests):
        assert a.finished == b.finished
        assert a.cached_prefix == b.cached_prefix


# -- cached-prefill stepper parity ------------------------------------------

def _run_pair(seed, m, pc_tokens, scheduler="fcfs", chunk=0):
    scn_a, scn_b = (_session_scn(seed=seed, n=140, m=m),
                    _session_scn(seed=seed, n=140, m=m))
    out = []
    for scn, backend in ((scn_a, "py"), (scn_b, "vec")):
        cluster = Cluster(PROF, m, scheduler=scheduler,
                          chunked_prefill=chunk, backend=backend,
                          prefix_cache_tokens=pc_tokens,
                          prefix_block=16)
        run_heuristic(cluster, scn.requests,
                      make_policy("round_robin", PROF))
        out.append((scn.requests, cluster))
    return out


@pytest.mark.parametrize("pc_tokens", [0, 512, 8192])
def test_session_parity_py_vs_vec(pc_tokens):
    """Cached-prefill admission credit, completion-time inserts, and
    LRU evictions (512-token budget) must be bit-identical."""
    (ra, ca), (rb, cb) = _run_pair(seed=5, m=3, pc_tokens=pc_tokens)
    for a, b in zip(ra, rb):
        assert a.finished == b.finished
        assert a.first_token == b.first_token
        assert a.prefill_done == b.prefill_done
        assert a.cached_prefix == b.cached_prefix
        assert a.prefilled == b.prefilled
        assert a.preemptions == b.preemptions
    if pc_tokens:
        for ia, ib in zip(ca.instances, cb.instances):
            assert ia.prefix_cache.hit_tokens \
                == ib.prefix_cache.hit_tokens
            assert list(ia.prefix_cache._blocks) \
                == list(ib.prefix_cache._blocks)
        assert sum(r.cached_prefix for r in ra) > 0


def test_failed_instance_loses_its_cache_on_both_backends():
    scn_a, scn_b = _session_scn(seed=3, n=120), _session_scn(seed=3,
                                                             n=120)
    reqs = []
    for scn, backend in ((scn_a, "py"), (scn_b, "vec")):
        cluster = Cluster(PROF, 3, backend=backend,
                          prefix_cache_tokens=4096, prefix_block=16)
        pending = sorted(scn.requests, key=lambda r: r.arrival)
        i, rr, failed = 0, 0, False
        while len(cluster.completed) < len(pending) \
                and cluster.t < 3000:
            while (i < len(pending)
                   and pending[i].arrival <= cluster.t):
                cluster.enqueue(pending[i])
                i += 1
            if cluster.t > 1.5 and not failed:
                cluster.fail_instance(0)
                failed = True
                assert len(cluster.instances[0].prefix_cache) == 0
            alive = cluster.alive()
            while cluster.central and alive:
                cluster.route(alive[rr % len(alive)])
                rr += 1
                alive = cluster.alive()
            cluster.advance()
        assert len(cluster.completed) == len(pending)
        reqs.append(pending)
    for a, b in zip(*reqs):
        assert a.finished == b.finished
        assert a.cached_prefix == b.cached_prefix
        assert a.preemptions == b.preemptions


# -- the headline win --------------------------------------------------------

def test_cache_aware_policy_beats_cache_blind_on_sessions():
    """mixing+cache must beat plain mixing on P95 E2E on a
    session-heavy stream (the bench_prefix_cache gate, in miniature)."""
    out = {}
    for pol in ("mixing", "mixing+cache"):
        scn = _session_scn(seed=7, n=200, rate=30.0)
        gw = Gateway(GatewayConfig(prefix_cache_tokens=4096,
                                   prefix_block=16),
                     (PROF,) * 3, make_gateway_policy(pol))
        stats = gw.run(scn)
        out[pol] = stats["snapshot"]["e2e"]["p95"]
    assert out["mixing+cache"] < out["mixing"]
