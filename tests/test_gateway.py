"""Serving gateway: streaming metrics, backpressure, policy parity,
micro-batched length prediction, prioritized replay, trend gate."""
import numpy as np
import pytest

from repro.core import batched_rl, predictor as pred_lib
from repro.core import rl_router as rl
from repro.core import workload as wl
from repro.core.cluster_manager import ManagedCluster, ManagedClusterConfig
from repro.core.dqn import DQNConfig, ReplayBuffer
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving.gateway import (Gateway, GatewayConfig,
                                   MicroBatchPredictor, OracleLength)
from repro.serving.metrics import SLO, P2Quantile, StreamMetrics, \
    WindowedReservoir
from repro.serving.policies import (LeastOutstandingWork,
                                    MixingImpactPolicy, RLPolicy,
                                    RoundRobinPolicy, make_gateway_policy)
from repro.training.train_loop import train_router

PROF = V100_LLAMA2_7B


def _tiny_predictor(seed=0):
    cfg = pred_lib.PredictorConfig(seq_len=32, d_model=16, n_heads=2,
                                   n_layers=1)
    return pred_lib.BucketPredictor(cfg, PROF, seed=seed)


def _scenario(seed=3, n=120, rate=14.0, m=3, pattern="bursty"):
    return wl.make_tenant_scenario(seed=seed, n_requests=n, rate=rate,
                                   pattern=pattern,
                                   profiles=(PROF,) * m)


# -- streaming percentile estimators ----------------------------------------

def test_p2_quantile_tracks_numpy():
    rng = np.random.default_rng(0)
    for q in (0.5, 0.95, 0.99):
        xs = rng.lognormal(1.0, 0.8, size=4000)
        est = P2Quantile(q)
        for x in xs:
            est.add(float(x))
        exact = float(np.quantile(xs, q))
        # P2 is an approximation; a few percent on a lognormal stream
        assert est.value() == pytest.approx(exact, rel=0.08), q


def test_p2_quantile_exact_below_five_samples():
    est = P2Quantile(0.5)
    for x in (3.0, 1.0, 2.0):
        est.add(x)
    assert est.value() == pytest.approx(2.0)
    assert P2Quantile(0.95).value() is None


def test_windowed_reservoir_matches_numpy_and_evicts():
    rng = np.random.default_rng(1)
    win = WindowedReservoir(window=10.0)
    samples = [(float(t), float(x)) for t, x in
               zip(np.linspace(0, 50, 500), rng.normal(5, 2, 500))]
    for t, x in samples:
        win.add(t, x)
    now = 50.0
    live = np.array([x for t, x in samples if t >= now - 10.0])
    for q in (0.5, 0.95, 0.99):
        assert win.quantile(q, now) == pytest.approx(
            float(np.quantile(live, q)))
    assert len(win) == live.size        # old samples evicted
    assert win.total == 500             # lifetime count preserved


def test_stream_metrics_per_tenant_and_slo():
    from repro.serving.request import Request
    m = StreamMetrics(window=100.0, slo=SLO(ttft_s=None, tbt_s=None,
                                            e2e_s=1.0))
    for i, (tenant, e2e) in enumerate([("a", 0.5), ("a", 2.0),
                                       ("b", 0.2)]):
        r = Request(prompt_tokens=10, decode_tokens=5, arrival=float(i),
                    tenant=tenant)
        r.first_token = r.arrival + e2e / 2
        r.finished = r.arrival + e2e
        m.on_admit(tenant)
        m.on_complete(r, tenant)
    m.on_shed("b")
    snap = m.snapshot(now=10.0)
    assert snap["completed"] == 3 and snap["shed"] == 1
    assert snap["slo_attained"] == 2
    assert snap["tenants"]["a"]["completed"] == 2
    assert snap["tenants"]["b"]["shed"] == 1
    assert snap["e2e"]["p50"] == pytest.approx(0.5)


# -- backpressure ------------------------------------------------------------

def test_bounded_queue_sheds_at_saturation():
    scn = _scenario(n=150, rate=60.0, m=2)
    gw = Gateway(GatewayConfig(queue_cap=8, on_full="shed"),
                 (PROF,) * 2, MixingImpactPolicy())
    stats = gw.run(scn)
    assert stats["shed"] > 0
    assert stats["admitted"] + stats["shed"] == 150
    assert stats["n"] == stats["admitted"]       # admitted all complete
    snap = stats["snapshot"]
    assert snap["shed"] == stats["shed"]
    assert 0.0 < snap["shed_rate"] < 1.0
    from repro.serving.request import Phase
    assert all(r.phase is Phase.SHED for r in gw.shed)


def test_bounded_queue_defers_without_loss():
    scn = _scenario(n=150, rate=60.0, m=2)
    cap = 8
    gw = Gateway(GatewayConfig(queue_cap=cap, on_full="defer"),
                 (PROF,) * 2, MixingImpactPolicy())
    stats = gw.run(scn)
    assert stats["shed"] == 0
    assert stats["n"] == stats["admitted"] == 150   # nothing lost
    # the router queue never exceeded the admission bound
    assert max(gw.cluster.queue_len_trace) <= cap


def test_unbounded_queue_never_sheds():
    scn = _scenario(n=80, rate=30.0, m=2)
    gw = Gateway(GatewayConfig(), (PROF,) * 2, RoundRobinPolicy())
    stats = gw.run(scn)
    assert stats["shed"] == 0 and stats["n"] == 80


# -- policy parity with the closed-loop path ---------------------------------

def test_policy_parity_with_managed_cluster():
    """Gateway + RL policy + oracle length service + unbounded queue
    must reproduce ManagedCluster.serve decision for decision."""
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0)
    agent = rl.make_agent(cfg)
    reqs_a = wl.to_requests(wl.generate(120, seed=7), rate=20.0, seed=8)
    reqs_b = wl.to_requests(wl.generate(120, seed=7), rate=20.0, seed=8)
    mc = ManagedCluster(ManagedClusterConfig(n_instances=3), cfg, PROF,
                        agent)
    seq = mc.serve(reqs_a)
    gw = Gateway(GatewayConfig(), (PROF,) * 3, RLPolicy(agent, cfg),
                 length=OracleLength())
    bat = gw.run(reqs_b)
    assert seq["n"] == bat["n"] == 120
    for a, b in zip(reqs_a, reqs_b):
        assert a.finished == pytest.approx(b.finished, abs=1e-9)
        assert a.instance == b.instance
        assert a.preemptions == b.preemptions
    for key in ("e2e_mean", "ttft_mean", "makespan", "preemptions"):
        assert seq[key] == pytest.approx(bat[key], rel=1e-9), key


def test_all_policies_complete_the_stream():
    for name in ("rr", "jsq", "mixing"):
        scn = _scenario(n=60, rate=10.0, m=2)
        gw = Gateway(GatewayConfig(), (PROF,) * 2,
                     make_gateway_policy(name))
        stats = gw.run(scn)
        assert stats["n"] == 60, name
        assert set(stats["snapshot"]["tenants"]) <= {"chat", "batch",
                                                     "misc"}


def test_jsq_policy_balances_outstanding_work():
    scn = _scenario(n=80, rate=12.0, m=3)
    gw = Gateway(GatewayConfig(), (PROF,) * 3, LeastOutstandingWork())
    stats = gw.run(scn)
    assert stats["n"] == 80
    per_inst = [sum(1 for r in scn.requests if r.instance == i)
                for i in range(3)]
    assert min(per_inst) > 0            # no instance starved


# -- micro-batched length predictor ------------------------------------------

def test_microbatch_predictor_matches_batch_predict_and_caches():
    pred = _tiny_predictor()
    samples = wl.generate(40, seed=5)
    reqs = wl.to_requests(samples, rate=10.0, seed=6)
    svc = MicroBatchPredictor(pred, batch_pad=16)
    svc.prefetch(list(zip(reqs, samples)))
    want = pred.predict(samples)
    got = np.array([r.predicted_bucket for r in reqs])
    np.testing.assert_array_equal(got, want)
    cap = int(PROF.capacity_tokens * 0.95)
    for r, b in zip(reqs, want):
        assert svc.estimate(r) == max(
            min(int(pred.bucket_upper_tokens(int(b))),
                cap - r.prompt_tokens), 1)
    assert svc.forwards == int(np.ceil(40 / 16))   # micro-batched
    assert svc.misses == 40 and svc.hits == 0
    # identical prompt content -> LRU hit, no new forward
    reqs2 = wl.to_requests(samples, rate=10.0, seed=7)
    svc.prefetch(list(zip(reqs2, samples)))
    assert svc.hits == 40 and svc.forwards == int(np.ceil(40 / 16))
    np.testing.assert_array_equal(
        np.array([r.predicted_bucket for r in reqs2]), want)


def test_microbatch_lru_evicts_oldest():
    pred = _tiny_predictor()
    svc = MicroBatchPredictor(pred, batch_pad=8, cache_size=10)
    samples = wl.generate(25, seed=9)
    reqs = wl.to_requests(samples, rate=10.0, seed=10)
    svc.prefetch(list(zip(reqs, samples)))
    assert len(svc._cache) <= 10


def test_gateway_runs_with_predictor_not_oracle():
    scn = _scenario(n=60, rate=10.0, m=2)
    svc = MicroBatchPredictor(_tiny_predictor())
    gw = Gateway(GatewayConfig(), (PROF,) * 2, MixingImpactPolicy(),
                 length=svc)
    stats = gw.run(scn)
    assert stats["n"] == 60
    assert svc.misses + svc.hits == 60
    assert all(r.predicted_decode is not None for r in scn.requests)


# -- predictor-backed d-hat in RL training (PR-1 follow-up) ------------------

def test_train_router_with_length_predictor_in_loop():
    pred = _tiny_predictor()
    seen = []

    def scn_fn(ep):
        samples = wl.generate(30, seed=50 + ep)
        s = wl.Scenario.homogeneous(
            PROF, 2, wl.to_requests(samples, rate=8.0, seed=60 + ep),
            name=f"t{ep}", samples=samples)
        seen.append(s)
        return s

    cfg = rl.RouterConfig(variant="guided", n_instances=2,
                          explore_episodes=2, q_arch="decomposed",
                          seed=0)
    out = train_router(cfg, scn_fn, 2,
                       batch_cfg=batched_rl.BatchedRLConfig(n_envs=2,
                                                            m_max=2),
                       length_predictor=pred)
    assert len(out["history"]) == 2
    for h in out["history"]:
        assert h["n"] == 30
    # every request the trainer saw carried the predictor's d-hat
    for s in seen:
        assert all(r.predicted_decode is not None for r in s.requests)
        assert all(r.predicted_bucket is not None for r in s.requests)
    r = seen[0].requests[0]
    assert pred_lib.predicted_decode(r) == r.predicted_decode


# -- prioritized replay -------------------------------------------------------

def _buf_cfg(**kw):
    base = dict(state_dim=4, n_actions=3, batch_size=8, buffer_size=64)
    base.update(kw)
    return DQNConfig(**base)


def test_replay_row_carries_unit_weight_by_default():
    buf = ReplayBuffer(_buf_cfg())
    buf.add(np.ones(4), 1, 0.5, np.zeros(4), 0.0, np.ones(3))
    assert buf.data.shape[1] == 2 * 4 + 4 + 3     # [s|s2|a|r|done|m|w]
    row = buf.data[0]
    assert row[-1] == 1.0
    np.testing.assert_array_equal(row[2 * 4 + 3:-1], np.ones(3))
    rows = buf.sample(np.random.default_rng(0), 4)
    assert np.all(rows[:, -1] == 1.0)             # uniform fallback


def test_prioritized_sampling_prefers_high_td():
    buf = ReplayBuffer(_buf_cfg())
    rng = np.random.default_rng(0)
    for i in range(32):
        buf.add(np.ones(4) * i, i % 3, 0.0, np.zeros(4), 0.0, np.ones(3))
    buf.update_priorities(np.arange(16), np.full(16, 1e-9))
    counts = np.zeros(32)
    for _ in range(200):
        rows, idx = buf.sample_prioritized(rng, 8, alpha=0.6, beta=0.4)
        assert np.all(rows[:, -1] > 0) and np.all(rows[:, -1] <= 1.0)
        counts[idx] += 1
    assert counts[16:].sum() > 5 * counts[:16].sum()
    # stored rows keep unit weights (IS weight only in the sampled copy)
    assert np.all(buf.data[:32, -1] == 1.0)


def test_per_priority_update_skips_overwritten_slots():
    """A deferred priority update for a ring slot that has since been
    overwritten must be dropped -- the fresh transition keeps its
    max-priority first-replay guarantee."""
    buf = ReplayBuffer(_buf_cfg(buffer_size=8))
    for i in range(8):
        buf.add(np.ones(4) * i, 0, 0.0, np.zeros(4), 0.0, np.ones(3))
    stamps = buf.write_seq[np.array([0, 1])].copy()
    buf.add(np.ones(4) * 99, 0, 0.0, np.zeros(4), 0.0, np.ones(3))
    buf.update_priorities(np.array([0, 1]), np.array([5.0, 5.0]),
                          expect_seq=stamps)
    assert buf.prio[0] == pytest.approx(1.0)       # slot 0 overwritten
    assert buf.prio[1] == pytest.approx(5.0 + 1e-3)
    assert buf.max_prio == pytest.approx(5.0 + 1e-3)


def test_prioritized_batched_training_completes():
    cfg = rl.RouterConfig(variant="guided", n_instances=2,
                          explore_episodes=2, seed=0)
    bcfg = batched_rl.BatchedRLConfig(n_envs=2, m_max=2,
                                      prioritized=True,
                                      learn_batch_size=32)
    out = batched_rl.train_batched(
        cfg, lambda ep: wl.Scenario.homogeneous(
            PROF, 2, wl.to_requests(wl.generate(30, seed=ep), rate=8.0,
                                    seed=ep + 9)),
        3, bcfg=bcfg)
    agent = out["agent"]
    assert agent.cfg.prioritized
    assert all(h["n"] == 30 for h in out["history"])
    assert agent.steps > 0                 # learner actually ran
    agent._resolve_priorities()
    live = agent.buffer.prio[:agent.buffer.size]
    assert len(np.unique(np.round(live, 9))) > 1   # TD priorities applied


# -- trend gate ---------------------------------------------------------------

def _report(ok=True, seconds=10.0, acc=0.9, p95=50.0):
    return {"results": [{
        "bench": "demo", "ok": ok, "seconds": seconds,
        "rows": [{"name": "demo_row", "us_per_call": "1.0",
                  "derived": f"acc={acc} p95_e2e={p95} n=100"}],
    }], "failures": []}


def test_trend_gate_passes_within_band_and_fails_on_regression():
    from benchmarks.trend import compare
    base = _report()
    ok, _ = compare(_report(acc=0.88, p95=55.0, seconds=20.0), base)
    assert ok == []
    bad_acc, _ = compare(_report(acc=0.4), base)
    assert any("acc" in r for r in bad_acc)
    # fraction-scale metrics gate at the tighter frac_tol band: a 0.2
    # accuracy drop fails even though it is within the generic 35% tol
    bad_frac, _ = compare(_report(acc=0.7), base)
    assert any("acc" in r for r in bad_frac)
    bad_p95, _ = compare(_report(p95=90.0), base)
    assert any("p95_e2e" in r for r in bad_p95)
    bad_time, _ = compare(_report(seconds=100.0), base)
    assert any("wall time" in r for r in bad_time)
    bad_fail, _ = compare(_report(ok=False), base)
    assert any("FAILED" in r for r in bad_fail)
    # unknown keys (n=) and new rows never gate
    cur = _report()
    cur["results"][0]["rows"][0]["derived"] += " n=5"
    cur["results"][0]["rows"].append(
        {"name": "new_row", "us_per_call": "1.0", "derived": "acc=0.1"})
    ok, notes = compare(cur, base)
    assert ok == [] and any("new" in n for n in notes)


def test_trend_gate_infers_direction_for_bare_value_rows():
    from benchmarks.trend import compare
    base = {"results": [{"bench": "table1", "ok": True, "seconds": 5.0,
                         "rows": [{"name": "table1_hint_acc",
                                   "us_per_call": "1",
                                   "derived": "0.766"}]}]}
    import copy
    cur = copy.deepcopy(base)
    cur["results"][0]["rows"][0]["derived"] = "0.30"
    bad, _ = compare(cur, base)
    assert any("table1_hint_acc" in r for r in bad)
    assert compare(base, base)[0] == []


def test_trend_gate_per_entry_directions():
    """A bench's baseline entry can carry its own ``directions`` map
    (emit_direction -> run.py --json); it beats the global prefix
    lists, so new keys gate the way the bench declared."""
    from benchmarks.trend import compare

    def rep(v, dirs):
        return {"results": [{
            "bench": "jaxsim", "ok": True, "seconds": 5.0,
            "directions": dirs,
            "rows": [{"name": "jaxsim_trainer", "us_per_call": "1",
                      "derived": f"episodes_per_sec_vec={v} "
                                 f"eps_gap={v}"}]}]}
    # prefix match: episodes_per_sec_* declared higher-is-better
    dirs = {"episodes_per_sec": "high", "eps_gap": "low"}
    bad, _ = compare(rep(10.0, dirs), rep(40.0, dirs))
    assert any("episodes_per_sec_vec" in r for r in bad)
    assert compare(rep(45.0, dirs), rep(40.0, dirs))[0] == []
    # exact-key override: the global lists call ``eps*`` higher-is-
    # better, the entry says lower -- the entry wins
    bad, _ = compare(rep(9.0, dirs), rep(5.0, dirs))
    assert any("eps_gap" in r for r in bad)
    ok, _ = compare(rep(4.0, {"eps_gap": "low"}),
                    rep(5.0, {"eps_gap": "low"}))
    assert ok == []


def test_trend_gate_flags_missing_rows():
    from benchmarks.trend import compare
    cur = _report()
    cur["results"][0]["rows"] = []
    bad, _ = compare(cur, _report())
    assert any("missing" in r for r in bad)


# -- per-tenant quotas / weighted-fair shedding -------------------------------

def _burst_stream(n_bursts=30, flood=14, queue_align=0.0145):
    """Adversarial timing: tenant 'flood' fills the bounded queue in
    bursts; tenant 'light' always arrives in the same tick, after the
    flood -- the worst case for tenant-blind shedding."""
    from repro.serving.request import Request
    reqs = []
    for burst in range(n_bursts):
        t = burst * 2.0
        for i in range(flood):
            reqs.append(Request(prompt_tokens=300, decode_tokens=400,
                                arrival=t + i * 0.001, tenant="flood"))
        reqs.append(Request(prompt_tokens=80, decode_tokens=60,
                            arrival=t + queue_align, tenant="light"))
    return reqs


def _run_quota_gateway(weights, backend="py"):
    gw = Gateway(GatewayConfig(queue_cap=8, on_full="shed",
                               tenant_weights=weights, backend=backend),
                 (PROF,) * 2, make_gateway_policy("rr"))
    stats = gw.run(_burst_stream())
    return gw, stats


def test_weighted_fair_shed_protects_under_share_tenant():
    """Blind shedding punishes whoever arrives at saturation (here: the
    light tenant, 100% shed); weighted-fair eviction sheds the tenant
    most over its queue share instead."""
    _, blind = _run_quota_gateway(None)
    _, fair = _run_quota_gateway({"flood": 1.0, "light": 1.0})
    b_light = blind["snapshot"]["tenants"]["light"]
    f_light = fair["snapshot"]["tenants"]["light"]
    assert b_light["admitted"] == 0          # blind: always shed
    assert f_light["shed"] == 0              # fair: fully protected
    assert fair["snapshot"]["shed_fairness"] \
        > blind["snapshot"]["shed_fairness"]
    # the shed burden moved onto the over-share tenant
    assert f_light["shed_burden"] == 0.0
    assert fair["snapshot"]["tenants"]["flood"]["shed_burden"] > 1.0
    # books balance under eviction accounting (offered counted once)
    n = len(_burst_stream())
    for stats in (blind, fair):
        assert stats["admitted"] + stats["shed"] == n
        snap = stats["snapshot"]
        assert sum(d["shed"] for d in snap["tenants"].values()) \
            == stats["shed"]
        assert sum(d["admitted"] for d in snap["tenants"].values()) \
            == stats["admitted"]


def test_fair_shed_respects_weights():
    """A zero-weight tenant is entitled to nothing: it gets no
    protection (its own arrivals shed at saturation) and never evicts
    the weighted tenant."""
    _, fair = _run_quota_gateway({"flood": 1.0, "light": 0.0})
    light = fair["snapshot"]["tenants"]["light"]
    assert light["admitted"] == 0


def test_fair_shed_on_vec_backend_matches_py():
    _, py = _run_quota_gateway({"flood": 1.0, "light": 1.0},
                               backend="py")
    gw_vec, vec = _run_quota_gateway({"flood": 1.0, "light": 1.0},
                                     backend="vec")
    assert vec["shed"] == py["shed"]
    assert vec["admitted"] == py["admitted"]
    assert vec["snapshot"]["shed_fairness"] == pytest.approx(
        py["snapshot"]["shed_fairness"])
    from repro.serving.request import Phase
    for r in gw_vec.shed:            # evicted requests stay SHED after
        assert r.phase is Phase.SHED  # the end-of-run arena sync


def test_no_weights_preserves_blind_behaviour():
    """tenant_weights=None must reproduce the pre-quota gateway
    decision for decision (no eviction machinery in the path)."""
    scn_a, scn_b = _scenario(seed=9, rate=40.0), _scenario(seed=9,
                                                           rate=40.0)
    gw_a = Gateway(GatewayConfig(queue_cap=4, on_full="shed"),
                   (PROF,) * 2, make_gateway_policy("rr"))
    gw_b = Gateway(GatewayConfig(queue_cap=4, on_full="shed",
                                 tenant_weights=None),
                   (PROF,) * 2, make_gateway_policy("rr"))
    a, b = gw_a.run(scn_a), gw_b.run(scn_b)
    assert a["shed"] == b["shed"] and a["admitted"] == b["admitted"]


def test_shed_fairness_index_bounds_and_none():
    m = StreamMetrics()
    assert m.shed_fairness() is None         # no tenants yet
    m.on_admit("a")
    m.on_admit("b")
    assert m.shed_fairness() == pytest.approx(1.0)
    for _ in range(9):
        m.on_shed("b")
    fairness = m.shed_fairness()
    assert 0.0 < fairness < 1.0
    snap = m.snapshot(0.0)
    assert snap["shed_fairness"] == pytest.approx(fairness)
    assert snap["tenants"]["a"]["shed_burden"] == 0.0
    assert snap["tenants"]["b"]["shed_burden"] > 1.0


def test_fair_evict_in_defer_mode_is_lossless():
    """Defer mode must never lose a request, with or without fair
    eviction: a displaced victim returns to the client overflow and is
    re-admitted when the queue drains."""
    reqs = _burst_stream()
    gw = Gateway(GatewayConfig(queue_cap=8, on_full="defer",
                               tenant_weights={"flood": 1.0,
                                               "light": 1.0}),
                 (PROF,) * 2, make_gateway_policy("rr"))
    stats = gw.run(reqs)
    assert stats["shed"] == 0
    assert stats["admitted"] == len(reqs)
    assert stats["n"] == len(reqs)          # all served
    # metrics admit-reversal kept offered counts exact
    snap = stats["snapshot"]
    assert snap["admitted"] == len(reqs)
    # queue-occupancy bookkeeping fully drained (keys pruned at zero)
    assert gw._q_tenant == {}
