"""Training substrate: optimizer math, checkpoint fault tolerance, data
pipeline determinism, EP grad symmetrization, adafactor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.training import checkpoint as ckpt_lib
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib


def test_adamw_minimizes_quadratic():
    cfg = opt_lib.OptimizerConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                  total_steps=200, clip_norm=0)
    target = jnp.asarray(np.random.default_rng(0).standard_normal((4, 4)))
    params = {"w": jnp.zeros((4, 4))}
    opt = opt_lib.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = opt_lib.update(cfg, g, opt, params)
    assert float(loss(params)) < 1e-2


def test_grad_clip_and_schedule():
    cfg = opt_lib.OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100,
                                  clip_norm=1.0)
    assert float(opt_lib.schedule(cfg, jnp.asarray(0))) == 0.0
    assert float(opt_lib.schedule(cfg, jnp.asarray(10))) == pytest.approx(
        1.0)
    assert float(opt_lib.schedule(cfg, jnp.asarray(100))) == pytest.approx(
        cfg.min_lr_ratio, rel=1e-3)
    params = {"w": jnp.ones((3,))}
    opt = opt_lib.init(params)
    g = {"w": jnp.full((3,), 100.0)}
    _, _, metrics = opt_lib.update(cfg, g, opt, params)
    assert float(metrics["grad_norm"]) > 100


def test_adafactor_memory_and_descent():
    from repro.launch.steps import adafactor_init, adafactor_update
    target = jnp.asarray(np.random.default_rng(1).standard_normal((8, 6)))
    params = {"w": jnp.zeros((8, 6))}
    st = adafactor_init(params)
    # factored state is O(n+m), not O(n*m)
    assert st["v"]["w"]["vr"].shape == (8,)
    assert st["v"]["w"]["vc"].shape == (6,)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for _ in range(300):
        g = jax.grad(loss)(params)
        params, st = adafactor_update(0.05, g, st, params)
    assert float(loss(params)) < 0.2


def test_checkpoint_roundtrip_and_corruption(tmp_path):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": [np.ones((2,), np.int32), np.zeros((5,), np.float64)]}
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2)
    mgr.save(1, tree, {"note": "x"}, sync=True)
    tree2 = jax.tree.map(np.zeros_like, tree)
    restored, extra = mgr.restore(tree2)
    assert extra["step"] == 1
    np.testing.assert_array_equal(restored["a"], tree["a"])
    # newer corrupt checkpoint -> falls back to the older intact one
    mgr.save(2, tree, sync=True)
    newest = sorted(p for p in os.listdir(tmp_path)
                    if p.startswith("step_"))[-1]
    with open(os.path.join(tmp_path, newest), "r+b") as f:
        f.seek(0)
        f.write(b"garbage!")
    restored, extra = mgr.restore(tree2)
    assert extra["step"] == 1
    assert mgr.latest_step() == 2
    mgr.close()


def test_checkpoint_gc_keeps_latest(tmp_path):
    mgr = ckpt_lib.CheckpointManager(str(tmp_path), keep=2)
    for s in range(5):
        mgr.save(s, {"x": np.asarray([s])}, sync=True)
    ckpts = sorted(p for p in os.listdir(tmp_path)
                   if p.startswith("step_"))
    assert len(ckpts) == 2
    restored, extra = mgr.restore({"x": np.zeros((1,))})
    assert extra["step"] == 4
    mgr.close()


def test_data_pipeline_deterministic_restart():
    cfg = REGISTRY["qwen3-0.6b"].reduced()
    b1 = data_lib.synthetic_batch(cfg, 4, 16, seed=7, step=42)
    b2 = data_lib.synthetic_batch(cfg, 4, 16, seed=7, step=42)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = data_lib.synthetic_batch(cfg, 4, 16, seed=7, step=43)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    loader = data_lib.PrefetchLoader(cfg, 4, 16, seed=7, start_step=42)
    step, batch = next(loader)
    loader.close()
    assert step == 42
    np.testing.assert_array_equal(batch["tokens"], b1["tokens"])


def test_symmetrize_ep_grads():
    import dataclasses
    from repro.training.train_loop import symmetrize_ep_grads
    cfg = REGISTRY["grok-1-314b"].reduced()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, impl="ep", n_experts=2))
    # storage 4 slots = 2 experts x R=2 (stacked under 'layers')
    g = {"layers": [{"moe": {"routed": {
        "w_up": jnp.arange(4 * 2 * 3, dtype=jnp.float32).reshape(
            1, 4, 2, 3)}}}]}
    out = symmetrize_ep_grads(cfg, g)
    w = np.asarray(out["layers"][0]["moe"]["routed"]["w_up"])[0]
    np.testing.assert_allclose(w[0], w[1])      # replicas of expert 0
    np.testing.assert_allclose(w[2], w[3])      # replicas of expert 1
    assert not np.allclose(w[0], w[2])


def test_train_loop_end_to_end_loss_decreases():
    from repro.training.train_loop import init_train_state, make_train_step
    cfg = REGISTRY["llama-2-7b"].reduced()
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(
        cfg, opt_lib.OptimizerConfig(lr=3e-3, warmup_steps=2,
                                     total_steps=60)))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in data_lib.synthetic_batch(
            cfg, 8, 32, seed=0, step=i % 4).items()}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
