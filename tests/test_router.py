"""Router-core unit tests: impact estimator (Eq. 1-2), bucket edges,
heuristic policies, DQN machinery, guidance properties."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core import impact
from repro.core.profiles import V100_LLAMA2_7B, fit, tpu_v5e_profile
from repro.core.workload import generate, table1_stats

PROF = V100_LLAMA2_7B


def test_eq1_prefill_penalty():
    # empty instance, tiny prompt: no penalty
    assert impact.prefill_penalty(PROF, 10, 0.0) == 1.0
    # T_p = grad1 * p^2 crossing epsilon turns into 1 - T/eps
    p = int((PROF.epsilon / PROF.grad1) ** 0.5) + 10
    t_p = impact.prefill_impact(PROF, p, 0.0)
    assert t_p > PROF.epsilon
    assert impact.prefill_penalty(PROF, p, 0.0) == pytest.approx(
        1.0 - t_p / PROF.epsilon)


def test_eq2_decode_penalty_monotone():
    r1 = impact.decode_penalty(PROF, 100, 100, 0.0)
    r2 = impact.decode_penalty(PROF, 100, 100, 10_000.0)
    assert r2 < r1 <= 0.0


@given(p=st.integers(1, 1000), d=st.integers(1, 4000),
       load_a=st.floats(0, 60000), load_b=st.floats(0, 60000))
@settings(max_examples=200, deadline=None)
def test_mixing_prefers_lighter_instance(p, d, load_a, load_b):
    """r_mixing is monotonically worse with resident tokens -- the router
    heuristic always prefers the lighter instance."""
    scores = impact.mixing_per_instance(PROF, p, d, [load_a, load_b])
    if load_a < load_b:
        assert scores[0] >= scores[1]
    elif load_b < load_a:
        assert scores[1] >= scores[0]


@given(p=st.integers(1, 1000), d=st.integers(1, 4000),
       loads=st.lists(st.floats(0, 50000), min_size=2, max_size=8),
       chosen=st.integers(0, 7))
@settings(max_examples=100, deadline=None)
def test_guidance_h_nonpositive_and_zero_at_best(p, d, loads, chosen):
    chosen = chosen % len(loads)
    h = impact.guidance_h(PROF, p, d, loads, chosen)
    assert h <= 1e-9
    best = int(np.argmax(impact.mixing_per_instance(PROF, p, d, loads)))
    assert impact.guidance_h(PROF, p, d, loads, best) == pytest.approx(0.0)


def test_bucket_edges_time_aligned():
    """Bucket edges follow the 0.5 * 4^k second boundaries (§5.1)."""
    edges = PROF.bucket_edges(5)
    tok_per_s = 1.0 / PROF.t_decode_base
    np.testing.assert_allclose(
        edges, [0.5 * 4 ** k * tok_per_s for k in range(4)])
    assert PROF.bucketize(1) == 0
    assert PROF.bucketize(int(edges[0]) + 1) == 1


def test_classification_thresholds():
    # 0.5s prompt, 5s decode thresholds
    p_heavy = int(PROF.heavy_prompt_s / PROF.grad1) + 1
    d_heavy = int(PROF.heavy_decode_s / PROF.t_decode_base) + 1
    assert PROF.classify(p_heavy, d_heavy) == "HH"
    assert PROF.classify(p_heavy - 50, d_heavy) == "LH"
    assert PROF.classify(p_heavy, d_heavy - 50) == "HL"
    assert PROF.classify(10, 10) == "LL"


def test_profile_fit_recovers_gradients():
    rng = np.random.default_rng(0)
    g1, g2, base = 3.2e-4, 3.3e-5, 0.0167
    pre = [(int(p), g1 * p + rng.normal(0, 1e-4))
           for p in rng.integers(10, 1000, 50)]
    dec = [(int(t), base + g2 * t + rng.normal(0, 1e-4))
           for t in rng.integers(100, 4000, 50)]
    prof = fit(pre, dec)
    assert abs(prof.grad1 - g1) / g1 < 0.05
    assert abs(prof.grad2 - g2) / g2 < 0.05


def test_tpu_profile_analytic():
    prof = tpu_v5e_profile(7e9, tp=16)
    # 7B bf16 weights over 16 chips: decode step time ~ weight read time
    assert 1e-4 < prof.t_decode_base < 2e-2
    assert prof.grad1 < prof.t_decode_base   # prefill/token < decode step


def test_workload_matches_table1():
    samples = generate(6000, seed=0)
    stats = table1_stats(samples, PROF)
    # Table 1: imdb (sentiment) has the longest prompts; eli5 (qna) the
    # longest decodes.
    assert stats["sentiment"]["prompt_mean"] > \
        2 * stats["qna"]["prompt_mean"]
    assert stats["qna"]["decode_mean"] > \
        2 * stats["translation"]["decode_mean"]
    # heavy-decode share ordering: qna >> entity/translation
    assert stats["qna"]["heavy_decode"] > stats["entity"]["heavy_decode"]
    for t, row in stats.items():
        assert row["prompt_mean"] <= 1000


def test_dqn_learns_trivial_contextual_bandit():
    from repro.core.dqn import DQNAgent, DQNConfig
    cfg = DQNConfig(state_dim=4, n_actions=2, hidden=(32, 32), gamma=0.0,
                    lr=1e-2, batch_size=64, buffer_size=5000, tau=0.05,
                    center_rewards=False)
    agent = DQNAgent(cfg, seed=0)
    rng = np.random.default_rng(0)
    mask = np.ones(2, bool)
    for i in range(600):
        s = rng.standard_normal(4).astype(np.float32)
        a = agent.act(s, mask, epsilon=0.3)
        r = 1.0 if (a == (s[0] > 0)) else -1.0
        agent.observe(s, a, r, s, 1.0, mask)
        agent.learn()
    correct = 0
    for _ in range(200):
        s = rng.standard_normal(4).astype(np.float32)
        a = agent.act(s, mask, epsilon=0.0)
        correct += int(a == (s[0] > 0))
    assert correct > 160


def test_decomposed_q_permutation_equivariance():
    """Swapping two instances' feature blocks swaps their Q values."""
    from repro.core.dqn import DQNConfig, apply_q, init_q
    import jax
    import jax.numpy as jnp
    inst, router, m = 9, 4, 4
    cfg = DQNConfig(state_dim=inst * m + router, n_actions=m + 1,
                    q_arch="decomposed", inst_dims=inst,
                    router_dims=router)
    params = init_q(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    s = rng.standard_normal((1, inst * m + router)).astype(np.float32)
    q = np.asarray(apply_q(cfg, params, jnp.asarray(s)))[0]
    s2 = s.copy()
    s2[0, :inst], s2[0, inst:2 * inst] = (s[0, inst:2 * inst].copy(),
                                          s[0, :inst].copy())
    q2 = np.asarray(apply_q(cfg, params, jnp.asarray(s2)))[0]
    np.testing.assert_allclose(q[0], q2[1], rtol=1e-5)
    np.testing.assert_allclose(q[1], q2[0], rtol=1e-5)
    np.testing.assert_allclose(q[4], q2[4], rtol=1e-5)   # defer invariant
