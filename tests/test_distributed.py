"""Distribution layer tests (multi host-device runs in subprocesses so the
main pytest process keeps a single CPU device)."""
import numpy as np


def test_pipeline_parallel_matches_sequential(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch import mesh as mesh_lib
        from repro.launch.mesh import make_mesh
        from repro.distributed import pipeline as pp
        mesh = make_mesh((4,), ('pipe',))
        n_stages, n_micro, mb, d = 4, 8, 2, 16
        ws = jax.random.normal(jax.random.PRNGKey(0), (n_stages, d, d)) / 4
        x = jax.random.normal(jax.random.PRNGKey(1), (n_micro, mb, d))
        stage = lambda w, x: jnp.tanh(x @ w)
        y = pp.pipeline_apply(stage, mesh, 'pipe', ws, x)
        ref = x
        for s in range(n_stages):
            ref = jnp.tanh(ref @ ws[s])
        print('ERR', float(jnp.abs(y - ref).max()))
    """)
    assert float(out.split("ERR")[1]) < 1e-5


def test_compressed_allreduce_accuracy(subproc):
    out = subproc("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.launch import mesh as mesh_lib
        from repro.launch.mesh import make_mesh
        from repro.distributed import compression as comp
        mesh = make_mesh((8,), ('data',))
        params = {'w': jax.random.normal(jax.random.PRNGKey(2), (16, 4))}
        xb = jax.random.normal(jax.random.PRNGKey(3), (32, 16))
        yb = jax.random.normal(jax.random.PRNGKey(4), (32, 4))
        loss = lambda p, b: jnp.mean((b['x'] @ p['w'] - b['y'])**2)
        batch = {'x': xb, 'y': yb}
        exact = jax.grad(lambda p: loss(p, batch))(params)
        gf = comp.make_compressed_dp_grad_fn(
            loss, mesh, ('data',),
            {'x': P('data', None), 'y': P('data', None)})
        with mesh_lib.set_mesh(mesh):
            approx = jax.jit(gf)(params, batch)
        rel = float(jnp.abs(approx['w'] - exact['w']).max()
                    / jnp.abs(exact['w']).max())
        print('REL', rel)
    """)
    assert float(out.split("REL")[1]) < 0.05


def test_ep_moe_matches_ragged(subproc):
    out = subproc("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import REGISTRY
        from repro.models import params as P, moe as MoE
        from repro.distributed import context as dist_ctx
        from repro.launch import mesh as mesh_lib
        from repro.launch.mesh import make_mesh
        cfg = REGISTRY['deepseek-moe-16b'].reduced()
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, n_experts=8, top_k=2, impl='ragged'))
        pr = P.init_params(jax.random.PRNGKey(0), cfg)
        moe_p = jax.tree.map(lambda x: x[0], pr['layers'][0])['moe']
        x = jax.random.normal(jax.random.PRNGKey(5), (32, cfg.d_model))
        yr, auxr = MoE.moe_ragged(moe_p, cfg, x)
        mesh = make_mesh((4, 2), ('data', 'model'))
        cfg_ep = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, impl='ep', capacity_factor=4.0))
        ctx = dist_ctx.ParallelContext(
            mesh=mesh, batch_axes=('data',), model_axis='model',
            ep_axes=('data',))
        with dist_ctx.use(ctx), mesh_lib.set_mesh(mesh):
            yep, auxep = jax.jit(
                lambda p, x: MoE.moe_ep(p, cfg_ep, x))(moe_p, x)
        print('ERR', float(jnp.abs(yep - yr).max()))
        print('AUXERR', abs(float(auxep) - float(auxr)))
    """)
    assert float(out.split("ERR")[1].split()[0]) < 1e-4
    # the EP aux loss is a per-shard estimator of the global balance loss
    # (mean of local f_e*P_e products), not bit-identical to it
    assert float(out.split("AUXERR")[1]) < 0.3


def test_moe_gather_matches_dense():
    import jax
    from repro.configs import REGISTRY
    from repro.models import moe as MoE
    from repro.models import params as P
    cfg = REGISTRY["jamba-v0.1-52b"].reduced()
    pr = P.init_params(jax.random.PRNGKey(0), cfg)
    moe_p = jax.tree.map(lambda x: x[0], pr["layers"][1])["moe"]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, cfg.d_model))
    yd, _ = MoE.moe_dense(moe_p, cfg, x)
    yg, _ = MoE.moe_gather(moe_p, cfg, x)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(yg),
                               rtol=2e-4, atol=2e-4)


def test_sharding_rules_divisibility_fallback():
    """Non-divisible dims fall back instead of producing invalid specs."""
    from repro.configs import get_config
    from repro.distributed.sharding import param_spec

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    # starcoder2: 36 heads don't divide 16 -> heads unsharded
    cfg = get_config("starcoder2-7b")
    spec = param_spec(("layers", "attn", "wq"), (1, 4608, 36, 128), cfg,
                      FakeMesh(), "train")
    assert spec[2] is None
    # gemma: 16 heads divide -> sharded over model
    cfg = get_config("gemma-7b")
    spec = param_spec(("layers", "attn", "wq"), (1, 3072, 16, 256), cfg,
                      FakeMesh(), "train")
    assert spec[2] == ("model",) or spec[2] == "model"
