"""Batched multi-episode RL runner + heterogeneous scenario generator."""
import numpy as np
import pytest

from repro.core import batched_rl, rl_router as rl
from repro.core.profiles import A100_LLAMA31_8B, V100_LLAMA2_7B
from repro.core.simulator import Cluster, SimInstance
from repro.core.workload import (ARRIVAL_PATTERNS, PROFILE_POOL, Scenario,
                                 arrival_times, generate, make_scenario,
                                 scenario_stream, to_requests)
from repro.serving.scheduler import get_scheduler

PROF = V100_LLAMA2_7B


def _reqs(n, seed=0, rate=20.0):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


# -- parity: 1-episode batched == sequential ---------------------------------

def test_batched_single_episode_matches_sequential_evaluate():
    """A 1-episode greedy batched run must reproduce the sequential
    rl_router path decision for decision: same completions, same
    per-request finish times, same summary metrics."""
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0)
    agent = rl.make_agent(cfg)
    reqs_seq = _reqs(120, seed=7)
    reqs_bat = _reqs(120, seed=7)
    seq = rl.evaluate(cfg, PROF, agent, reqs_seq)
    bat = batched_rl.evaluate_scenarios(
        cfg, agent, [Scenario.homogeneous(PROF, 3, reqs_bat)])[0]
    assert seq["n"] == bat["n"] == 120
    for a, b in zip(reqs_seq, reqs_bat):
        assert a.finished == pytest.approx(b.finished, abs=1e-9)
        assert a.instance == b.instance
        assert a.preemptions == b.preemptions
    for key in ("e2e_mean", "ttft_mean", "makespan", "preemptions",
                "router_wait_mean", "spikes"):
        assert seq[key] == pytest.approx(bat[key], rel=1e-9), key


def test_batched_parity_holds_for_mlp_arch_and_baseline_variant():
    cfg = rl.RouterConfig(variant="baseline", n_instances=2,
                          q_arch="mlp", seed=3)
    agent = rl.make_agent(cfg)
    ra, rb = _reqs(60, seed=11), _reqs(60, seed=11)
    seq = rl.evaluate(cfg, PROF, agent, ra)
    bat = batched_rl.evaluate_scenarios(
        cfg, agent, [Scenario.homogeneous(PROF, 2, rb)])[0]
    assert seq["e2e_mean"] == pytest.approx(bat["e2e_mean"], rel=1e-9)


# -- padding: narrow scenarios under a wide agent ----------------------------

def test_padded_narrow_scenario_completes_all_requests():
    cfg = rl.RouterConfig(variant="guided", n_instances=4, seed=0)
    agent = rl.make_agent(cfg, m=4)          # padded width 4
    scen = Scenario.homogeneous(PROF, 2, _reqs(60, seed=5))
    stats = batched_rl.evaluate_scenarios(cfg, agent, [scen], m_max=4)[0]
    assert stats["n"] == 60
    assert all(r.instance in (0, 1) for r in scen.requests)


def test_pad_state_and_mask_layout():
    from repro.core import state as sl
    dims = sl.INSTANCE_DIMS + 1
    s = np.arange(dims * 2 + sl.ROUTER_DIMS, dtype=np.float32)
    p = sl.pad_state(s, 2, 5)
    assert p.shape == (dims * 5 + sl.ROUTER_DIMS,)
    np.testing.assert_array_equal(p[:dims * 2], s[:dims * 2])
    assert not p[dims * 2:dims * 5].any()        # padded blocks are zeros
    np.testing.assert_array_equal(p[dims * 5:], s[dims * 2:])
    m = sl.pad_mask(np.array([True, False, True]), 2, 5)
    assert m.tolist() == [True, False, False, False, False, True]


def test_scenario_wider_than_m_max_raises():
    cfg = rl.RouterConfig(variant="guided", n_instances=2, seed=0)
    agent = rl.make_agent(cfg, m=2)
    scen = Scenario.homogeneous(PROF, 4, _reqs(10, seed=1))
    with pytest.raises(ValueError, match="m_max"):
        batched_rl.evaluate_scenarios(cfg, agent, [scen], m_max=2)


# -- training smoke: shared buffer, heterogeneous stream ---------------------

def test_batched_training_on_hetero_stream_completes():
    cfg = rl.RouterConfig(variant="guided", n_instances=4,
                          explore_episodes=4, q_arch="decomposed", seed=0)
    bcfg = batched_rl.BatchedRLConfig(n_envs=3, m_max=6)
    out = batched_rl.train_batched(
        cfg, scenario_stream(0, n_requests=40), 5, bcfg=bcfg)
    hist = out["history"]
    assert [h["episode"] for h in hist] == list(range(5))
    for h in hist:
        assert h["n"] == 40                  # every request completed
    assert out["agent"].buffer.size > 0      # shared replay buffer fed
    # episodes came from different cluster shapes/patterns
    assert len({(h["m"], h["pattern"]) for h in hist}) > 1


# -- scenario generator invariants -------------------------------------------

def test_make_scenario_deterministic_and_well_formed():
    for seed in (0, 1, 17):
        a = make_scenario(seed)
        b = make_scenario(seed)
        assert a.name == b.name and a.m == b.m
        assert [r.prompt_tokens for r in a.requests] == \
            [r.prompt_tokens for r in b.requests]
        assert [r.arrival for r in a.requests] == \
            [r.arrival for r in b.requests]
        assert 2 <= a.m <= 6
        assert all(p in PROFILE_POOL for p in a.profiles)
        assert a.pattern in ARRIVAL_PATTERNS
        arr = [r.arrival for r in a.requests]
        assert all(t >= 0 for t in arr)
        assert arr == sorted(arr)
        # every request fits the smallest KV pool in the cluster
        cap = min(p.capacity_tokens for p in a.profiles)
        for r in a.requests:
            assert r.prompt_tokens + r.decode_tokens <= cap
            assert r.decode_tokens >= 1


def test_scenario_stream_varies_shape_and_hardware():
    fn = scenario_stream(0)
    scens = [fn(ep) for ep in range(12)]
    assert len({s.m for s in scens}) > 1
    assert len({s.pattern for s in scens}) > 1
    assert any(len(set(s.profiles)) > 1 for s in scens)   # mixed hardware


def test_bursty_arrivals_are_burstier_than_poisson():
    """Coefficient of variation of inter-arrival gaps: Poisson ~= 1,
    the MMPP bursty pattern substantially above."""
    def cv(pattern):
        out = []
        for seed in range(4):
            t = arrival_times(800, 20.0, pattern, seed=seed)
            gaps = np.diff(np.concatenate([[0.0], t]))
            out.append(np.std(gaps) / np.mean(gaps))
        return float(np.mean(out))
    assert cv("bursty") > 1.4 * cv("poisson")


def test_diurnal_arrivals_follow_sinusoid():
    """Mean rate over the positive half-period of the sinusoid must
    exceed the negative half-period's."""
    t = arrival_times(4000, 20.0, "diurnal", seed=0, period=240.0,
                      depth=0.8)
    phase = (t % 240.0) / 240.0
    peak = np.sum(phase < 0.5)           # sin > 0 half
    trough = np.sum(phase >= 0.5)
    assert peak > 1.3 * trough


def test_arrival_times_mean_rate_close_to_nominal():
    for pattern in ARRIVAL_PATTERNS:
        t = arrival_times(3000, 25.0, pattern, seed=2)
        rate = 3000 / t[-1]
        assert 0.6 * 25.0 < rate < 1.6 * 25.0, pattern


# -- heterogeneous cluster plumbing ------------------------------------------

def test_cluster_accepts_per_instance_profiles():
    profs = (V100_LLAMA2_7B, A100_LLAMA31_8B)
    c = Cluster(profs, 2)
    assert c.instances[0].profile is V100_LLAMA2_7B
    assert c.instances[1].profile is A100_LLAMA31_8B
    with pytest.raises(ValueError):
        Cluster(profs, 3)


def test_backlog_accounting_survives_elastic_add():
    """Instances added mid-episode must inherit the env's observer hooks,
    or the incremental backlog penalty drifts (decode events on the new
    instance would never credit _T while finishes still debit it)."""
    cfg = rl.RouterConfig(variant="guided", n_instances=2, seed=0)
    env = rl.RoutingEnv(cfg, PROF)
    env.reset(_reqs(40, seed=9))
    done, added = False, False
    for _ in range(5000):
        if not done:
            a = int(np.argmax(env.guidance_bonus()[:env.cluster.m])) \
                if env.cluster.central else env.cluster.m
            _, _, done, _ = env.step(a)
        if not added and env.cluster.t > 1.0:
            env.cluster.add_instance()
            added = True
        if done:
            break
    assert done and added
    # all requests finished -> exact accounting returns to zero
    assert env._backlog_penalty() == pytest.approx(0.0, abs=1e-9)


def test_incremental_token_sums_match_rescan():
    """The O(1) resident/queue token sums must equal a full recount at
    every tick (guards the incremental bookkeeping in _iteration)."""
    inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
    for r in _reqs(40, seed=3, rate=200.0):
        inst.submit(r)
    for _ in range(3000):
        inst.run_until(inst.clock + 0.02)
        assert inst.resident_token_sum() == pytest.approx(
            sum(r.total_context for r in inst.residents))
        assert inst.queued_prompt_sum() == pytest.approx(
            sum(r.prompt_tokens for r in inst.queue))
        assert all(r.decoded == 0 and r.prefilled == 0
                   for r in inst.queue)
        if len(inst.completed) == 40:
            break
    assert len(inst.completed) == 40
