"""Pallas kernel validation: shape/dtype sweeps, assert_allclose against the
pure-jnp oracles (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as da
from repro.kernels.flash_attention import ops as fa
from repro.kernels.mamba_scan import ops as ms


@pytest.mark.parametrize("shape", [
    # (B, S, H, KV, hd, bq, bk)
    (1, 128, 4, 4, 32, 64, 64),
    (2, 256, 8, 2, 64, 128, 64),
    (1, 512, 4, 1, 128, 128, 128),     # MQA
    (2, 128, 6, 2, 64, 128, 128),      # blocks > S get clamped
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(shape, dtype):
    b, s, h, kv, hd, bq, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    out = fa.flash_attention(q, k, v, block_q=bq, block_k=bk,
                             interpret=True)
    ref = fa.reference(q, k, v)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    # (B, S, H, KV, hd, bk)
    (2, 256, 8, 2, 64, 64),
    (1, 512, 4, 4, 32, 128),
    (3, 128, 16, 2, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(shape, dtype):
    b, s, h, kv, hd, bk = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, hd), dtype)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, s + 1, b),
                       jnp.int32)
    out = da.decode_attention(q, k, v, lens, block_k=bk, interpret=True)
    ref = da.reference(q, k, v, lens)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("shape", [
    # (B, S, di, ds, chunk, bc)
    (1, 64, 128, 8, 16, 64),
    (2, 32, 256, 16, 32, 128),
    (1, 128, 128, 4, 64, 128),
])
def test_mamba_scan(shape):
    b, s, di, ds, chunk, bc = shape
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    xc = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di))) * 0.1
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    al = jnp.log(jnp.abs(jax.random.normal(ks[4], (di, ds))) + 0.5)
    d = jnp.ones((di,))
    y, hf = ms.mamba_scan(xc, dt, bm, cm, al, d, chunk=chunk, block_c=bc,
                          interpret=True)
    yr, hr = ms.reference(xc, dt, bm, cm, al, d,
                          jnp.zeros((b, di, ds)))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)


def test_mamba_scan_matches_model_path():
    """The kernel agrees with the model's chunked associative scan."""
    from repro.models.mamba import selective_scan
    b, s, di, ds = 2, 64, 128, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xc = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di))) * 0.1
    bm = jax.random.normal(ks[2], (b, s, ds))
    cm = jax.random.normal(ks[3], (b, s, ds))
    al = jnp.log(jnp.abs(jax.random.normal(ks[4], (di, ds))) + 0.5)
    d = jnp.ones((di,))
    h0 = jnp.zeros((b, di, ds))
    y1, h1 = ms.mamba_scan(xc, dt, bm, cm, al, d, chunk=16, block_c=64,
                           interpret=True)
    y2, h2 = selective_scan(xc, dt, bm, cm, al, d, h0, chunk=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


def test_decode_attention_int8_cache():
    """int8-quantized KV cache: in-kernel dequant matches the dequantized
    oracle tightly and the exact oracle within quantization noise."""
    from repro.kernels.decode_attention.kernel import decode_attention_kernel
    from repro.models.attention import dequantize_kv, quantize_kv
    b, s, h, kv, hd = 2, 256, 8, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, 1, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    lens = jnp.array([100, 256])
    kq, kscale = quantize_kv(k)
    vq, vscale = quantize_kv(v)
    out = decode_attention_kernel(q.astype(jnp.bfloat16), kq, vq, lens,
                                  block_k=64, k_scale=kscale,
                                  v_scale=vscale, interpret=True)
    ref = da.reference(q, dequantize_kv(kq, kscale).astype(jnp.float32),
                       dequantize_kv(vq, vscale).astype(jnp.float32), lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)
    exact = da.reference(q, k, v, lens)
    assert float(jnp.abs(out.astype(jnp.float32) - exact).max()) < 0.05


def test_int8_kv_cache_decode_matches_bf16():
    """Model-level int8 cache path stays within 5% relative logit error."""
    import dataclasses
    from repro.configs import REGISTRY
    from repro.models import model as M
    from repro.models import params as P
    cfg = dataclasses.replace(REGISTRY["gemma-7b"].reduced(),
                              kv_cache_dtype="int8")
    pr = P.init_params(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    full, _ = M.forward_train(pr, cfg, tokens=toks)
    last, cache = M.prefill(pr, cfg, tokens=toks[:, :12], cache_len=16)
    errs = [float(jnp.abs(last - full[:, 11]).max())]
    for t in range(4):
        lg, cache = M.decode_step(pr, cfg, cache, tokens=toks[:, 12 + t])
        errs.append(float(jnp.abs(lg - full[:, 12 + t]).max()))
    rel = max(errs) / float(jnp.abs(full).max())
    assert rel < 0.05
