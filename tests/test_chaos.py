"""Chaos subsystem + gateway failover: seeded fault schedules, py-vs-vec
bit parity under crashes/stragglers, recovery, bounded-retry failover,
circuit breaker, hedged re-dispatch, and the engine TTFT anchor."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core import state as state_lib
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster
from repro.core.workload import generate, to_requests
from repro.serving.chaos import (ChaosInjector, Crash, FaultSchedule,
                                 HealthTracker, Straggler, TenantBurst,
                                 inject_bursts)
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.policies import (LeastOutstandingWork,
                                    MixingImpactPolicy,
                                    RoundRobinPolicy, healthy_candidates)
from repro.serving.request import Phase, Request
from repro.serving import trace as tr

PROF = V100_LLAMA2_7B

TERMINAL = (Phase.DONE, Phase.SHED, Phase.CANCELLED)


def _reqs(n, seed=0, rate=20.0):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


def _drive(cluster, reqs, schedule=None, t_max=3000.0):
    """Round-robin-over-alive driving loop with per-tick chaos
    injection (the simulator-level harness; the gateway has its own)."""
    injector = ChaosInjector(schedule) if schedule is not None else None
    pending = sorted(reqs, key=lambda r: r.arrival)
    i, rr = 0, 0
    while len(cluster.completed) < len(reqs) and cluster.t < t_max:
        if injector is not None:
            injector.step(cluster, cluster.t)
        while i < len(pending) and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            i += 1
        alive = cluster.alive()
        while cluster.central and alive:
            cluster.route(alive[rr % len(alive)])
            rr += 1
        cluster.advance()
    return injector


def _assert_parity(ra, rb):
    for a, b in zip(ra, rb):
        assert a.finished == b.finished, (a.rid, a.finished, b.finished)
        assert a.first_token == b.first_token, a.rid
        assert a.prefill_done == b.prefill_done
        assert a.instance == b.instance
        assert a.decoded == b.decoded and a.prefilled == b.prefilled
        assert a.phase is b.phase
        assert a.retries == b.retries and a.hedges == b.hedges


# -- fault schedules ---------------------------------------------------------

def test_fault_schedule_seed_deterministic():
    a = FaultSchedule.random(seed=11, m=4, horizon=30.0, n_crashes=2,
                             n_stragglers=2, n_bursts=1)
    b = FaultSchedule.random(seed=11, m=4, horizon=30.0, n_crashes=2,
                             n_stragglers=2, n_bursts=1)
    c = FaultSchedule.random(seed=12, m=4, horizon=30.0, n_crashes=2,
                             n_stragglers=2, n_bursts=1)
    assert a == b
    assert a != c
    assert a.events() == b.events()
    # faults land early enough to observe their fallout
    assert all(ev[0] <= 30.0 for ev in a.events()
               if ev[1] != "recover")


def test_fault_schedule_event_order():
    s = FaultSchedule(
        crashes=(Crash(5.0, 1, restart_after=3.0),),
        stragglers=(Straggler(5.0, 8.0, 0, factor=2.0),))
    ev = s.events()
    assert [e[1] for e in ev] == ["fail", "slow", "recover", "slow"]
    assert ev[2] == (8.0, "recover", 1, 0.0)
    assert ev[3] == (8.0, "slow", 0, 1.0)      # window closes to 1.0


def test_inject_bursts_clones_tenant_shapes():
    base = [Request(prompt_tokens=100, decode_tokens=20, arrival=0.5,
                    tenant="a", rid=0),
            Request(prompt_tokens=30, decode_tokens=7, arrival=1.0,
                    tenant="b", rid=1)]
    sched = FaultSchedule(bursts=(TenantBurst(0.0, 10.0, "b",
                                              rate=2.0),))
    out1 = inject_bursts(base, sched, seed=3)
    out2 = inject_bursts(base, sched, seed=3)
    extra = out1[2:]
    assert len(out1) > 2
    assert [r.arrival for r in out1] == [r.arrival for r in out2]
    assert all(r.tenant == "b" for r in extra)
    assert all(r.prompt_tokens == 30 and r.decode_tokens == 7
               for r in extra)           # donor shapes, fresh rids
    assert len({r.rid for r in out1}) == len(out1)
    assert all(0.0 < r.arrival < 10.0 for r in extra)


# -- straggler / recovery parity ---------------------------------------------

def test_speed_factor_parity_py_vec():
    sched = FaultSchedule(stragglers=(Straggler(1.0, 6.0, 0,
                                                factor=3.5),))
    ra, rb = _reqs(90, seed=5), _reqs(90, seed=5)
    ca = Cluster(PROF, 3)
    cb = Cluster(PROF, 3, backend="vec")
    _drive(ca, ra, sched)
    _drive(cb, rb, sched)
    cb.sync_all()
    _assert_parity(ra, rb)
    assert all(r.phase is Phase.DONE for r in ra)


def test_straggler_slows_instance():
    def run(factor):
        reqs = _reqs(60, seed=2)
        sched = FaultSchedule(stragglers=(
            Straggler(0.0, 1e9, 0, factor=factor),))
        c = Cluster(PROF, 1)
        _drive(c, reqs, sched)
        return max(r.finished for r in reqs)
    assert run(4.0) > 2.0 * run(1.0)


def test_crash_restart_parity_py_vec():
    sched = FaultSchedule(crashes=(Crash(2.0, 1, restart_after=4.0),),
                          stragglers=(Straggler(3.0, 7.0, 0,
                                                factor=2.0),))
    ra, rb = _reqs(90, seed=7), _reqs(90, seed=7)
    ca = Cluster(PROF, 3)
    cb = Cluster(PROF, 3, backend="vec")
    _drive(ca, ra, sched)
    _drive(cb, rb, sched)
    cb.sync_all()
    _assert_parity(ra, rb)
    assert all(r.phase is Phase.DONE for r in ra)


def test_recover_surfaces_through_cluster_and_trace():
    for backend in ("py", "vec"):
        rec = tr.TraceRecorder()
        cluster = Cluster(PROF, 2, backend=backend)
        cluster.set_trace(rec)
        reqs = _reqs(30, seed=4)
        sched = FaultSchedule(crashes=(Crash(1.0, 0,
                                             restart_after=2.0),))
        inj = _drive(cluster, reqs, sched)
        assert [(k, i) for _, k, i, _ in inj.log] == [("fail", 0),
                                                      ("recover", 0)]
        assert 0 in cluster.alive()
        kinds = [e[1] for e in rec.events()]
        assert tr.EV_FAIL in kinds and tr.EV_RECOVER in kinds
        # the recovered instance serves fresh traffic again
        extra = Request(prompt_tokens=16, decode_tokens=4,
                        arrival=cluster.t, rid=99_000)
        cluster.enqueue(extra)
        cluster.route(0)
        while extra.finished is None and cluster.t < 1000.0:
            cluster.advance()
        if backend == "vec":
            cluster.sync_all()
        assert extra.finished is not None and extra.instance == 0, backend


def test_injector_skips_dead_and_out_of_range():
    sched = FaultSchedule(crashes=(Crash(1.0, 0), Crash(2.0, 0),
                                   Crash(2.0, 9)))
    cluster = Cluster(PROF, 2)
    inj = ChaosInjector(sched)
    inj.step(cluster, 5.0)
    assert [(k, i) for _, k, i, _ in inj.log] == [("fail", 0)]
    assert inj.pending == 0


# -- S1: crash requeue restarts the latency clock ----------------------------

def test_fail_requeue_clears_timing_stamps():
    """A crash orphan's TTFT must measure the attempt that actually
    serves it -- the dead instance's stamps are cleared on requeue."""
    for backend in ("py", "vec"):
        cluster = Cluster(PROF, 2, backend=backend)
        req = Request(prompt_tokens=50, decode_tokens=200, arrival=0.0)
        cluster.enqueue(req)
        cluster.route(0)
        while cluster.t < 1.0:          # serve long enough to emit
            cluster.advance()
        if backend == "vec":
            cluster.sync_all()
        assert req.first_token is not None
        t_fail = cluster.t
        cluster.fail_instance(0)
        if backend == "vec":
            cluster.sync_all()
        assert req.first_token is None, backend
        assert req.prefill_done is None and req.token_times == []
        cluster.route(1)
        while req.finished is None and cluster.t < 100.0:
            cluster.advance()
            if backend == "vec":
                cluster.sync_all()
        assert req.finished is not None
        # the pinned metric: TTFT anchored to the SECOND attempt
        assert req.first_token > t_fail, backend
        assert req.ttft == req.first_token - req.arrival


# -- health tracking / circuit breaker ---------------------------------------

def _fake_completion(tbt, decoded=11, t0=0.0):
    r = Request(prompt_tokens=10, decode_tokens=decoded, arrival=t0)
    r.decoded = decoded
    r.first_token = t0 + 0.1
    r.finished = r.first_token + tbt * (decoded - 1)
    return r


def test_health_tracker_trips_breaker_and_reprobes():
    h = HealthTracker(3, min_samples=4, breaker_factor=2.0,
                      cooldown_s=10.0)
    for _ in range(6):
        h.on_complete(0, _fake_completion(0.1))
        h.on_complete(1, _fake_completion(0.1))
        h.on_complete(2, _fake_completion(0.5))   # 5x the median
    mask, scores = h.assess(t=1.0, alive=[0, 1, 2])
    assert list(mask) == [True, True, False]
    assert h.trips == 1
    assert scores[2] >= 1.0 > scores[0]
    # open for cooldown_s, then fresh samples decide again
    mask, _ = h.assess(t=5.0, alive=[0, 1, 2])
    assert not mask[2]
    mask, _ = h.assess(t=12.0, alive=[0, 1, 2])
    assert mask[2]                    # re-probed with forgotten history


def test_health_tracker_guarded_fallback_keeps_fleet():
    h = HealthTracker(2, min_samples=2, breaker_factor=1.5,
                      bad_weight=10.0)
    h.on_bad(0)
    h.on_bad(1)
    mask, _ = h.assess(t=0.0, alive=[0, 1])
    # both would trip; the guard refuses to empty the candidate set
    assert mask[0] and mask[1]


def test_health_tracker_ignores_short_completions():
    h = HealthTracker(1)
    h.on_complete(0, _fake_completion(0.1, decoded=1))
    assert h.n[0] == 0                # no TBT from a 1-token reply


def test_healthy_candidates_filter_and_fallback():
    cluster = Cluster(PROF, 3)
    assert healthy_candidates(cluster) == [0, 1, 2]
    cluster.health_mask = np.array([True, False, True])
    assert healthy_candidates(cluster) == [0, 2]
    rr = RoundRobinPolicy()
    req = Request(prompt_tokens=10, decode_tokens=5)
    picks = {rr.route(cluster, req, 5) for _ in range(6)}
    assert picks == {0, 2}
    jsq = LeastOutstandingWork()
    assert jsq.route(cluster, req, 5) in (0, 2)
    cluster.health_mask = np.array([False, False, False])
    assert healthy_candidates(cluster) == [0, 1, 2]   # fallback


def test_action_mask_respects_health_mask():
    for backend in ("py", "vec"):
        cluster = Cluster(PROF, 3, backend=backend)
        cluster.enqueue(Request(prompt_tokens=10, decode_tokens=5))
        cluster.health_mask = np.array([True, False, True])
        mask = state_lib.action_mask(cluster)
        assert list(mask) == [True, False, True, True], backend


def test_mixing_scores_penalize_breakered_instance():
    from repro.core import rl_router as rl
    cluster = Cluster(PROF, 3)
    req = Request(prompt_tokens=64, decode_tokens=32)
    base = rl.mixing_scores(cluster, req, 32)
    cluster.health_mask = np.array([True, False, True])
    pen = rl.mixing_scores(cluster, req, 32)
    assert pen[1] == base[1] - rl.HEALTH_PENALTY
    assert pen[0] == base[0] and pen[2] == base[2]
    assert np.isfinite(pen[1])        # penalized, not removed


def test_health_features_bit_exact_py_vec():
    sched = FaultSchedule(stragglers=(Straggler(0.0, 1e9, 1,
                                                factor=2.5),))
    ra, rb = _reqs(40, seed=6), _reqs(40, seed=6)
    ca = Cluster(PROF, 3)
    cb = Cluster(PROF, 3, backend="vec")
    _drive(ca, ra, sched, t_max=2.0)
    _drive(cb, rb, sched, t_max=2.0)
    scores = np.array([0.0, 0.4, 0.0])
    ca.health_scores = scores
    cb.health_scores = scores
    ca.enqueue(Request(prompt_tokens=10, decode_tokens=5, rid=10_000))
    cb.enqueue(Request(prompt_tokens=10, decode_tokens=5, rid=10_000))
    fa = state_lib.featurize(ca, PROF, include_health=True)
    fb = state_lib.featurize(cb, PROF, include_health=True)
    assert fa.shape == fb.shape
    assert fa.shape[0] == state_lib.state_dim(3, include_health=True)
    assert np.array_equal(fa, fb)
    dims = state_lib.instance_dims(include_health=True)
    assert fa[dims * 1 + dims - 2] == np.float32(0.4)      # score
    assert fa[dims * 1 + dims - 1] == np.float32(1 - 1 / 2.5)


# -- gateway failover --------------------------------------------------------

def _gateway_run(backend, sched, failover, n=100, m=3, seed=9,
                 **cfg_kw):
    reqs = _reqs(n, seed=seed)
    cfg = GatewayConfig(backend=backend, chaos=sched, failover=failover,
                        max_time=2000.0, **cfg_kw)
    gw = Gateway(cfg, (PROF,) * m, MixingImpactPolicy())
    stats = gw.run(reqs)
    return reqs, stats, gw


def test_gateway_failover_conservation():
    """Every admitted request terminates exactly once -- none lost,
    none duplicated -- through crash + restart with bounded retries."""
    sched = FaultSchedule(crashes=(Crash(2.0, 0, restart_after=5.0),
                                   Crash(4.0, 1, restart_after=4.0)))
    reqs, stats, gw = _gateway_run("py", sched, failover=True)
    assert stats["orphaned"] > 0 and stats["retried"] > 0
    assert all(r.phase in TERMINAL for r in reqs)
    done = [r for r in reqs if r.phase is Phase.DONE]
    assert len({r.rid for r in done}) == len(done)
    assert len(done) + stats["shed"] + stats["cancelled"] == len(reqs)
    assert len(gw.cluster.completed) == len(done)


def test_gateway_retry_budget_exhaustion_sheds():
    # a repeatedly-crashing fleet (always restarting, so the run
    # drains): the retry budget must bound per-request work
    sched = FaultSchedule(crashes=tuple(
        Crash(0.5 + 0.5 * k, k % 2, restart_after=0.4)
        for k in range(10)))
    reqs, stats, _ = _gateway_run("py", sched, failover=True,
                                  n=40, m=2, max_retries=1,
                                  retry_backoff_s=0.05)
    assert all(r.phase in TERMINAL for r in reqs)
    shed_by_retry = [r for r in reqs
                     if r.phase is Phase.SHED and r.retries > 0]
    assert shed_by_retry, "budget exhaustion never triggered"
    assert all(r.retries == 2 for r in shed_by_retry)   # budget + 1
    assert max((r.retries for r in reqs), default=0) <= 2
    assert stats["shed"] >= len(shed_by_retry)


def test_gateway_retry_backoff_is_exponential():
    gw = Gateway(GatewayConfig(failover=True, retry_backoff_s=0.25),
                 (PROF,) * 2, MixingImpactPolicy())
    req = Request(prompt_tokens=10, decode_tokens=5)
    gw._on_orphans([req])
    gw._on_orphans([heapq_pop(gw)])
    assert req.retries == 2
    # second backoff doubles (both enqueued at t=0)
    assert gw._retry_q[0][0] == pytest.approx(0.5)


def heapq_pop(gw):
    import heapq
    return heapq.heappop(gw._retry_q)[2]


def test_gateway_chaos_parity_py_vec():
    sched = FaultSchedule(crashes=(Crash(2.0, 0, restart_after=6.0),),
                          stragglers=(Straggler(1.0, 8.0, 2,
                                                factor=3.0),))
    ra, sa, _ = _gateway_run("py", sched, failover=True,
                             hedge_after_s=3.0)
    rb, sb, _ = _gateway_run("vec", sched, failover=True,
                             hedge_after_s=3.0)
    _assert_parity(ra, rb)
    assert sa["orphaned"] == sb["orphaned"]
    assert sa["hedged"] == sb["hedged"]
    assert sa["retried"] == sb["retried"]
    assert sa.get("breaker_trips") == sb.get("breaker_trips")


def test_gateway_failover_beats_requeue_on_p95():
    sched = FaultSchedule(crashes=(Crash(2.0, 0, restart_after=8.0),),
                          stragglers=(Straggler(1.0, 10.0, 1,
                                                factor=4.0),))
    def p95(failover):
        reqs, _, _ = _gateway_run("py", sched, failover=failover,
                                  n=120, seed=3,
                                  hedge_after_s=(3.0 if failover
                                                 else None))
        e2e = sorted(r.e2e for r in reqs if r.finished is not None)
        return e2e[int(0.95 * (len(e2e) - 1))]
    assert p95(True) < p95(False)


def test_gateway_hedging_rescues_stuck_requests():
    # one instance serves at 1/50 speed from t=0; hedging must move
    # its stuck requests elsewhere
    sched = FaultSchedule(stragglers=(Straggler(0.0, 1e9, 0,
                                                factor=50.0),))
    reqs, stats, gw = _gateway_run("py", sched, failover=True, n=60,
                                   hedge_after_s=2.0, seed=12)
    assert stats["hedged"] > 0
    assert all(r.phase in TERMINAL for r in reqs)
    hedged = [r for r in reqs if r.hedges > 0]
    assert hedged and all(r.finished is not None for r in hedged)
    assert gw.health.bad[0] > 0       # hedges attributed to the slow node


def test_gateway_trace_parity_and_new_events():
    sched = FaultSchedule(crashes=(Crash(2.0, 0, restart_after=5.0),),
                          stragglers=(Straggler(0.0, 1e9, 1,
                                                factor=40.0),))
    def run(backend):
        rec = tr.TraceRecorder()
        reqs = _reqs(60, seed=9)
        gw = Gateway(GatewayConfig(backend=backend, chaos=sched,
                                   failover=True, hedge_after_s=2.0,
                                   max_time=2000.0),
                     (PROF,) * 3, MixingImpactPolicy(), trace=rec)
        gw.run(reqs)
        # rids are process-global; renumber by first appearance so two
        # runs in one process compare equal
        remap = {}
        out = []
        for ev in rec.events():
            t, kind, rid, rest = ev[0], ev[1], ev[2], ev[3:]
            out.append((t, kind, remap.setdefault(rid, len(remap)),
                        *rest))
        return out
    ea, eb = run("py"), run("vec")
    assert ea == eb
    kinds = {e[1] for e in ea}
    assert {tr.EV_FAIL, tr.EV_RECOVER, tr.EV_RETRY,
            tr.EV_HEDGE} <= kinds


def test_gateway_chaos_trace_validates():
    from repro.serving import obs
    rec = tr.TraceRecorder()
    sched = FaultSchedule.random(seed=5, m=3, horizon=10.0,
                                 n_crashes=1, n_stragglers=1)
    reqs = _reqs(50, seed=5)
    gw = Gateway(GatewayConfig(chaos=sched, failover=True,
                               hedge_after_s=3.0, max_time=2000.0),
                 (PROF,) * 3, MixingImpactPolicy(), trace=rec)
    gw.run(reqs)
    doc = obs.chrome_trace(rec)
    assert obs.validate_chrome_trace(doc) == []


def test_gateway_metrics_count_chaos_events():
    sched = FaultSchedule(crashes=(Crash(2.0, 0, restart_after=5.0),))
    reqs, stats, gw = _gateway_run("py", sched, failover=True)
    snap = stats["snapshot"]
    assert snap["orphaned"] == stats["orphaned"]
    assert snap["retried"] == stats["retried"]
    assert sum(t["orphaned"] for t in snap["tenants"].values()) \
        == stats["orphaned"]


# -- property: termination exactly once under random fault schedules --------

@given(seed=st.integers(0, 60))
@settings(max_examples=8, deadline=None)
def test_chaos_termination_and_parity_property(seed):
    """Any seeded crash+straggler schedule: every admitted request
    reaches exactly one terminal phase on BOTH backends, and the two
    backends agree bit-for-bit."""
    sched = FaultSchedule.random(seed=seed, m=3, horizon=8.0,
                                 n_crashes=2, n_stragglers=1)
    ra, sa, _ = _gateway_run("py", sched, failover=True, n=60,
                             seed=seed, max_retries=2,
                             hedge_after_s=4.0)
    rb, sb, _ = _gateway_run("vec", sched, failover=True, n=60,
                             seed=seed, max_retries=2,
                             hedge_after_s=4.0)
    for reqs in (ra, rb):
        assert all(r.phase in TERMINAL for r in reqs)
        done = [r for r in reqs if r.phase is Phase.DONE]
        assert len({r.rid for r in done}) == len(done)
        assert all(r.finished is None for r in reqs
                   if r.phase is not Phase.DONE)
    _assert_parity(ra, rb)
    assert sa["shed"] == sb["shed"]
    assert sa["cancelled"] == sb["cancelled"]


# -- S6: engine TTFT anchor --------------------------------------------------

def test_engine_ttft_anchor_matches_simulator():
    """The engine stamps first-token at the iteration's END (clock
    advanced before the decode pass) -- the same anchor the simulator
    uses, so fidelity deltas compare like-for-like."""
    import jax
    from repro.configs import get_config
    from repro.models import params as params_lib
    from repro.serving.engine import LLMInstance
    from repro.serving.scheduler import FCFS
    from repro.core.simulator import SimInstance
    from repro.serving.scheduler import get_scheduler

    cfg = get_config("llama-2-7b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    eng = LLMInstance(cfg, params, PROF, FCFS(), n_slots=2,
                      cache_len=64)
    sim = SimInstance(PROF, get_scheduler("fcfs"), 0)
    re_ = Request(prompt_tokens=20, decode_tokens=6)
    rs = Request(prompt_tokens=20, decode_tokens=6)
    eng.submit(re_)
    sim.submit(rs)
    for _ in range(40):
        eng.step()
        if re_.finished is not None:
            break
    sim.run_until(60.0)
    assert rs.finished is not None and re_.finished is not None
    assert re_.first_token == pytest.approx(rs.first_token, rel=1e-9)
    assert re_.finished == pytest.approx(rs.finished, rel=1e-9)


def test_engine_speed_factor_scales_clock():
    import jax
    from repro.configs import get_config
    from repro.models import params as params_lib
    from repro.serving.engine import LLMInstance
    from repro.serving.scheduler import FCFS

    cfg = get_config("llama-2-7b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)

    def serve(speed):
        eng = LLMInstance(cfg, params, PROF, FCFS(), n_slots=2,
                          cache_len=64)
        eng.speed_factor = speed
        r = Request(prompt_tokens=20, decode_tokens=6)
        eng.submit(r)
        for _ in range(40):
            eng.step()
            if r.finished is not None:
                break
        return r.finished
    assert serve(3.0) == pytest.approx(3.0 * serve(1.0), rel=1e-9)
