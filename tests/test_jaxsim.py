"""Device-resident jitted backend (core.jaxsim): decision/clock/TTFT
bit-parity with the py/vec steppers across schedulers, chunked prefill
and failure lanes; the shared pool under the batched trainer; the
on-device featurize twin; and the packed replay-row path."""
from types import SimpleNamespace

import numpy as np
import pytest
from _hypothesis_support import given, settings, st
from test_vecsim import _assert_request_parity, _reqs

from repro.core import batched_rl, rl_router as rl
from repro.core import state as state_lib
from repro.core.dqn import DQNConfig, ReplayBuffer
from repro.core.jaxsim import JaxSimPool
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.vecsim import VecCluster
from repro.core.workload import Scenario

PROF = V100_LLAMA2_7B


def _jax_cluster(m, **kw):
    # min_span_ticks=0 sends EVERY span through the jitted kernel (the
    # hybrid default keeps short spans on the numpy path for speed)
    return VecCluster(PROF, m, pool=JaxSimPool(1, min_span_ticks=0),
                      **kw)


# -- seeded heuristic parity: jax kernel vs python stepper -------------------

@pytest.mark.parametrize("chunk,sched", [
    (0, "fcfs"),
    (64, "fcfs"),
    (0, "bin_packing"),
    (128, "least_work_left"),
])
def test_jax_heuristic_parity(chunk, sched):
    ra, rb = _reqs(100, seed=5), _reqs(100, seed=5)
    ca = Cluster(PROF, 3, scheduler=sched, chunked_prefill=chunk)
    cb = _jax_cluster(3, scheduler=sched, chunked_prefill=chunk)
    sa = run_heuristic(ca, ra, make_policy("round_robin", PROF))
    sb = run_heuristic(cb, rb, make_policy("round_robin", PROF))
    _assert_request_parity(ra, rb)
    assert sa["spikes"] == sb["spikes"]
    assert sa["e2e_mean"] == sb["e2e_mean"]
    assert sa["ttft_mean"] == sb["ttft_mean"]
    assert cb.pool.n_jax_calls > 0      # the kernel actually ran


@given(seed=st.integers(0, 30), m=st.integers(1, 4),
       chunk=st.sampled_from([0, 64, 256]), fail=st.booleans())
@settings(max_examples=8, deadline=None)
def test_jax_parity_property(seed, m, chunk, fail):
    """Random widths x chunked-prefill x failure lanes: completions,
    clocks, TTFT and preemption counts must match the reference
    stepper exactly (the py-vs-vec contract, now including jax)."""
    do_fail = fail and m > 1

    def drive(make_cluster):
        rs = _reqs(50, seed=seed)
        cluster = make_cluster()
        pending = sorted(rs, key=lambda r: r.arrival)
        i, rr, failed, restored = 0, 0, False, False
        while len(cluster.completed) < len(rs) and cluster.t < 3000:
            while i < len(pending) and pending[i].arrival <= cluster.t:
                cluster.enqueue(pending[i])
                i += 1
            if do_fail and cluster.t > 1.0 and not failed:
                cluster.fail_instance(0)
                failed = True
            if do_fail and cluster.t > 2.0 and not restored:
                cluster.instances[0].restore()
                cluster.instances[0].clock = cluster.t
                restored = True
            alive = cluster.alive()
            while cluster.central and alive:
                cluster.route(alive[rr % len(alive)])
                rr += 1
                alive = cluster.alive()
            cluster.advance()
        if getattr(cluster, "is_vec", False):
            cluster.sync_all()
        return rs

    a = drive(lambda: Cluster(PROF, m, chunked_prefill=chunk))
    b = drive(lambda: _jax_cluster(m, chunked_prefill=chunk))
    _assert_request_parity(a, b)


# -- batched trainer on the jax pool -----------------------------------------

def test_train_batched_jax_reproduces_python_backend():
    """Same seeds, same scenarios: the jax-pool trainer must make the
    SAME decisions as the Python-stepper trainer (identical ticks and
    completions; rewards match to float summation order)."""
    def scenario(ep):
        return Scenario.homogeneous(PROF, 3, _reqs(40, seed=700 + ep))

    def cfg():
        return rl.RouterConfig(variant="guided", n_instances=3,
                               explore_episodes=2, q_arch="decomposed",
                               seed=0)
    out_py = batched_rl.train_batched(
        cfg(), scenario, 3,
        bcfg=batched_rl.BatchedRLConfig(n_envs=3, m_max=3,
                                        backend="py"))
    out_jax = batched_rl.train_batched(
        cfg(), scenario, 3,
        bcfg=batched_rl.BatchedRLConfig(n_envs=3, m_max=3,
                                        backend="jax"))
    for hp, hj in zip(out_py["history"], out_jax["history"]):
        assert hp["n"] == hj["n"] == 40
        assert hp["ticks"] == hj["ticks"]
        assert hp["preemptions"] == hj["preemptions"]
        assert hp["e2e_mean"] == pytest.approx(hj["e2e_mean"], rel=1e-9)
        assert hp["reward"] == pytest.approx(hj["reward"], rel=1e-6)


# -- on-device featurization -------------------------------------------------

@pytest.mark.parametrize("flags", [
    {},
    {"include_hardware": True},
    {"include_cache": True, "include_health": True},
    {"include_impact": False, "include_hardware": True},
])
def test_featurize_jax_many_bit_parity(flags):
    """The jitted featurize twin must be bit-identical to the numpy
    fast path at every decision point of a seeded episode pair."""
    pool = JaxSimPool(2, min_span_ticks=0)
    cfg = rl.RouterConfig(variant="guided", n_instances=3, seed=0)
    envs = [rl.RoutingEnv(cfg, PROF, pool=pool, pool_ep=i)
            for i in range(2)]
    for i, env in enumerate(envs):
        env.reset(_reqs(30, seed=40 + i))
    for _ in range(25):
        for env in envs:
            a = (int(np.argmax(env.guidance_bonus()[:env.cluster.m]))
                 if env.cluster.central else env.cluster.m)
            env.step(a)
        kw = dict(n_buckets=cfg.n_buckets, alpha=cfg.alpha, **flags)
        vec = state_lib.featurize_vec_many(
            [e.cluster for e in envs], [e.profile for e in envs],
            [e.predict_decode for e in envs], **kw)
        dev = state_lib.featurize_jax_many(
            [e.cluster for e in envs], [e.profile for e in envs],
            [e.predict_decode for e in envs], **kw)
        np.testing.assert_array_equal(dev, vec)


# -- packed replay rows ------------------------------------------------------

def test_packed_replay_rows_bit_identical():
    """ReplayBuffer.add_rows over the jitted packer must leave the
    buffer in EXACTLY the state of per-transition ``add`` calls --
    data, priorities, ring pointer and write sequence -- including
    across a ring wrap and uneven per-round batch sizes."""
    rng = np.random.default_rng(3)
    cfg = DQNConfig(state_dim=6, n_actions=3, buffer_size=32)
    ba, bb = ReplayBuffer(cfg), ReplayBuffer(cfg)
    trans = [(rng.standard_normal(6).astype(np.float32),
              int(rng.integers(0, 3)),
              float(rng.standard_normal()),
              rng.standard_normal(6).astype(np.float32),
              float(rng.integers(0, 2)),
              rng.integers(0, 2, size=3).astype(bool))
             for _ in range(40)]                  # 40 > cap: ring wraps
    for t in trans:
        ba.add(*t)
    stub = SimpleNamespace(cfg=SimpleNamespace(center_rewards=False),
                           buffer=bb)
    i = 0
    for size in (7, 5, 12, 9, 7):                 # uneven round batches
        batched_rl._observe_packed(stub, trans[i:i + size])
        i += size
    np.testing.assert_array_equal(ba.data, bb.data)
    np.testing.assert_array_equal(ba.write_seq, bb.write_seq)
    np.testing.assert_array_equal(ba.prio, bb.prio)
    assert (ba.ptr, ba.size, ba.seq) == (bb.ptr, bb.size, bb.seq)


def test_packed_replay_rows_center_rewards_falls_back():
    """Reward centering is an order-dependent EMA applied at observe
    time; the packed path must defer to sequential ``observe``."""
    calls = []
    stub = SimpleNamespace(cfg=SimpleNamespace(center_rewards=True),
                           buffer=None,
                           observe=lambda *t: calls.append(t))
    trans = [(np.zeros(2, np.float32), 0, 1.0,
              np.zeros(2, np.float32), 0.0, np.ones(2, bool))] * 3
    batched_rl._observe_packed(stub, trans)
    assert len(calls) == 3
