"""Calibration fit machinery, profile JSON round-trip, the sim-vs-engine
fidelity harness, and the calibrated-profile wiring."""
import dataclasses

import numpy as np
import pytest

from repro.core import calibrate as cal
from repro.core.policies import make_policy
from repro.core.profiles import (A100_LLAMA31_8B, V100_LLAMA2_7B,
                                 HardwareProfile, profile_from_json,
                                 profile_to_json)
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import generate, make_scenario, to_requests
from repro.serving import fidelity as fid

GROUND_TRUTH = HardwareProfile(
    name="stub-gpu", grad1=2.4e-4, grad2=1.9e-5, t_decode_base=0.011,
    t_prefill_base=3.0e-4, capacity_tokens=5_000)


def _stub_engine_samples(profile, noise=0.0, seed=0):
    """What the sweep would measure on an engine whose true cost model
    IS ``profile``: prefill t(p) = tpre + grad1*p, decode t(R) = tdec +
    grad2*R, with optional relative timing noise."""
    rng = np.random.default_rng(seed)
    ccfg = cal.CalibrationConfig()

    def jitter():
        return 1.0 + noise * rng.standard_normal()
    pre = [(float(p),
            (profile.t_prefill_base + profile.grad1 * p) * jitter())
           for p in ccfg.prompt_grid]
    dec = [(float(b * c),
            (profile.t_decode_base + profile.grad2 * b * c) * jitter())
           for b, c in ccfg.decode_grid]
    return pre, dec


def test_fit_roundtrip_recovers_ground_truth():
    """Synthetic ground-truth profile -> timed engine stub -> the fit
    must recover grad1/grad2 within tolerance, with the diagnostics the
    CI calibration gate asserts (R^2 >= 0.95, grad1 > grad2 > 0)."""
    pre, dec = _stub_engine_samples(GROUND_TRUTH, noise=0.01)
    res = cal.fit_calibration(pre, dec, base=GROUND_TRUTH, name="refit")
    assert res.profile.grad1 == pytest.approx(GROUND_TRUTH.grad1,
                                              rel=0.05)
    assert res.profile.grad2 == pytest.approx(GROUND_TRUTH.grad2,
                                              rel=0.05)
    assert res.profile.t_decode_base == pytest.approx(
        GROUND_TRUTH.t_decode_base, rel=0.10)
    assert res.prefill_fit.r2 >= 0.95
    assert res.decode_fit.r2 >= 0.95
    assert res.ok
    # thresholds are inherited from the base, not fitted
    assert res.profile.capacity_tokens == GROUND_TRUTH.capacity_tokens


def test_fit_is_exact_on_noiseless_samples():
    pre, dec = _stub_engine_samples(GROUND_TRUTH, noise=0.0)
    res = cal.fit_calibration(pre, dec, base=GROUND_TRUTH)
    assert res.prefill_fit.r2 == pytest.approx(1.0, abs=1e-9)
    assert res.decode_fit.r2 == pytest.approx(1.0, abs=1e-9)
    assert res.prefill_fit.residual_band == pytest.approx(0.0, abs=1e-12)
    assert res.profile.grad1 == pytest.approx(GROUND_TRUTH.grad1,
                                              rel=1e-6)
    assert res.profile.t_prefill_base == pytest.approx(
        GROUND_TRUTH.t_prefill_base, rel=1e-6)


def test_calibration_sanity_flags_inverted_gradients():
    """A 'calibration' where decode interference outprices prefill work
    must be flagged, not silently shipped."""
    inverted = dataclasses.replace(GROUND_TRUTH, grad1=1e-6, grad2=1e-4)
    pre, dec = _stub_engine_samples(inverted)
    res = cal.fit_calibration(pre, dec, base=inverted)
    assert not res.ok


def test_profile_json_roundtrip(tmp_path):
    prof = dataclasses.replace(GROUND_TRUTH, name="artifact")
    assert profile_from_json(profile_to_json(prof)) == prof
    # unknown keys from newer writers are ignored
    d = profile_to_json(prof)
    d["diagnostic_only_field"] = 42
    assert profile_from_json(d) == prof
    # the full CalibrationResult artifact round-trips too
    pre, dec = _stub_engine_samples(prof)
    res = cal.fit_calibration(pre, dec, base=prof, name="artifact")
    path = tmp_path / "profile.json"
    res.save(str(path))
    assert cal.load_profile(str(path)) == res.profile
    import json
    res2 = cal.CalibrationResult.from_json(json.loads(path.read_text()))
    assert res2.profile == res.profile
    assert res2.decode_fit == res.decode_fit


def test_prefill_base_enters_iteration_time_and_vec_parity():
    """t_prefill_base must price prefilling iterations (and only them)
    identically on the scalar profile, the py stepper, and vecsim."""
    prof = dataclasses.replace(V100_LLAMA2_7B, t_prefill_base=0.004)
    assert prof.iteration_time(0, 100) == pytest.approx(
        prof.t_decode_base + prof.grad2 * 100)
    assert prof.iteration_time(50, 100) == pytest.approx(
        prof.t_decode_base + prof.grad1 * 50 + prof.grad2 * 100
        + 0.004)
    ra = to_requests(generate(80, seed=3), rate=20.0, seed=4)
    rb = to_requests(generate(80, seed=3), rate=20.0, seed=4)
    sa = run_heuristic(Cluster(prof, 3), ra,
                       make_policy("round_robin", prof))
    sb = run_heuristic(Cluster(prof, 3, backend="vec"), rb,
                       make_policy("round_robin", prof))
    for a, b in zip(ra, rb):
        assert a.finished == b.finished
        assert a.first_token == b.first_token
    assert sa["e2e_mean"] == sb["e2e_mean"]
    # and the base actually costs time vs the zero-base profile
    rc = to_requests(generate(80, seed=3), rate=20.0, seed=4)
    sc = run_heuristic(Cluster(V100_LLAMA2_7B, 3), rc,
                       make_policy("round_robin", V100_LLAMA2_7B))
    assert sa["e2e_mean"] > sc["e2e_mean"]


def test_make_scenario_profiles_override():
    calibrated = dataclasses.replace(GROUND_TRUTH, name="cal-a")
    mix = (calibrated, V100_LLAMA2_7B, A100_LLAMA31_8B)
    scn = make_scenario(seed=5, profiles=mix, n_requests=50)
    assert scn.profiles == mix
    assert scn.m == 3
    cap = min(p.capacity_tokens for p in mix)
    for r in scn.requests:
        assert r.prompt_tokens + r.decode_tokens <= cap
    # same seed, sampled shape: the override really changed the cluster
    sampled = make_scenario(seed=5, n_requests=50)
    assert sampled.profiles != scn.profiles


# -- fidelity harness --------------------------------------------------------

def test_fidelity_sim_backends_match_bitwise():
    """The harness's vec-vs-py deltas must be exactly zero and the
    report must carry the full percentile/delta shape."""
    fcfg = fid.FidelityConfig(backends=("py", "vec"), n_requests=30)
    rep = fid.run_fidelity(V100_LLAMA2_7B, fcfg)
    assert set(rep["backends"]) == {"py", "vec"}
    assert rep["backends"]["py"]["completed"] == 30
    assert rep["backends"]["vec"] == rep["backends"]["py"]
    d = rep["deltas"]["vec_vs_py"]
    for metric in fid.METRICS:
        assert set(d[metric]) == {"p50", "p95", "p99"}
        for pct in d[metric].values():
            assert pct["abs"] == 0.0
            assert pct["rel"] == 0.0
    # the serving profile is engine-sized
    assert rep["profile"]["capacity_tokens"] <= fcfg.capacity_tokens


def test_fidelity_stream_is_deterministic():
    fcfg = fid.FidelityConfig(n_requests=12)
    assert fid.make_stream(fcfg) == fid.make_stream(fcfg)
    for p, d, _ in fid.make_stream(fcfg):
        assert p in fcfg.prompt_lengths
        assert fcfg.decode_range[0] <= d <= fcfg.decode_range[1]


def test_fidelity_engine_backend_smoke():
    """Real-engine leg on a tiny config: the engine serves the whole
    stream and its percentile deltas against the simulator are finite
    and small on the virtual clock."""
    import jax
    from repro.configs import get_config
    from repro.models import params as params_lib
    model_cfg = get_config("qwen3-0.6b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), model_cfg)
    fcfg = fid.FidelityConfig(
        backends=("py", "engine"), n_requests=12, n_instances=1,
        n_slots=2, cache_len=64, capacity_tokens=200,
        prompt_lengths=(16, 32), decode_range=(4, 12), rate=6.0)
    rep = fid.run_fidelity(V100_LLAMA2_7B, fcfg, model_cfg=model_cfg,
                           params=params)
    assert rep["backends"]["engine"]["completed"] == 12
    rel = rep["deltas"]["engine_vs_py"]["e2e"]["p95"]["rel"]
    assert rel is not None and abs(rel) < 0.5
