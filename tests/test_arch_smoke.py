"""Per-architecture smoke tests: every assigned config instantiates a
REDUCED same-family variant and runs forward/train/prefill/decode on CPU,
asserting shapes and finiteness.  Full configs are exercised only by the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models import model as model_lib
from repro.models import params as params_lib
from repro.training import optimizer as opt_lib
from repro.training.train_loop import make_train_step

ARCHS = sorted(REGISTRY)


def _inputs(cfg, b=2, s=16, seed=1):
    kw = {}
    key = jax.random.PRNGKey(seed)
    if cfg.input_mode == "tokens":
        kw["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    else:
        kw["embeds"] = jax.random.normal(key, (b, s, cfg.d_model))
    if cfg.vision_tokens:
        kw["vision"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                         (b, cfg.vision_tokens,
                                          cfg.vision_dim))
    return kw


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_decode(arch):
    cfg = REGISTRY[arch].reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    kw = _inputs(cfg)
    logits, aux = model_lib.forward_train(params, cfg, **kw)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    last, cache = model_lib.prefill(params, cfg, cache_len=32, **kw)
    assert last.shape == (2, cfg.vocab_size)
    if cfg.input_mode == "tokens":
        lg, cache = model_lib.decode_step(params, cfg, cache,
                                          tokens=jnp.array([1, 2]))
    else:
        lg, cache = model_lib.decode_step(
            params, cfg, cache,
            embeds=jnp.zeros((2, 1, cfg.d_model)))
    assert lg.shape == (2, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    assert int(cache["pos"][0]) == 17


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg = REGISTRY[arch].reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    opt_state = opt_lib.init(params)
    kw = _inputs(cfg)
    batch = dict(kw)
    batch["labels"] = jax.random.randint(jax.random.PRNGKey(9), (2, 16),
                                         0, cfg.vocab_size)
    step = make_train_step(cfg, opt_lib.OptimizerConfig(lr=1e-3))
    params2, opt_state2, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt_state2["step"]) == 1
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "minicpm3-4b",
                                  "falcon-mamba-7b", "jamba-v0.1-52b",
                                  "deepseek-moe-16b",
                                  "llama-3.2-vision-11b",
                                  "musicgen-medium"])
def test_decode_matches_train_forward(arch):
    """Prefill+decode logits must equal the teacher-forced forward."""
    cfg = REGISTRY[arch].reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    b, s = 2, 12
    kw = _inputs(cfg, b, s + 3, seed=3)
    full_logits, _ = model_lib.forward_train(params, cfg, **kw)
    kw_p = dict(kw)
    if cfg.input_mode == "tokens":
        toks = kw["tokens"]
        kw_p["tokens"] = toks[:, :s]
    else:
        emb = kw["embeds"]
        kw_p["embeds"] = emb[:, :s]
    last, cache = model_lib.prefill(params, cfg, cache_len=s + 3, **kw_p)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, s - 1]),
                               rtol=2e-4, atol=2e-4)
    for t in range(3):
        if cfg.input_mode == "tokens":
            lg, cache = model_lib.decode_step(params, cfg, cache,
                                              tokens=toks[:, s + t])
        else:
            lg, cache = model_lib.decode_step(
                params, cfg, cache, embeds=emb[:, s + t:s + t + 1])
        np.testing.assert_allclose(np.asarray(lg),
                                   np.asarray(full_logits[:, s + t]),
                                   rtol=2e-4, atol=2e-4)


def test_param_counts_match_published():
    """Full-config parameter counts are in range of published sizes."""
    expect = {"gemma-7b": 8.5e9, "starcoder2-7b": 7.4e9,
              "minicpm3-4b": 4.1e9, "qwen3-0.6b": 0.6e9,
              "falcon-mamba-7b": 7.3e9, "grok-1-314b": 314e9,
              "deepseek-moe-16b": 16.4e9, "jamba-v0.1-52b": 52e9,
              "llama-2-7b": 6.7e9}
    for arch, n in expect.items():
        got = REGISTRY[arch].count_params()
        assert abs(got - n) / n < 0.12, (arch, got, n)


def test_blocked_attention_matches_reference():
    from repro.models import attention as A
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 2048, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 2048, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 2048, 2, 32))
    from repro.models import ops
    mask = ops.causal_mask(2048, 2048, 0)[None]
    ref = A.gqa_core(q, k, v, mask)
    out = A.gqa_blocked(q, k, v, causal=True, block_q=256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
