"""Tracing & telemetry: recorder semantics, py-vs-vec event-stream
parity, Chrome-trace / metrics-registry export, decision attribution,
and the P2 small-n fallback satellite."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import generate, make_tenant_scenario, to_requests
from repro.serving import obs
from repro.serving import trace as tr
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.metrics import P2Quantile, StreamMetrics, _MetricTrack
from repro.serving.policies import make_gateway_policy

PROF = V100_LLAMA2_7B


def _reqs(n, seed=0, rate=20.0):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


def _normalized(recorder, requests):
    """Event stream with rids rebased to arrival-order indices, so two
    runs over freshly-built copies of the same scenario (whose Request
    rids differ by a global autoincrement offset) compare equal."""
    rid_map = {r.rid: i for i, r in
               enumerate(sorted(requests, key=lambda r: r.rid))}
    out = []
    for t, etype, rid, inst, tenant, data in recorder.events():
        out.append((t, etype, rid_map.get(rid, rid), inst, tenant, data))
    return out


# -- recorder semantics ------------------------------------------------------

def test_ring_buffer_capacity_and_dropped():
    rec = tr.TraceRecorder(capacity=8)
    for i in range(20):
        rec.emit(float(i), tr.EV_ARRIVE, i)
    assert len(rec) == 8
    assert rec.dropped == 12
    assert rec.n_emitted == 20
    # oldest dropped first: the retained window is the last 8
    assert [e[2] for e in rec.events()] == list(range(12, 20))


def test_head_sampling_is_deterministic_and_whole_request():
    a = tr.TraceRecorder(sample=0.5, seed=3)
    b = tr.TraceRecorder(sample=0.5, seed=3)
    kept = {rid for rid in range(500) if a.sampled(rid)}
    assert kept == {rid for rid in range(500) if b.sampled(rid)}
    assert 100 < len(kept) < 400          # roughly half
    for rid in range(500):                # every event of a kept rid
        a.emit(0.0, tr.EV_ARRIVE, rid)
        a.emit(1.0, tr.EV_COMPLETE, rid, 0)
    rids = {e[2] for e in a.events()}
    assert rids == kept
    counts = {rid: 0 for rid in kept}
    for e in a.raw_events():
        counts[e[2]] += 1
    assert set(counts.values()) == {2}
    # different seed -> different (deterministic) subset
    c = tr.TraceRecorder(sample=0.5, seed=4)
    assert kept != {rid for rid in range(500) if c.sampled(rid)}


def test_instance_fail_event_bypasses_sampling():
    rec = tr.TraceRecorder(sample=0.0)
    rec.emit(1.0, tr.EV_ARRIVE, 7)
    rec.emit(2.0, tr.EV_FAIL, -1, 3)
    evs = rec.events()
    assert len(evs) == 1 and evs[0][1] == tr.EV_FAIL


def test_canonical_order_is_lifecycle_order_within_a_tick():
    rec = tr.TraceRecorder()
    rec.emit(1.0, tr.EV_COMPLETE, 0, 1)
    rec.emit(1.0, tr.EV_FIRST_TOKEN, 0, 1)
    rec.emit(1.0, tr.EV_PREFILL_DONE, 0, 1)
    rec.emit(0.5, tr.EV_ARRIVE, 1)
    assert [e[1] for e in rec.events()] == [
        tr.EV_ARRIVE, tr.EV_PREFILL_DONE, tr.EV_FIRST_TOKEN,
        tr.EV_COMPLETE]


def test_null_recorder_is_disabled_noop():
    assert not tr.NULL.enabled
    tr.NULL.emit(0.0, tr.EV_ARRIVE, 0)
    tr.NULL.counter(0.0, "queue_depth", 1.0)
    assert len(tr.NULL) == 0


# -- py-vs-vec event-stream parity -------------------------------------------

@pytest.mark.parametrize("m,chunk,sched", [
    (3, 0, "fcfs"),
    (2, 128, "fcfs"),
    (3, 0, "bin_packing"),
])
def test_sim_event_parity_py_vs_vec(m, chunk, sched):
    streams = []
    for backend in ("py", "vec"):
        rs = _reqs(120, seed=3)
        rec = tr.TraceRecorder()
        cluster = Cluster(PROF, m, scheduler=sched,
                          chunked_prefill=chunk, backend=backend,
                          trace=rec)
        run_heuristic(cluster, rs, make_policy("round_robin", PROF))
        streams.append(_normalized(rec, rs))
    assert streams[0], "py backend recorded no events"
    assert streams[0] == streams[1]


def test_sim_event_parity_with_instance_failure():
    streams = []
    for backend in ("py", "vec"):
        rs = _reqs(80, seed=11)
        rec = tr.TraceRecorder()
        cluster = Cluster(PROF, 3, backend=backend, trace=rec)
        pending = sorted(rs, key=lambda r: r.arrival)
        i, rr, failed = 0, 0, False
        while len(cluster.completed) < len(rs) and cluster.t < 3000:
            while i < len(pending) and pending[i].arrival <= cluster.t:
                cluster.enqueue(pending[i])
                i += 1
            if cluster.t > 1.0 and not failed:
                cluster.fail_instance(0)
                failed = True
            alive = cluster.alive()
            while cluster.central and alive:
                cluster.route(alive[rr % len(alive)])
                rr += 1
                alive = cluster.alive()
            cluster.advance()
        assert len(cluster.completed) == len(rs)
        streams.append(_normalized(rec, rs))
    fails = [e for e in streams[0] if e[1] == tr.EV_FAIL]
    assert len(fails) == 1 and fails[0][3] == 0
    assert streams[0] == streams[1]


def test_gateway_event_parity_py_vs_vec():
    streams = []
    for backend in ("py", "vec"):
        scn = make_tenant_scenario(seed=9, n_requests=100, rate=8.0,
                                   profiles=(PROF,) * 3)
        rec = tr.TraceRecorder()
        gw = Gateway(GatewayConfig(backend=backend), (PROF,) * 3,
                     make_gateway_policy("mixing"), trace=rec)
        gw.run(scn)
        streams.append(_normalized(rec, scn.requests))
    types = {e[1] for e in streams[0]}
    assert {tr.EV_ARRIVE, tr.EV_ADMIT, tr.EV_ROUTE, tr.EV_INST_ADMIT,
            tr.EV_PREFILL_DONE, tr.EV_FIRST_TOKEN,
            tr.EV_COMPLETE} <= types
    assert streams[0] == streams[1]


def test_tracing_is_an_observer_snapshot_identical():
    """A fully-traced gateway run must reproduce the untraced run's
    simulated metrics bit-for-bit (events never advance the clock)."""
    snaps = []
    for trace in (None, tr.TraceRecorder()):
        scn = make_tenant_scenario(seed=5, n_requests=80, rate=8.0,
                                   profiles=(PROF,) * 2)
        gw = Gateway(GatewayConfig(), (PROF,) * 2,
                     make_gateway_policy("mixing"), trace=trace)
        stats = gw.run(scn)
        snap = stats["snapshot"]
        snaps.append((snap["e2e"]["p95"], snap["e2e"]["p50"],
                      snap["ttft"]["p95"], stats["preemptions"],
                      stats["n"]))
    assert snaps[0] == snaps[1]


# -- Chrome trace export -----------------------------------------------------

GOLDEN = os.path.join(os.path.dirname(__file__), "data",
                      "golden_trace.json")


def _golden_run():
    """Tiny deterministic traced gateway run (the committed snapshot).
    Rids are rebased to 0..n-1 -- the only run-to-run variance is the
    Request rid autoincrement's global offset."""
    scn = make_tenant_scenario(seed=2, n_requests=15, rate=6.0,
                               profiles=(PROF,) * 2)
    base = min(r.rid for r in scn.requests)
    rec = tr.TraceRecorder()
    gw = Gateway(GatewayConfig(), (PROF,) * 2,
                 make_gateway_policy("mixing"), trace=rec)
    gw.run(scn)
    doc = obs.chrome_trace(rec, title="golden")
    for e in doc["traceEvents"]:
        rid = e.get("args", {}).get("rid")
        if rid is not None and rid >= 0:
            e["args"]["rid"] = rid - base
    return doc


def test_chrome_trace_matches_golden_snapshot():
    doc = _golden_run()
    assert obs.validate_chrome_trace(doc) == []
    with open(GOLDEN) as f:
        golden = json.load(f)
    # compare through a JSON round-trip so float repr is identical
    assert json.loads(json.dumps(doc)) == golden


def test_chrome_trace_structure():
    doc = _golden_run()
    evs = doc["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert pids == {0, 1, 2}              # router + 2 instances
    names = {e["name"] for e in evs if e["ph"] == "X"}
    assert names == {"queued", "prefill", "decode"}
    counters = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"queue_depth", "kv_tokens", "backlog"} <= counters
    # spans on one lane never overlap (greedy packing invariant)
    lanes = {}
    for e in evs:
        if e["ph"] == "X":
            lanes.setdefault((e["pid"], e["tid"]), []).append(
                (e["ts"], e["ts"] + e["dur"]))
    for spans in lanes.values():
        spans.sort()
        for (_, end0), (start1, _) in zip(spans, spans[1:]):
            assert start1 >= end0


def test_validate_chrome_trace_rejects_malformed():
    assert obs.validate_chrome_trace([]) != []
    assert obs.validate_chrome_trace({}) != []
    bad_ph = {"traceEvents": [
        {"name": "x", "ph": "Z", "pid": 0, "ts": 0.0}]}
    assert any("ph" in e for e in obs.validate_chrome_trace(bad_ph))
    no_dur = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": 0, "ts": 0.0}]}
    assert any("dur" in e for e in obs.validate_chrome_trace(no_dur))
    neg_ts = {"traceEvents": [
        {"name": "x", "ph": "i", "pid": 0, "ts": -1.0}]}
    assert any("ts" in e for e in obs.validate_chrome_trace(neg_ts))
    empty_c = {"traceEvents": [
        {"name": "x", "ph": "C", "pid": 0, "ts": 0.0, "args": {}}]}
    assert any("args" in e for e in obs.validate_chrome_trace(empty_c))


def test_obs_cli_validates_and_rejects(tmp_path):
    good = tmp_path / "good.json"
    with open(good, "w") as f:
        json.dump(_golden_run(), f)
    bad = tmp_path / "bad.json"
    with open(bad, "w") as f:
        json.dump({"traceEvents": [{"ph": "X"}]}, f)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(__file__)), "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    ok = subprocess.run(
        [sys.executable, "-m", "repro.serving.obs", "--validate",
         str(good)], env=env, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stderr
    fail = subprocess.run(
        [sys.executable, "-m", "repro.serving.obs", "--validate",
         str(bad)], env=env, capture_output=True, text=True)
    assert fail.returncode == 1
    assert "INVALID" in fail.stdout


# -- decision attribution ----------------------------------------------------

def test_attribution_joins_decisions_to_actuals():
    scn = make_tenant_scenario(seed=7, n_requests=80, rate=8.0,
                               profiles=(PROF,) * 3)
    gw = Gateway(GatewayConfig(attribution=True), (PROF,) * 3,
                 make_gateway_policy("mixing"))
    stats = gw.run(scn)
    at = stats["snapshot"]["attribution"]
    assert at["policy"] == "mixing"
    assert at["decisions"] >= stats["n"]
    assert at["drift"]["joined"] == stats["n"]
    # mixing IS the yardstick -> zero regret, full agreement
    assert at["agree_rate"] == 1.0
    assert at["regret"]["p95"] == 0.0
    # oracle length predictor -> zero drift, no bucket vocabulary
    assert at["drift"]["abs_err"]["p95"] == 0.0
    assert at["drift"]["bucket_accuracy"] is None


def test_attribution_nonzero_regret_for_blind_policy():
    scn = make_tenant_scenario(seed=7, n_requests=80, rate=8.0,
                               profiles=(PROF,) * 3)
    gw = Gateway(GatewayConfig(attribution=True), (PROF,) * 3,
                 make_gateway_policy("rr"))
    stats = gw.run(scn)
    at = stats["snapshot"]["attribution"]
    assert at["policy"] == "rr"
    assert at["agree_rate"] < 1.0
    assert at["regret"]["p95"] > 0.0


def test_attribution_bucketed_predictor_reports_bucket_accuracy():
    sm = StreamMetrics()
    sm.enable_attribution(policy="p", bucket_of=lambda d: min(d // 100,
                                                              3))
    reqs = _reqs(20, seed=1)
    for i, r in enumerate(reqs):
        d_hat = r.decode_tokens if i % 2 == 0 else r.decode_tokens + 400
        sm.on_decision(r, d_hat, regret=0.1 * i, agree=(i % 2 == 0))
        r.finished = float(i + 1)
        r.first_token = float(i)
        sm.on_complete(r)
    at = sm.snapshot(now=30.0)["attribution"]
    assert at["decisions"] == 20 and at["drift"]["joined"] == 20
    assert at["agree_rate"] == 0.5
    assert 0.0 < at["drift"]["bucket_accuracy"] <= 1.0
    assert at["drift"]["abs_err"]["p50"] > 0.0


def test_explain_breakdown_matches_route_decision():
    scn = make_tenant_scenario(seed=4, n_requests=30, rate=6.0,
                               profiles=(PROF,) * 3)
    cluster = Cluster(PROF, 3)
    for name, key in (("jsq", "loads"), ("sticky", "hit_frac"),
                      ("mixing", "bonus")):
        pol = make_gateway_policy(name)
        req = scn.requests[0]
        a = pol.route(cluster, req, d_hat=50)
        ex = pol.explain(cluster, req, d_hat=50)
        assert key in ex and len(ex[key]) >= 3
        if name == "jsq":
            assert a == ex["alive"][int(np.argmin(ex["loads"]))]
        if name == "mixing":
            assert a == int(np.argmax(ex["bonus"]))


def test_route_events_carry_explain_payload():
    scn = make_tenant_scenario(seed=4, n_requests=40, rate=6.0,
                               profiles=(PROF,) * 3)
    rec = tr.TraceRecorder()
    gw = Gateway(GatewayConfig(), (PROF,) * 3,
                 make_gateway_policy("mixing"), trace=rec)
    gw.run(scn)
    routes = [e for e in rec.events() if e[1] == tr.EV_ROUTE]
    assert routes
    for e in routes:
        data = e[5]
        assert data["inst"] == e[3]
        assert "d_hat" in data and "regret" in data
        assert len(data["scores"]) == 3
        assert data.get("forced") \
            or int(np.argmax(data["bonus"])) == data["inst"]


# -- metrics registry --------------------------------------------------------

def test_registry_flattens_and_renders_prometheus(tmp_path):
    reg = obs.MetricsRegistry()
    reg.ingest_snapshot({"e2e": {"p95": 1.5, "n_window": 10},
                         "slo_rate": 0.9,
                         "tenants": {"a-b": {"shed": 2}},
                         "skipped": None,
                         "label": "text-not-a-number"})
    j = reg.to_json()
    assert j["gateway_e2e_p95"] == 1.5
    assert j["gateway_tenants_a_b_shed"] == 2.0
    assert "gateway_skipped" not in j and "gateway_label" not in j
    prom = reg.to_prometheus()
    assert "# TYPE gateway_e2e_p95 gauge" in prom
    assert "gateway_e2e_p95 1.5" in prom
    for line in prom.splitlines():
        if not line.startswith("#"):
            name, val = line.split()
            float(val)
            assert name == obs._metric_name(name)
    path = tmp_path / "m.json"
    reg.save(str(path))
    assert json.load(open(path)) == j
    ppath = tmp_path / "m.prom"
    reg.save(str(ppath))
    assert open(ppath).read() == prom


def test_registry_ingests_rl_telemetry():
    from repro.core import rl_router as rl
    agent = rl.make_agent(rl.RouterConfig(n_instances=3), m=3)
    reg = obs.MetricsRegistry()
    reg.ingest_rl(agent.telemetry())
    j = reg.to_json()
    assert j["rl_learn_steps"] == 0.0
    assert j["rl_replay_size"] == 0.0


# -- P2 small-n fallback (satellite) -----------------------------------------

@pytest.mark.parametrize("n", [1, 3, 7, 20, 64])
def test_metric_track_life_quantiles_exact_for_short_streams(n):
    rng = np.random.default_rng(n)
    xs = rng.lognormal(0.0, 1.0, size=n)
    track = _MetricTrack(window=1e9, quantiles=(0.5, 0.95, 0.99))
    for i, x in enumerate(xs):
        track.add(float(i), float(x))
    rep = track.report(now=float(n), quantiles=(0.5, 0.95, 0.99))
    for q in (0.5, 0.95, 0.99):
        exact = float(np.quantile(xs, q))
        assert rep[f"p{int(q * 100)}_life"] == pytest.approx(exact), \
            (n, q)


def test_p2_converges_on_long_streams():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(0.0, 0.5, size=20000)
    track = _MetricTrack(window=1e9, quantiles=(0.5, 0.95))
    for i, x in enumerate(xs):
        track.add(float(i), float(x))
    rep = track.report(now=2e4, quantiles=(0.5, 0.95))
    for q in (0.5, 0.95):
        exact = float(np.quantile(xs, q))
        assert rep[f"p{int(q * 100)}_life"] == pytest.approx(
            exact, rel=0.05), q


def test_p2_exact_below_five_samples():
    est = P2Quantile(0.95)
    assert est.value() is None
    for x in (3.0, 1.0, 2.0):
        est.add(x)
    assert est.value() == pytest.approx(float(np.quantile(
        [3.0, 1.0, 2.0], 0.95)))
