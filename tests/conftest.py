import os
import subprocess
import sys
import textwrap

import pytest

# NOTE: do NOT set xla_force_host_platform_device_count here -- smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses.

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    """Run python code in a fresh interpreter with N host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env, capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{out.stdout}\n"
            f"STDERR:\n{out.stderr}")
    return out.stdout


@pytest.fixture
def subproc():
    return run_subprocess
