"""core.backends registry resolution and the deprecation shims that
keep the pre-registry kwargs (``sim_backend=`` spellings) working."""
import warnings

import pytest

from repro.core import batched_rl, rl_router as rl
from repro.core.backends import (available_backends, make_backend,
                                 register_backend)
from repro.core.jaxsim import JaxSimPool
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster
from repro.core.vecsim import VecCluster, VecSimPool
from repro.core.workload import Scenario, generate, to_requests
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.policies import make_gateway_policy

PROF = V100_LLAMA2_7B


def test_registry_resolves_all_builtin_backends():
    assert {"py", "vec", "jax", "engine"} <= set(available_backends())
    for name in ("py", "vec", "jax", "engine"):
        assert make_backend(name).name == name


def test_make_backend_unknown_name_lists_alternatives():
    with pytest.raises(ValueError, match="unknown simulator backend"):
        make_backend("cuda")
    with pytest.raises(ValueError, match="vec"):
        make_backend("nope")


def test_register_backend_shadows():
    @register_backend("_test_shadow")
    class Fake:
        name = "_test_shadow"

        def make_cluster(self, profile, n_instances, **kw):
            return "fake"

        def make_pool(self, n_episodes, **kw):
            return "fake-pool"
    try:
        assert make_backend("_test_shadow").make_pool(1) == "fake-pool"
    finally:
        from repro.core import backends as b
        b._REGISTRY.pop("_test_shadow", None)


def test_cluster_kwarg_dispatches_through_registry():
    assert not isinstance(Cluster(PROF, 2), VecCluster)
    cv = Cluster(PROF, 2, backend="vec")
    assert isinstance(cv, VecCluster)
    assert type(cv.pool) is VecSimPool
    cj = Cluster(PROF, 2, backend="jax")
    assert isinstance(cj, VecCluster)
    assert isinstance(cj.pool, JaxSimPool)


def test_pool_less_backends_raise_actionable_errors():
    with pytest.raises(ValueError, match="no pooled"):
        make_backend("py").make_pool(2)
    with pytest.raises(ValueError, match="pooled"):
        make_backend("engine").make_pool(2)
    with pytest.raises(ValueError, match="engines="):
        make_backend("engine").make_cluster(PROF, 2)


def test_gateway_backend_resolves_through_registry():
    gw = Gateway(GatewayConfig(backend="jax"), (PROF,) * 2,
                 make_gateway_policy("jsq"))
    assert isinstance(gw.cluster, VecCluster)
    assert isinstance(gw.cluster.pool, JaxSimPool)


# -- deprecation shims -------------------------------------------------------

def test_batched_config_sim_backend_shim():
    with pytest.warns(DeprecationWarning, match="sim_backend is"):
        bcfg = batched_rl.BatchedRLConfig(n_envs=2, sim_backend="vec")
    assert bcfg.backend == "vec"
    # the new spelling stays silent
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        bcfg = batched_rl.BatchedRLConfig(n_envs=2, backend="jax")
    assert bcfg.backend == "jax"


def test_routing_env_sim_backend_shim():
    cfg = rl.RouterConfig(n_instances=2, seed=0)
    with pytest.warns(DeprecationWarning, match="sim_backend"):
        env = rl.RoutingEnv(cfg, PROF, sim_backend="vec")
    assert env.sim_backend == "vec"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        env = rl.RoutingEnv(cfg, PROF, backend="vec")
    assert env.sim_backend == "vec"


def test_evaluate_scenarios_sim_backend_shim():
    cfg = rl.RouterConfig(variant="guided", n_instances=2,
                          q_arch="decomposed", seed=0)
    agent = rl.make_agent(cfg)
    reqs = to_requests(generate(12, seed=1), rate=20.0, seed=2)
    scn = Scenario.homogeneous(PROF, 2, reqs)
    with pytest.warns(DeprecationWarning, match="sim_backend"):
        out = batched_rl.evaluate_scenarios(cfg, agent, [scn],
                                            sim_backend="vec")
    assert out[0]["n"] == 12
