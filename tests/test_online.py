"""Online continual learning: drift scenario determinism, py-vs-vec
transition parity, frozen-policy equivalence, full learner-state
checkpoint round-trips, atomic hot-swap, the safe-fallback guardrail,
and the saturating preemption-fidelity stream."""
import threading

import jax
import numpy as np
import pytest

from repro.core import dqn as dqn_lib
from repro.core import rl_router as rl
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B
from repro.serving import fidelity as fid
from repro.serving.gateway import Gateway, GatewayConfig, OracleLength
from repro.serving.policies import RLPolicy
from repro.training.checkpoint import (CheckpointManager, restore_learner,
                                       save_learner)
from repro.training.online import OnlineConfig, OnlinePolicy, OnlineTrainer

PROF = V100_LLAMA2_7B


def _rcfg(m=3, **kw):
    kw.setdefault("include_health_features", True)
    return rl.RouterConfig(variant="guided", n_instances=m,
                           q_arch="decomposed", seed=0, **kw)


def _req_key(r):
    return (r.prompt_tokens, r.decode_tokens, r.arrival, r.tenant, r.task)


# -- drift scenario generator ------------------------------------------------

def test_drift_scenario_deterministic():
    a = wl.make_drift_scenario(seed=11, n_requests=200)
    b = wl.make_drift_scenario(seed=11, n_requests=200)
    assert [_req_key(r) for r in a.requests] \
        == [_req_key(r) for r in b.requests]
    assert a.meta["chaos"] == b.meta["chaos"]     # frozen dataclasses
    assert a.meta["flip_time"] == b.meta["flip_time"]
    # a different seed moves the stream
    c = wl.make_drift_scenario(seed=12, n_requests=200)
    assert [_req_key(r) for r in a.requests] \
        != [_req_key(r) for r in c.requests]


def test_drift_scenario_flips_mix_and_churns_tenants():
    scn = wl.make_drift_scenario(seed=5, n_requests=300, flip_frac=0.5)
    i = scn.meta["flip_index"]
    pre = {r.tenant for r in scn.requests[:i]}
    post = {r.tenant for r in scn.requests[i:]}
    assert "chat" in pre and "chat" not in post       # tenant leaves
    assert "ingest" in post and "ingest" not in pre   # tenant arrives
    # arrivals are one continuous stream: monotone across the flip
    ts = [r.arrival for r in scn.requests]
    assert ts == sorted(ts)
    assert scn.requests[i].arrival == pytest.approx(scn.meta["flip_time"])
    # the auto chaos straggles an instance from the flip onward
    ch = scn.meta["chaos"]
    assert ch.stragglers and ch.stragglers[0].t0 == scn.meta["flip_time"]
    assert ch.crashes and ch.crashes[0].restart_after is not None
    # chaos=None leaves a pure workload flip
    assert wl.make_drift_scenario(seed=5, n_requests=60,
                                  chaos=None).meta["chaos"] is None


# -- transition recording ----------------------------------------------------

def _spy_trainer(rcfg, **ocfg_kw):
    tr = OnlineTrainer(rcfg, OnlineConfig(**ocfg_kw))
    rows = []
    orig = tr._pack

    def spy(t, s2, mask2, done=1.0):
        rows.append((np.array(t[0]), int(t[1]), float(t[2]),
                     np.array(s2), np.array(mask2), float(done)))
        orig(t, s2, mask2, done)
    tr._pack = spy
    return tr, rows


def _run_online(backend, rcfg, scn, seed=0, learn=False, **gw_kw):
    tr, rows = _spy_trainer(rcfg, learn=learn, eps=0.0, guard=False,
                            seed=seed)
    gw = Gateway(GatewayConfig(backend=backend, **gw_kw),
                 scn.profiles, tr.policy, length=OracleLength())
    stats = gw.run(_clone(scn.requests))
    return stats, rows, tr


def _clone(reqs):
    from repro.serving.request import Request
    return [Request(prompt_tokens=r.prompt_tokens,
                    decode_tokens=r.decode_tokens, arrival=r.arrival,
                    task=r.task, tenant=r.tenant) for r in reqs]


def test_online_transition_parity_py_vs_vec_under_drift():
    """Bit parity of the recorded transition stream between the python
    stepper and the vectorized backend, on the drift scenario WITH the
    mid-stream flip and instance fail/recover active.  States, actions,
    masks, and done flags are bit-exact; rewards agree to float
    tolerance (the vec backlog accumulators sum via np.bincount, a
    documented summation-order divergence)."""
    rcfg = _rcfg()
    scn = wl.make_drift_scenario(seed=9, n_requests=160, rate=14.0,
                                 profiles=(PROF,) * 3)
    out = {}
    for backend in ("py", "vec"):
        stats, rows, _ = _run_online(backend, rcfg, scn,
                                     chaos=scn.meta["chaos"],
                                     failover=True)
        out[backend] = (stats, rows)
    (sp, rp), (sv, rv) = out["py"], out["vec"]
    assert sp["n"] == sv["n"] > 0
    assert sp["orphaned"] == sv["orphaned"] > 0    # the crash really hit
    assert len(rp) == len(rv) > 0
    for a, b in zip(rp, rv):
        np.testing.assert_array_equal(a[0], b[0])      # s
        np.testing.assert_array_equal(a[3], b[3])      # s2
        np.testing.assert_array_equal(a[4], b[4])      # mask2
        assert a[1] == b[1] and a[5] == b[5]           # action, done
        assert a[2] == pytest.approx(b[2], rel=1e-6, abs=1e-9)


def test_online_transitions_deterministic():
    """Same seed, same backend -> byte-identical transition stream."""
    rcfg = _rcfg()
    scn = wl.make_drift_scenario(seed=4, n_requests=100, rate=12.0,
                                 profiles=(PROF,) * 3, chaos=None)
    _, ra, _ = _run_online("py", rcfg, scn)
    _, rb, _ = _run_online("py", rcfg, scn)
    assert len(ra) == len(rb) > 0
    for a, b in zip(ra, rb):
        np.testing.assert_array_equal(a[0], b[0])
        assert (a[1], a[2], a[5]) == (b[1], b[2], b[5])


def test_online_frozen_equivalence():
    """With learning off, eps=0 and the guardrail off, the online
    policy's decision stream is identical to a frozen RLPolicy over the
    same agent weights -- shadow recording is behaviorally free."""
    rcfg = _rcfg()
    scn = wl.make_tenant_scenario(seed=5, n_requests=120, rate=16.0,
                                  pattern="bursty", profiles=(PROF,) * 3)
    reqs_a, reqs_b = _clone(scn.requests), _clone(scn.requests)
    agent = rl.make_agent(rcfg)
    gw_f = Gateway(GatewayConfig(), scn.profiles, RLPolicy(agent, rcfg),
                   length=OracleLength())
    gw_f.run(reqs_a)
    tr = OnlineTrainer(rcfg, OnlineConfig(learn=False, eps=0.0,
                                          guard=False),
                       agent=rl.make_agent(rcfg))
    gw_o = Gateway(GatewayConfig(), scn.profiles, tr.policy,
                   length=OracleLength())
    gw_o.run(reqs_b)
    for a, b in zip(reqs_a, reqs_b):
        assert a.instance == b.instance
        assert a.finished == b.finished
    assert tr.transitions > 0          # ... while still recording


def test_online_learner_steps_and_publishes():
    rcfg = _rcfg()
    scn = wl.make_tenant_scenario(seed=3, n_requests=400, rate=20.0,
                                  pattern="bursty", profiles=(PROF,) * 3)
    tr = OnlineTrainer(rcfg, OnlineConfig(seed=0))
    gw = Gateway(GatewayConfig(), scn.profiles, tr.policy,
                 length=OracleLength())
    stats = gw.run(_clone(scn.requests))
    assert stats["n"] == 400
    assert tr.agent.steps > 0                    # learner actually ran
    assert tr.publishes > 1                      # weights were republished
    assert tr.agent.buffer.size == tr.transitions
    # the served weights are the learner's latest published tree
    assert tr.policy.agent.params is tr.agent.params


def test_online_rejects_engine_backend():
    rcfg = _rcfg(m=1)
    tr = OnlineTrainer(rcfg, m=1)

    class _FakeEngineView:                 # no on_token hook surface
        pass

    class _FakeCluster:
        is_vec = False
        instances = (_FakeEngineView(),)

    class _FakeGateway:
        cluster = _FakeCluster()

    with pytest.raises(ValueError, match="backend"):
        tr.bind(_FakeGateway())


# -- full learner-state checkpointing ----------------------------------------

def _filled_agent(rcfg, seed=0, n=700):
    agent = rl.make_agent(rcfg)
    rng = np.random.default_rng(seed)
    d, na = agent.cfg.state_dim, agent.cfg.n_actions
    for _ in range(n):
        s = rng.normal(size=d).astype(np.float32)
        s2 = rng.normal(size=d).astype(np.float32)
        mask = np.ones(na, bool)
        agent.observe(s, int(rng.integers(na)), float(rng.normal()),
                      s2, 1.0, mask)
    return agent


def _trees_equal(a, b):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    assert len(fa) == len(fb)
    for x, y in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_full_checkpoint_exact_resume(tmp_path):
    """save_learner/restore_learner round-trips EVERYTHING: params,
    target, optimizer, replay contents+priorities, centering EMA, RNG.
    A restored learner continues bit-identically to the original."""
    rcfg = _rcfg()
    agent = _filled_agent(rcfg)
    for _ in range(3):
        agent.learn(sync=True)
    save_learner(str(tmp_path / "ck"), step=agent.steps, agent=agent)

    fresh = rl.make_agent(rcfg)
    step = restore_learner(str(tmp_path / "ck"), fresh)
    assert step == agent.steps
    _trees_equal(fresh.params, agent.params)
    _trees_equal(fresh.target, agent.target)
    _trees_equal(fresh.opt, agent.opt)
    np.testing.assert_array_equal(fresh.buffer.data, agent.buffer.data)
    np.testing.assert_array_equal(fresh.buffer.prio, agent.buffer.prio)
    assert fresh.buffer.ptr == agent.buffer.ptr
    assert fresh.buffer.size == agent.buffer.size
    assert fresh.r_mean == agent.r_mean
    assert fresh.rng.bit_generator.state == agent.rng.bit_generator.state
    # exact resume: both continue with identical prioritized sampling
    for _ in range(3):
        la = agent.learn(sync=True)
        lb = fresh.learn(sync=True)
        assert la == lb
    _trees_equal(fresh.params, agent.params)
    assert fresh.steps == agent.steps


def test_restore_learner_accepts_params_only_artifact(tmp_path):
    """The offline trainers save bare state_dict trees; restore_learner
    warm-starts networks+optimizer from those and leaves the replay
    buffer fresh."""
    rcfg = _rcfg()
    src = _filled_agent(rcfg, n=600)
    src.learn(sync=True)
    mgr = CheckpointManager(str(tmp_path / "off"))
    mgr.save(7, src.state_dict(), {}, sync=True)
    mgr.close()
    fresh = rl.make_agent(rcfg)
    step = restore_learner(str(tmp_path / "off"), fresh)
    assert step == 7
    _trees_equal(fresh.params, src.params)
    assert fresh.buffer.size == 0                  # replay NOT restored


def test_restore_learner_missing_dir_is_none(tmp_path):
    agent = rl.make_agent(_rcfg())
    assert restore_learner(str(tmp_path / "nope"), agent) is None


def test_online_warm_start_from_offline_checkpoint(tmp_path):
    rcfg = _rcfg()
    src = _filled_agent(rcfg, n=600)
    src.learn(sync=True)
    save_learner(str(tmp_path / "warm"), step=11, agent=src)
    tr = OnlineTrainer(rcfg, OnlineConfig(
        warm_start=str(tmp_path / "warm")))
    assert tr.warm_started_step == 11
    _trees_equal(tr.agent.params, src.params)
    # the published serving weights ARE the warm-started tree
    assert tr.policy.agent.params is tr.agent.params


# -- atomic hot-swap ---------------------------------------------------------

def test_hot_swap_no_torn_reads():
    """A writer thread flips the policy between two tagged param trees
    while a reader evaluates Q continuously: every read must produce
    the exact output of ONE tree, never a torn mixture of layers."""
    rcfg = _rcfg()
    agent = rl.make_agent(rcfg)
    policy = RLPolicy(agent, rcfg)
    tree_a = agent.params
    tree_b = jax.tree.map(lambda x: x + 1.0, tree_a)
    s = np.random.default_rng(0).normal(
        size=agent.cfg.state_dim).astype(np.float32)[None]
    qa = np.asarray(dqn_lib.q_values(agent.cfg, tree_a, s))
    qb = np.asarray(dqn_lib.q_values(agent.cfg, tree_b, s))
    assert not np.allclose(qa, qb)
    stop = threading.Event()

    def writer():
        trees = (tree_a, tree_b)
        i = 0
        while not stop.is_set():
            policy.hot_swap(trees[i & 1])
            i += 1

    torn = []
    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(300):
            q = np.asarray(dqn_lib.q_values(agent.cfg,
                                            policy.agent.params, s))
            if not (np.array_equal(q, qa) or np.array_equal(q, qb)):
                torn.append(q)
    finally:
        stop.set()
        t.join()
    assert not torn, f"torn read detected: {torn[:1]}"


# -- safe-fallback guardrail -------------------------------------------------

def test_guardrail_trips_to_mixing_and_recovers():
    """An adversarial Q-head (argmin of the guidance bonus) must trip
    the regret guardrail; during fallback decisions equal the mixing
    argmax; after the cooldown the trainer re-probes the Q-head."""
    rcfg = _rcfg()
    tr = OnlineTrainer(rcfg, OnlineConfig(
        learn=False, eps=0.0, guard=True, guard_window=8,
        guard_regret=1e-4, guard_cooldown=3.0))

    # sabotage the served Q: always pick the WORST-bonus valid action
    class _Adversary:
        cfg = tr.serve_agent.cfg

        def act(self, s, mask, epsilon=0.0, prior=None, q_squash=0.0):
            bonus = prior if prior is not None else np.zeros(len(mask))
            b = np.where(mask, bonus, np.inf)
            return int(np.argmin(b))
    tr.policy.agent = _Adversary()
    tr.serve_agent = tr.policy.agent

    scn = wl.make_tenant_scenario(seed=2, n_requests=150, rate=16.0,
                                  pattern="bursty", profiles=(PROF,) * 3)
    gw = Gateway(GatewayConfig(), scn.profiles, tr.policy,
                 length=OracleLength())
    stats = gw.run(_clone(scn.requests))
    assert stats["n"] == 150
    assert tr.fallback_entries >= 1          # guardrail tripped
    assert tr.fallback_decisions > 0         # ... and routed by mixing
    # cooldown expired at least once mid-run (trip count > 1 or ended
    # back in rl mode): the fallback is a probation, not a latch
    assert tr.fallback_entries > 1 or tr.mode == "rl"


def test_guardrail_stays_quiet_for_mixing_equivalent_decisions():
    """Decisions that track the guidance argmax accumulate ~zero
    regret: the guardrail must not trip on a healthy policy."""
    rcfg = _rcfg()
    tr = OnlineTrainer(rcfg, OnlineConfig(
        learn=False, eps=0.0, guard=True, guard_window=8,
        guard_regret=0.05))

    class _Mirror:                      # picks the best-bonus action
        cfg = tr.serve_agent.cfg

        def act(self, s, mask, epsilon=0.0, prior=None, q_squash=0.0):
            bonus = prior if prior is not None \
                else np.zeros(len(mask))
            return int(np.argmax(np.where(mask, bonus, -np.inf)))
    tr.policy.agent = _Mirror()
    tr.serve_agent = tr.policy.agent
    scn = wl.make_tenant_scenario(seed=2, n_requests=120, rate=14.0,
                                  pattern="bursty", profiles=(PROF,) * 3)
    gw = Gateway(GatewayConfig(), scn.profiles, tr.policy,
                 length=OracleLength())
    gw.run(_clone(scn.requests))
    assert tr.fallback_entries == 0


# -- saturating preemption-fidelity stream -----------------------------------

def test_saturating_stream_preempts_on_both_sim_backends():
    fcfg = fid.FidelityConfig(backends=("py", "vec"), n_requests=32,
                              saturate=True)
    rep = fid.run_fidelity(PROF, fcfg)
    py = rep["backends"]["py"]
    assert py["preemptions"] > 0
    assert py["completed"] == 32                  # queued, not lost
    assert rep["backends"]["vec"] == py           # bitwise sim parity
    d = rep["deltas"]["vec_vs_py"]["preemptions"]
    assert d["both_preempt"] and d["abs"] == 0


def test_saturating_stream_is_deterministic_and_clustered():
    fcfg = fid.FidelityConfig(n_requests=16, saturate=True)
    sa = fid.make_stream(fcfg)
    assert sa == fid.make_stream(fcfg)
    # bursts of 2*n_slots near-simultaneous ladder-top prompts
    assert all(p == max(fcfg.prompt_lengths) for p, _, _ in sa)
    g = 2 * fcfg.n_slots
    t0 = [t for _, _, t in sa[:g]]
    assert max(t0) - min(t0) < 0.01


def test_saturating_stream_preempts_on_real_engine():
    """The engine leg of preemption fidelity: same saturating stream,
    tiny real engine, preemptions on BOTH sides of the delta."""
    from repro.configs import get_config
    from repro.models import params as params_lib
    model_cfg = get_config("qwen3-0.6b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), model_cfg)
    fcfg = fid.FidelityConfig(
        backends=("py", "engine"), n_requests=8, n_instances=1,
        n_slots=2, cache_len=64, capacity_tokens=80,
        prompt_lengths=(16, 32), decode_range=(4, 12), rate=6.0,
        saturate=True)
    rep = fid.run_fidelity(PROF, fcfg, model_cfg=model_cfg,
                           params=params)
    d = rep["deltas"]["engine_vs_py"]["preemptions"]
    assert d["both_preempt"], d
    assert rep["backends"]["engine"]["completed"] == 8
