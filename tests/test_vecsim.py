"""Vectorized simulator core: decision-for-decision parity with the
Python stepper, fused batched-RL stepping, O(1) outstanding tokens, and
the gateway's cancellation/autoscaling satellites."""
import numpy as np
import pytest
from _hypothesis_support import given, settings, st

from repro.core import batched_rl, rl_router as rl
from repro.core import state as state_lib
from repro.core.policies import make_policy
from repro.core.profiles import A100_LLAMA31_8B, V100_LLAMA2_7B
from repro.core.simulator import Cluster, SimInstance, run_heuristic
from repro.core.vecsim import VecCluster, VecSimPool
from repro.core.workload import (Scenario, SessionConfig, generate,
                                 make_tenant_scenario, scenario_stream,
                                 to_requests)
from repro.serving.gateway import Gateway, GatewayConfig
from repro.serving.policies import make_gateway_policy
from repro.serving.request import Phase, Request
from repro.serving.scheduler import get_scheduler

PROF = V100_LLAMA2_7B


def _reqs(n, seed=0, rate=20.0):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


def _assert_request_parity(ra, rb):
    """Field-level parity between a Python-stepped and a vec-stepped
    copy of the same workload.  Everything except tbt is bit-exact;
    tbt telescopes through synthesized token_times and may differ in
    the last float ulps."""
    for a, b in zip(ra, rb):
        assert a.finished == b.finished, (a.rid, a.finished, b.finished)
        assert a.first_token == b.first_token
        assert a.prefill_done == b.prefill_done
        assert a.routed_at == b.routed_at
        assert a.instance == b.instance
        assert a.preemptions == b.preemptions
        assert a.decoded == b.decoded and a.prefilled == b.prefilled
        assert a.phase is b.phase
        assert len(a.token_times) == len(b.token_times)
        if a.tbt is None:
            assert b.tbt is None
        else:
            assert b.tbt == pytest.approx(a.tbt, rel=1e-12)


# -- seeded heuristic parity -------------------------------------------------

@pytest.mark.parametrize("policy,m,chunk,sched", [
    ("round_robin", 3, 0, "fcfs"),
    ("jsq", 4, 0, "fcfs"),
    ("impact_greedy", 3, 0, "fcfs"),
    ("min_min", 3, 0, "fcfs"),
    ("round_robin", 2, 256, "fcfs"),
    ("round_robin", 3, 0, "bin_packing"),
    ("round_robin", 3, 0, "least_work_left"),
    ("round_robin", 3, 128, "bin_packing"),
])
def test_heuristic_parity(policy, m, chunk, sched):
    ra, rb = _reqs(120, seed=3), _reqs(120, seed=3)
    ca = Cluster(PROF, m, scheduler=sched, chunked_prefill=chunk)
    cb = Cluster(PROF, m, scheduler=sched, chunked_prefill=chunk,
                 backend="vec")
    assert isinstance(cb, VecCluster)
    sa = run_heuristic(ca, ra, make_policy(policy, PROF))
    sb = run_heuristic(cb, rb, make_policy(policy, PROF))
    _assert_request_parity(ra, rb)
    assert sa["spikes"] == sb["spikes"]
    assert sa["e2e_mean"] == sb["e2e_mean"]
    assert sa["ttft_mean"] == sb["ttft_mean"]
    assert len(ca.completed) == len(cb.completed) == 120


def test_heterogeneous_profiles_parity():
    profs = (PROF, A100_LLAMA31_8B)
    ra, rb = _reqs(100, seed=9), _reqs(100, seed=9)
    run_heuristic(Cluster(profs, 2), ra, make_policy("jsq", PROF))
    run_heuristic(Cluster(profs, 2, backend="vec"), rb,
                  make_policy("jsq", PROF))
    _assert_request_parity(ra, rb)


@given(seed=st.integers(0, 40), m=st.integers(1, 5),
       chunk=st.sampled_from([0, 64, 256]))
@settings(max_examples=10, deadline=None)
def test_parity_property(seed, m, chunk):
    """Random widths x chunked-prefill settings: completions, TTFT, and
    preemption counts must match the reference stepper exactly."""
    ra, rb = _reqs(60, seed=seed), _reqs(60, seed=seed)
    run_heuristic(Cluster(PROF, m, chunked_prefill=chunk), ra,
                  make_policy("round_robin", PROF))
    run_heuristic(Cluster(PROF, m, chunked_prefill=chunk, backend="vec"),
                  rb, make_policy("round_robin", PROF))
    _assert_request_parity(ra, rb)


def test_fail_restore_and_elastic_add_parity():
    def drive(backend):
        rs = _reqs(80, seed=11)
        cluster = Cluster(PROF, 3, backend=backend)
        pending = sorted(rs, key=lambda r: r.arrival)
        i, rr, failed, added = 0, 0, False, False
        while len(cluster.completed) < len(rs) and cluster.t < 3000:
            while i < len(pending) and pending[i].arrival <= cluster.t:
                cluster.enqueue(pending[i])
                i += 1
            if cluster.t > 1.0 and not failed:
                cluster.fail_instance(0)
                failed = True
            if cluster.t > 1.5 and not added:
                cluster.add_instance()
                cluster.instances[0].restore()
                cluster.instances[0].clock = cluster.t
                added = True
            alive = cluster.alive()
            while cluster.central and alive:
                cluster.route(alive[rr % len(alive)])
                rr += 1
                alive = cluster.alive()
            cluster.advance()
        assert len(cluster.completed) == len(rs)
        return rs
    a, b = drive("py"), drive("vec")
    _assert_request_parity(a, b)
    # the added instance served on both backends identically
    assert (any(r.instance == 3 for r in a)
            == any(r.instance == 3 for r in b))


# -- featurization / scores read the packed arrays ---------------------------

def test_featurize_bit_exact_against_python_stepper():
    """state.featurize's vec fast path must be bit-identical to the
    scalar path at every decision point of a seeded episode."""
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0)
    env_p = rl.RoutingEnv(cfg, PROF)
    env_v = rl.RoutingEnv(cfg, PROF, backend="vec")
    s_p = env_p.reset(_reqs(60, seed=5))
    s_v = env_v.reset(_reqs(60, seed=5))
    assert isinstance(env_v.cluster, VecCluster)
    done = False
    steps = 0
    while not done and steps < 400:
        np.testing.assert_array_equal(s_p, s_v)
        np.testing.assert_array_equal(env_p.mask(), env_v.mask())
        np.testing.assert_array_equal(env_p.guidance_bonus(),
                                      env_v.guidance_bonus())
        a = (int(np.argmax(env_p.guidance_bonus()[:env_p.cluster.m]))
             if env_p.cluster.central else env_p.cluster.m)
        s_p, r_p, done, _ = env_p.step(a)
        s_v, r_v, done_v, _ = env_v.step(a)
        assert done == done_v
        assert r_v == pytest.approx(r_p, rel=1e-9, abs=1e-9)
        steps += 1
    assert done


def test_featurize_hardware_block_bit_exact_and_hetero():
    """The optional per-instance hardware block (grad1/grad2/capacity)
    must be bit-identical between the scalar and vec featurize paths,
    distinguish mixed hardware, and zero out on failed instances."""
    profs = (PROF, A100_LLAMA31_8B, PROF)
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0,
                          include_hardware_features=True)
    env_p = rl.RoutingEnv(cfg, profs)
    env_v = rl.RoutingEnv(cfg, profs, backend="vec")
    s_p = env_p.reset(_reqs(50, seed=5))
    s_v = env_v.reset(_reqs(50, seed=5))
    dims = state_lib.instance_dims(True, True)
    assert s_p.shape[0] == state_lib.state_dim(3, True, True)
    hb = state_lib.INSTANCE_DIMS + 1
    # V100 block vs A100 block carry their own calibration constants
    v100 = s_p[hb:hb + 3]
    a100 = s_p[dims + hb:dims + hb + 3]
    np.testing.assert_allclose(
        v100, [PROF.grad1 * state_lib.HW_G1_SCALE,
               PROF.grad2 * state_lib.HW_G2_SCALE,
               PROF.capacity_tokens * state_lib.HW_CAP_SCALE],
        rtol=1e-7)
    assert not np.array_equal(v100, a100)
    done, steps = False, 0
    while not done and steps < 200:
        np.testing.assert_array_equal(s_p, s_v)
        a = (int(np.argmax(env_p.guidance_bonus()[:3]))
             if env_p.cluster.central else 3)
        s_p, _, done, _ = env_p.step(a)
        s_v, _, done_v, _ = env_v.step(a)
        assert done == done_v
        steps += 1
    assert done
    # failed instance: the whole block (hardware included) reads zero
    env_p.cluster.fail_instance(1)
    s_fail = env_p._state()
    np.testing.assert_array_equal(s_fail[dims:2 * dims],
                                  np.zeros(dims, np.float32))


def test_featurize_vec_many_hardware_matches_single():
    pool = VecSimPool(2)
    cfg = rl.RouterConfig(variant="guided", n_instances=2, seed=0,
                          include_hardware_features=True)
    profs = (PROF, A100_LLAMA31_8B)
    envs = [rl.RoutingEnv(cfg, profs, pool=pool, pool_ep=i)
            for i in range(2)]
    for i, env in enumerate(envs):
        env.reset(_reqs(30, seed=20 + i))
    for _ in range(25):
        for env in envs:
            a = (int(np.argmax(env.guidance_bonus()[:env.cluster.m]))
                 if env.cluster.central else env.cluster.m)
            env.step(a)
        many = state_lib.featurize_vec_many(
            [e.cluster for e in envs], [e.profile for e in envs],
            [e.predict_decode for e in envs],
            n_buckets=cfg.n_buckets, include_impact=True,
            alpha=cfg.alpha, include_hardware=True)
        for env, got in zip(envs, many):
            np.testing.assert_array_equal(got, env._state())


def test_backlog_accounting_drains_to_zero_on_vec():
    cfg = rl.RouterConfig(variant="guided", n_instances=2, seed=0)
    env = rl.RoutingEnv(cfg, PROF, backend="vec")
    env.reset(_reqs(40, seed=9))
    done, added = False, False
    for _ in range(5000):
        if not done:
            a = (int(np.argmax(env.guidance_bonus()[:env.cluster.m]))
                 if env.cluster.central else env.cluster.m)
            _, _, done, _ = env.step(a)
        if not added and env.cluster.t > 1.0:
            env.cluster.add_instance()
            added = True
        if done:
            break
    assert done and added
    assert env._backlog_penalty() == pytest.approx(0.0, abs=1e-9)


# -- batched RL: fused cross-episode stepping --------------------------------

def test_evaluate_scenarios_vec_matches_sequential():
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0)
    agent = rl.make_agent(cfg)
    ra, rb = _reqs(120, seed=7), _reqs(120, seed=7)
    seq = rl.evaluate(cfg, PROF, agent, ra)
    bat = batched_rl.evaluate_scenarios(
        cfg, agent, [Scenario.homogeneous(PROF, 3, rb)],
        backend="vec")[0]
    _assert_request_parity(ra, rb)
    for key in ("e2e_mean", "ttft_mean", "makespan", "preemptions",
                "router_wait_mean", "spikes"):
        assert seq[key] == pytest.approx(bat[key], rel=1e-9), key


def test_train_batched_vec_reproduces_python_backend():
    """Same seeds, same scenarios: the fused vec trainer must make the
    SAME decisions as the Python-stepper trainer (identical ticks and
    completions; rewards match to float summation order)."""
    def scenario(ep):
        return Scenario.homogeneous(PROF, 3, _reqs(60, seed=300 + ep))

    def cfg():
        return rl.RouterConfig(variant="guided", n_instances=3,
                               explore_episodes=3, q_arch="decomposed",
                               seed=0)
    out_py = batched_rl.train_batched(
        cfg(), scenario, 5,
        bcfg=batched_rl.BatchedRLConfig(n_envs=3, m_max=3,
                                        backend="py"))
    out_vec = batched_rl.train_batched(
        cfg(), scenario, 5,
        bcfg=batched_rl.BatchedRLConfig(n_envs=3, m_max=3,
                                        backend="vec"))
    for hp, hv in zip(out_py["history"], out_vec["history"]):
        assert hp["n"] == hv["n"] == 60
        assert hp["ticks"] == hv["ticks"]
        assert hp["preemptions"] == hv["preemptions"]
        assert hp["e2e_mean"] == pytest.approx(hv["e2e_mean"], rel=1e-9)
        assert hp["reward"] == pytest.approx(hv["reward"], rel=1e-6)


def test_train_batched_vec_hetero_stream_completes():
    cfg = rl.RouterConfig(variant="guided", n_instances=4,
                          explore_episodes=4, q_arch="decomposed", seed=0)
    out = batched_rl.train_batched(
        cfg, scenario_stream(0, n_requests=40), 5,
        bcfg=batched_rl.BatchedRLConfig(n_envs=3, m_max=6,
                                        backend="vec"))
    assert [h["n"] for h in out["history"]] == [40] * 5
    assert out["agent"].buffer.size > 0
    assert len({(h["m"], h["pattern"]) for h in out["history"]}) > 1


def test_featurize_vec_many_matches_single():
    pool = VecSimPool(2)
    cfg = rl.RouterConfig(variant="guided", n_instances=3, seed=0)
    envs = [rl.RoutingEnv(cfg, PROF, pool=pool, pool_ep=i)
            for i in range(2)]
    for i, env in enumerate(envs):
        env.reset(_reqs(40, seed=20 + i))
    for _ in range(40):
        for env in envs:
            a = (int(np.argmax(env.guidance_bonus()[:env.cluster.m]))
                 if env.cluster.central else env.cluster.m)
            env.step(a)
        many = state_lib.featurize_vec_many(
            [e.cluster for e in envs], [e.profile for e in envs],
            [e.predict_decode for e in envs],
            n_buckets=cfg.n_buckets, include_impact=True,
            alpha=cfg.alpha)
        for env, got in zip(envs, many):
            np.testing.assert_array_equal(got, env._state())


# -- O(1) outstanding tokens -------------------------------------------------

def test_outstanding_tokens_incremental_matches_rescan():
    inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
    for r in _reqs(40, seed=3, rate=200.0):
        inst.submit(r)
    for _ in range(3000):
        inst.run_until(inst.clock + 0.02)
        expect = sum((r.prompt_tokens - r.prefilled)
                     + max(r.decode_tokens - r.decoded, 0)
                     for r in inst.residents)
        expect += sum(r.prompt_tokens + r.decode_tokens
                      for r in inst.queue)
        assert inst.outstanding_tokens() == pytest.approx(expect)
        if len(inst.completed) == 40:
            break
    assert len(inst.completed) == 40
    assert inst.outstanding_tokens() == pytest.approx(0.0)


def test_outstanding_tokens_vec_view_matches_python():
    ra, rb = _reqs(60, seed=13), _reqs(60, seed=13)
    ca = Cluster(PROF, 2)
    cb = Cluster(PROF, 2, backend="vec")
    pa = sorted(ra, key=lambda r: r.arrival)
    pb = sorted(rb, key=lambda r: r.arrival)
    ia = ib = 0
    for tick in range(8000):
        for cluster, pending, idx in ((ca, pa, "a"), (cb, pb, "b")):
            i = ia if idx == "a" else ib
            while i < len(pending) and pending[i].arrival <= cluster.t:
                cluster.enqueue(pending[i])
                i += 1
            if idx == "a":
                ia = i
            else:
                ib = i
            while cluster.central:
                cluster.route(tick % 2)
            cluster.advance()
        for k in range(2):
            assert (ca.instances[k].outstanding_tokens()
                    == cb.instances[k].outstanding_tokens())
        if len(ca.completed) == 60 and len(cb.completed) == 60:
            break
    assert len(ca.completed) == len(cb.completed) == 60


# -- gateway satellites: cancellation + autoscaling --------------------------

def _sat_scenario(seed=7, n=120):
    return make_tenant_scenario(seed=seed, n_requests=n, rate=40.0,
                                pattern="bursty",
                                profiles=(PROF,) * 2)


def test_deferred_requests_past_deadline_are_cancelled():
    scn = make_tenant_scenario(seed=7, n_requests=200, rate=80.0,
                               pattern="bursty", profiles=(PROF,) * 2)
    gw = Gateway(GatewayConfig(queue_cap=2, on_full="defer",
                               default_deadline_s=1.0),
                 (PROF,) * 2, make_gateway_policy("rr"))
    stats = gw.run(scn)
    assert stats["cancelled"] > 0
    assert stats["cancelled"] == len(gw.cancelled)
    for r in gw.cancelled:
        assert r.phase is Phase.CANCELLED
        assert r.finished is None
    # cancelled requests surface in the metrics snapshot, per tenant too
    snap = stats["snapshot"]
    assert snap["cancelled"] == stats["cancelled"]
    assert sum(t["cancelled"] for t in snap["tenants"].values()) \
        == stats["cancelled"]
    # nothing cancelled ever completed, and the books balance
    assert stats["admitted"] + stats["shed"] + stats["cancelled"] \
        + len(gw._overflow) == len(scn.requests)


def test_request_level_deadline_beats_default():
    reqs = [Request(prompt_tokens=50, decode_tokens=20,
                    arrival=0.01 * i, deadline=0.5) for i in range(40)]
    gw = Gateway(GatewayConfig(queue_cap=1, on_full="defer"),
                 (PROF,) * 1, make_gateway_policy("rr"))
    stats = gw.run(reqs)
    assert stats["cancelled"] > 0


def test_no_deadline_means_no_cancellation():
    scn = _sat_scenario()
    gw = Gateway(GatewayConfig(queue_cap=4, on_full="defer"),
                 (PROF,) * 2, make_gateway_policy("rr"))
    stats = gw.run(scn)
    assert stats["cancelled"] == 0


def test_autoscale_hook_fires_at_most_once_per_window():
    scn = _sat_scenario(n=200)
    calls = []

    def pred(shed_rate, p95):
        calls.append((shed_rate, p95))
        return True                      # always want more capacity
    gw = Gateway(GatewayConfig(queue_cap=2, on_full="shed",
                               scale_window=10.0),
                 (PROF,) * 2, make_gateway_policy("rr"),
                 scale_up_when=pred)
    stats = gw.run(scn)
    assert stats["scaled"] == len(gw.scale_events) >= 1
    assert gw.cluster.m == 2 + stats["scaled"]
    # rate limit: consecutive scale-ups at least scale_window apart
    for a, b in zip(gw.scale_events, gw.scale_events[1:]):
        assert b - a >= 10.0
    assert calls, "predicate was never consulted"


def test_add_instance_under_load_keeps_parity():
    """Regression: mid-episode scale-out must lower the episode's
    cached min-clock bound, or the advance() fast path skips stepping
    the new lane and decisions diverge from the Python stepper."""
    for seed in (0, 3, 5):
        ra, rb = _reqs(80, seed=seed, rate=30.0), _reqs(80, seed=seed,
                                                        rate=30.0)
        for rs, backend in ((ra, "py"), (rb, "vec")):
            cluster = Cluster(PROF, 2, backend=backend)
            pol = make_policy("jsq", PROF)
            pending = sorted(rs, key=lambda r: r.arrival)
            i, added = 0, False
            while len(cluster.completed) < len(rs) and cluster.t < 3000:
                while (i < len(pending)
                       and pending[i].arrival <= cluster.t):
                    cluster.enqueue(pending[i])
                    i += 1
                if not added and cluster.t > 0.7:
                    cluster.add_instance()
                    added = True
                while cluster.central:
                    a = pol.act(cluster)
                    if a is None or a >= cluster.m:
                        break
                    cluster.route(a)
                cluster.advance()
        _assert_request_parity(ra, rb)


def test_autoscale_predicate_sees_float_p95_before_completions():
    """Regression: the windowed P95 is None before any completion; the
    documented numeric predicates must not crash on the first ticks."""
    scn = _sat_scenario()
    gw = Gateway(GatewayConfig(queue_cap=2, on_full="shed",
                               scale_window=5.0),
                 (PROF,) * 2, make_gateway_policy("rr"),
                 scale_up_when=lambda shed, p95: p95 > 30.0)
    stats = gw.run(scn)            # must not raise
    assert stats["scaled"] == len(gw.scale_events)


def test_autoscale_predicate_false_never_scales():
    scn = _sat_scenario()
    gw = Gateway(GatewayConfig(queue_cap=2, on_full="shed"),
                 (PROF,) * 2, make_gateway_policy("rr"),
                 scale_up_when=lambda shed, p95: False)
    gw.run(scn)
    assert gw.cluster.m == 2 and not gw.scale_events


def test_gateway_rides_vec_backend_with_identical_results():
    scn_a, scn_b = _sat_scenario(seed=3), _sat_scenario(seed=3)
    out = []
    for scn, backend in ((scn_a, "py"), (scn_b, "vec")):
        gw = Gateway(GatewayConfig(queue_cap=8, on_full="defer",
                                   backend=backend),
                     (PROF,) * 2, make_gateway_policy("mixing"))
        out.append(gw.run(scn))
    _assert_request_parity(scn_a.requests, scn_b.requests)
    assert out[0]["shed"] == out[1]["shed"]
    assert out[0]["admitted"] == out[1]["admitted"]
    p_a = out[0]["snapshot"]["e2e"]["p95"]
    p_b = out[1]["snapshot"]["e2e"]["p95"]
    assert p_a == pytest.approx(p_b, rel=1e-12)


@given(seed=st.integers(0, 30), m=st.integers(1, 4),
       pc_tokens=st.sampled_from([0, 256, 4096]),
       inject_failure=st.booleans())
@settings(max_examples=10, deadline=None)
def test_session_cache_parity_property(seed, m, pc_tokens,
                                       inject_failure):
    """Randomized multi-turn session streams through the prefix-cache
    model: cached-prefill admission credit, completion-time radix
    inserts, LRU evictions (the 256-token budget forces them), and
    failure-time cache wipes must be bit-identical across steppers."""
    def drive(backend):
        scn = make_tenant_scenario(seed=seed, n_requests=100,
                                   rate=24.0, pattern="poisson",
                                   profiles=(PROF,) * max(m, 1),
                                   sessions=SessionConfig(block=16))
        rs = scn.requests
        cluster = Cluster(PROF, m, backend=backend,
                          prefix_cache_tokens=pc_tokens,
                          prefix_block=16)
        pending = sorted(rs, key=lambda r: r.arrival)
        i, rr, failed = 0, 0, False
        while len(cluster.completed) < len(rs) and cluster.t < 3000:
            while i < len(pending) and pending[i].arrival <= cluster.t:
                cluster.enqueue(pending[i])
                i += 1
            if inject_failure and m > 1 and not failed \
                    and cluster.t > 1.0:
                cluster.fail_instance(0)
                failed = True
            if failed and cluster.t > 1.5:
                cluster.instances[0].restore()
                cluster.instances[0].clock = cluster.t
                failed = False
            alive = cluster.alive()
            while cluster.central and alive:
                cluster.route(alive[rr % len(alive)])
                rr += 1
                alive = cluster.alive()
            cluster.advance()
        assert len(cluster.completed) == len(rs)
        return rs, cluster
    (ra, ca), (rb, cb) = drive("py"), drive("vec")
    _assert_request_parity(ra, rb)
    for a, b in zip(ra, rb):
        assert a.cached_prefix == b.cached_prefix
    for ia, ib in zip(ca.instances, cb.instances):
        pa = getattr(ia, "prefix_cache", None)
        pb = getattr(ib, "prefix_cache", None)
        assert (pa is None) == (pb is None)
        if pa is not None:
            assert pa.hit_tokens == pb.hit_tokens
            assert pa.lookup_tokens == pb.lookup_tokens
            assert list(pa._blocks) == list(pb._blocks)
