"""End-to-end behaviour tests for the paper's system: real engine +
router, managed-cluster fault tolerance, elastic scaling, RL plumbing."""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import rl_router as rl
from repro.core.cluster_manager import ManagedCluster, ManagedClusterConfig
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.workload import generate, to_requests
from repro.models import params as params_lib
from repro.serving.engine import LLMInstance
from repro.serving.request import Request, summarize
from repro.serving.scheduler import FCFS

PROF = V100_LLAMA2_7B


def test_real_engine_continuous_batching_and_preemption():
    cfg = get_config("llama-2-7b").reduced()
    prof = dataclasses.replace(PROF, capacity_tokens=220)
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    eng = LLMInstance(cfg, params, prof, FCFS(), n_slots=3, cache_len=128)
    reqs = [Request(prompt_tokens=40, decode_tokens=80),
            Request(prompt_tokens=30, decode_tokens=70),
            Request(prompt_tokens=50, decode_tokens=60)]
    for r in reqs:
        eng.submit(r)
    for _ in range(3000):
        eng.step()
        if len(eng.completed) == 3:
            break
    assert len(eng.completed) == 3
    stats = summarize(reqs)
    assert stats["e2e_mean"] > 0
    # continuous batching: decode phases overlapped (makespan < serial sum)
    serial = sum(prof.request_time(r.prompt_tokens, r.decode_tokens)
                 for r in reqs)
    assert stats["makespan"] < serial


def test_engine_failure_requeues():
    cfg = get_config("llama-2-7b").reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    eng = LLMInstance(cfg, params, PROF, FCFS(), n_slots=2, cache_len=64)
    reqs = [Request(prompt_tokens=10, decode_tokens=30) for _ in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.step()
    orphans = eng.fail()
    assert len(orphans) == 3
    assert all(r.instance is None and r.decoded == 0 for r in orphans)
    assert eng.step() == []          # dead engine does nothing


def test_managed_cluster_survives_failure_and_scales():
    cfg = rl.RouterConfig(variant="guided", n_instances=3,
                          q_arch="decomposed", seed=0)
    agent = rl.make_agent(cfg)       # untrained: prior-driven routing
    mgr = ManagedCluster(ManagedClusterConfig(n_instances=3), cfg, PROF,
                         agent)
    reqs = to_requests(generate(120, seed=5), rate=20.0, seed=6)
    stats = mgr.serve(reqs, fault_plan={2.0: "fail:1", 6.0: "add",
                                        9.0: "restore:1"})
    assert stats["n"] == 120, "all requests complete despite failure"
    assert len(stats["events"]) == 3
    # the elastic instance (id 3) actually served traffic
    assert any(r.instance == 3 for r in reqs)
    for r in reqs:
        assert r.finished is not None


def test_router_checkpoint_roundtrip(tmp_path):
    cfg = rl.RouterConfig(variant="guided", n_instances=2,
                          q_arch="decomposed", seed=3)
    agent = rl.make_agent(cfg)
    mgr = ManagedCluster(ManagedClusterConfig(
        n_instances=2, checkpoint_dir=str(tmp_path)), cfg, PROF, agent)
    mgr.save_router(step=7)
    agent2 = rl.make_agent(dataclasses.replace(cfg, seed=99))
    mgr2 = ManagedCluster(ManagedClusterConfig(
        n_instances=2, checkpoint_dir=str(tmp_path)), cfg, PROF, agent2)
    assert mgr2.restore_router()
    for a, b in zip(jax.tree.leaves(agent.params),
                    jax.tree.leaves(agent2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_rl_training_smoke():
    """RL loop runs end to end and the guided variant's guidance decays."""
    cfg = rl.RouterConfig(variant="guided", n_instances=2,
                          explore_episodes=2, q_arch="decomposed", seed=0)
    out = rl.train(
        cfg, PROF,
        lambda ep: to_requests(generate(60, seed=ep), rate=20.0,
                               seed=ep + 9),
        n_episodes=3)
    hist = out["history"]
    assert len(hist) == 3
    assert hist[0]["guide_w"] > hist[-1]["guide_w"]
    st = rl.evaluate(cfg, PROF, out["agent"],
                     to_requests(generate(60, seed=77), rate=20.0,
                                 seed=78))
    assert st["n"] == 60
