"""Simulator + cluster invariants (unit + hypothesis property tests)."""
import pytest
from _hypothesis_support import given, settings, st

from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, SimInstance, run_heuristic
from repro.core.workload import generate, to_requests
from repro.serving.request import Phase, Request
from repro.serving.scheduler import get_scheduler

PROF = V100_LLAMA2_7B


def _requests(n, seed=0, rate=20.0):
    return to_requests(generate(n, seed=seed), rate=rate, seed=seed + 1)


@pytest.mark.parametrize("policy", ["round_robin", "jsq", "decode_balancer",
                                    "dedicated", "min_min", "max_capacity",
                                    "impact_greedy"])
def test_every_request_completes_exactly_once(policy):
    reqs = _requests(120, seed=3)
    cluster = Cluster(PROF, 3)
    run_heuristic(cluster, reqs, make_policy(policy, PROF))
    assert len(cluster.completed) == 120
    assert len({r.rid for r in cluster.completed}) == 120
    for r in reqs:
        assert r.phase is Phase.DONE
        assert r.finished is not None and r.finished >= r.arrival
        assert r.decoded == r.decode_tokens
        if r.preemptions == 0 and r.ttft is not None:
            assert r.ttft >= 0


@given(seed=st.integers(0, 50), n_inst=st.integers(1, 5))
@settings(max_examples=12, deadline=None)
def test_capacity_never_exceeded(seed, n_inst):
    reqs = _requests(60, seed=seed)
    cluster = Cluster(PROF, n_inst)
    pending = sorted(reqs, key=lambda r: r.arrival)
    i, rr = 0, 0
    while len(cluster.completed) < len(reqs) and cluster.t < 3000:
        while i < len(pending) and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            i += 1
        while cluster.central:
            cluster.route(rr % n_inst)
            rr += 1
        cluster.advance()
        for inst in cluster.instances:
            assert inst.resident_token_sum() <= PROF.capacity_tokens
    assert len(cluster.completed) == len(reqs)


def test_fcfs_head_of_line():
    sched = get_scheduler("fcfs")
    q = [Request(prompt_tokens=500, decode_tokens=500),
         Request(prompt_tokens=10, decode_tokens=10)]
    # head's PROMPT doesn't fit -> FCFS refuses to skip it (HOL blocking)
    assert sched.pick(q, 400, PROF) is None
    assert sched.pick(q, 2000, PROF) == 0


def test_bin_packing_picks_largest_fitting():
    sched = get_scheduler("bin_packing")
    q = [Request(prompt_tokens=100, decode_tokens=100),
         Request(prompt_tokens=400, decode_tokens=400),
         Request(prompt_tokens=900, decode_tokens=2000)]
    # all prompts fit; bin packing picks the largest PREDICTED total
    assert sched.pick(q, 1000, PROF) == 2
    # admission filter: only requests whose prompt fits are considered
    assert sched.pick(q, 500, PROF) == 1


def test_least_work_left():
    sched = get_scheduler("least_work_left")
    q = [Request(prompt_tokens=100, decode_tokens=500),
         Request(prompt_tokens=100, decode_tokens=20)]
    assert sched.pick(q, 10_000, PROF) == 1


def test_preemption_resets_progress_and_requeues():
    inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
    big = Request(prompt_tokens=1000, decode_tokens=3500)
    small = Request(prompt_tokens=100, decode_tokens=3000)
    inst.submit(big)
    inst.submit(small)
    preempted = False
    for _ in range(20000):
        inst.run_until(inst.clock + 0.02)
        if small.preemptions or big.preemptions:
            preempted = True
            break
        if len(inst.completed) == 2:
            break
    # capacity 4000 < total 7600 -> someone must get evicted
    assert preempted
    # run to completion: evicted request still finishes
    while len(inst.completed) < 2 and inst.clock < 3000:
        inst.run_until(inst.clock + 1.0)
    assert len(inst.completed) == 2


def test_chunked_prefill_reduces_tbt_spikes():
    """Sarathi-style chunking trades TTFT for smaller decode stalls."""
    def run(chunk):
        reqs = _requests(150, seed=7)
        cluster = Cluster(PROF, 2, chunked_prefill=chunk)
        stats = run_heuristic(cluster, reqs,
                              make_policy("round_robin", PROF))
        return stats
    plain = run(0)
    chunked = run(256)
    assert plain["n"] == chunked["n"] == 150
    # chunked prefill caps per-iteration prefill work -> fewer/late spikes
    assert chunked["spikes"] <= plain["spikes"]


def test_instance_failure_requeues_orphans():
    reqs = _requests(80, seed=11)
    cluster = Cluster(PROF, 3)
    pending = sorted(reqs, key=lambda r: r.arrival)
    i, rr = 0, 0
    failed = False
    while len(cluster.completed) < len(reqs) and cluster.t < 3000:
        while i < len(pending) and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            i += 1
        if cluster.t > 2.0 and not failed:
            cluster.fail_instance(0)
            failed = True
        alive = cluster.alive()
        while cluster.central and alive:
            cluster.route(alive[rr % len(alive)])
            rr += 1
        cluster.advance()
    assert len(cluster.completed) == len(reqs)
    assert all(r.instance != 0 or r.finished is not None for r in reqs)


def test_elastic_add_instance():
    cluster = Cluster(PROF, 2)
    idx = cluster.add_instance()
    assert idx == 2 and cluster.m == 3
    reqs = _requests(40, seed=13)
    stats = run_heuristic(cluster, reqs, make_policy("jsq", PROF))
    assert stats["n"] == 40
    assert any(r.instance == 2 for r in reqs)


def test_engine_and_simulator_agree_on_iteration_cost():
    """The real JAX engine and the simulator share iteration-time
    semantics: a lone decode iteration costs t_decode_base + grad2*ctx."""
    inst = SimInstance(PROF, get_scheduler("fcfs"), 0)
    r = Request(prompt_tokens=50, decode_tokens=5)
    inst.submit(r)
    inst.run_until(1e-9)  # one iteration: admission+prefill
    t_prefill_iter = inst.clock
    assert t_prefill_iter == pytest.approx(
        PROF.iteration_time(50, 0), rel=1e-6)
