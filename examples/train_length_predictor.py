"""Train the output-length bucket predictor (paper §5.1) on the 5-task
synthetic mixture and report Table-1-style accuracies.

  PYTHONPATH=src python examples/train_length_predictor.py
"""
import numpy as np

from repro.core import predictor as pred
from repro.core import workload as wl
from repro.core.profiles import V100_LLAMA2_7B

PROF = V100_LLAMA2_7B

if __name__ == "__main__":
    train = wl.generate(3000, seed=1)
    test = wl.generate(800, seed=2)
    print("== output-length predictor (hint + time-aligned buckets) ==")
    model = pred.BucketPredictor(pred.PredictorConfig(use_hint=True),
                                 PROF, seed=0)
    model.fit(train, epochs=3, verbose=True)
    acc = model.accuracy(test)
    labels = [model.label(s) for s in test]
    maj = np.bincount(labels).max() / len(labels)
    print(f"bucket accuracy: {acc:.3f} (majority baseline {maj:.3f})")
    preds = model.predict(test)
    for task in wl.TASKS:
        idx = [i for i, s in enumerate(test) if s.task == task]
        a = np.mean([preds[i] == labels[i] for i in idx])
        print(f"  {task:16s} acc={a:.3f} (n={len(idx)})")
    print("d-hat examples (bucket upper bound in tokens):")
    for s, b in list(zip(test, preds))[:5]:
        print(f"  true d={s.decode_tokens:5d} -> bucket {b} "
              f"(<= {model.bucket_upper_tokens(int(b))} tokens)")
