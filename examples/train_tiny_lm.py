"""Training driver: train a small LM for a few hundred steps with the full
substrate -- prefetching data pipeline, AdamW, async checkpointing, and
crash-restart (run twice: the second run resumes from the checkpoint).

  PYTHONPATH=src python examples/train_tiny_lm.py [steps]
"""
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.training import checkpoint as ckpt_lib
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib
from repro.training.train_loop import init_train_state, make_train_step

CKPT_DIR = "artifacts/tiny_lm_ckpt"

if __name__ == "__main__":
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    cfg = get_config("llama-2-7b").reduced(
        d_model=128, n_layers=4, d_ff=512, vocab_size=2048, n_heads=8,
        n_kv_heads=8, head_dim=16)
    print(f"model: {cfg.count_params()/1e6:.2f}M params")
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    mgr = ckpt_lib.CheckpointManager(CKPT_DIR, keep=2)
    start = 0
    restored = mgr.restore({"params": params, "opt": opt_state})
    if restored is not None:
        state, extra = restored
        params, opt_state = state["params"], state["opt"]
        start = extra["step"]
        print(f"resumed from checkpoint at step {start}")
    step_fn = jax.jit(make_train_step(
        cfg, opt_lib.OptimizerConfig(lr=1e-3, warmup_steps=20,
                                     total_steps=steps)))
    loader = data_lib.PrefetchLoader(cfg, batch=16, seq=128, seed=0,
                                     start_step=start)
    t0 = time.time()
    for i, (step_idx, host_batch) in zip(range(start, steps), loader):
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (i + 1) % 50 == 0 or i + 1 == steps:
            print(f"step {i+1:4d} loss={float(m['loss']):.3f} "
                  f"lr={float(m['lr']):.2e} "
                  f"({(i+1-start)/(time.time()-t0):.1f} it/s)")
            mgr.save(i + 1, {"params": params, "opt": opt_state})
    loader.close()
    mgr.close()
    print("done; checkpoint in", CKPT_DIR)
