"""End-to-end serving driver: a managed cluster with the intelligent
router, batched requests, a mid-flight instance FAILURE, and an elastic
scale-out -- the router adapts (decomposed Q scores any instance count).

  PYTHONPATH=src python examples/serve_cluster.py
"""
from repro.core import rl_router as rl
from repro.core.cluster_manager import ManagedCluster, ManagedClusterConfig
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.workload import generate, to_requests

PROF = V100_LLAMA2_7B

if __name__ == "__main__":
    router_cfg = rl.RouterConfig(variant="guided", n_instances=4,
                                 explore_episodes=3, q_arch="decomposed",
                                 seed=0)
    # short warm-up training
    out = rl.train(router_cfg, PROF,
                   lambda ep: to_requests(generate(200, seed=ep),
                                          rate=20.0, seed=ep + 50),
                   n_episodes=4)
    mgr = ManagedCluster(ManagedClusterConfig(n_instances=4,
                                              checkpoint_dir="artifacts/"
                                              "router_ckpt"),
                         router_cfg, PROF, out["agent"])
    mgr.save_router(step=0)          # checkpoint the trained router
    reqs = to_requests(generate(400, seed=991), rate=20.0, seed=992)
    stats = mgr.serve(reqs, fault_plan={5.0: "fail:2", 12.0: "add",
                                        20.0: "restore:2"})
    print("== managed cluster with fault injection ==")
    for e in stats["events"]:
        print("  ", e)
    print(f"served n={stats['n']} e2e={stats['e2e_mean']:.2f}s "
          f"ttft={stats['ttft_mean']:.2f}s "
          f"preemptions={stats['preemptions']}")
    assert stats["n"] == 400, "every request must complete despite failure"
    # restart path: fresh agent, restore from checkpoint
    agent2 = rl.make_agent(router_cfg)
    mgr2 = ManagedCluster(ManagedClusterConfig(
        n_instances=4, checkpoint_dir="artifacts/router_ckpt"),
        router_cfg, PROF, agent2)
    assert mgr2.restore_router(), "router checkpoint restore failed"
    print("router checkpoint restored OK")
