"""Quickstart: route a handful of requests across two REAL (tiny) LLM
instances with the workload-aware router vs round-robin.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import impact
from repro.core.profiles import V100_LLAMA2_7B
from repro.models import params as params_lib
from repro.serving.engine import LLMInstance
from repro.serving.request import Request, summarize
from repro.serving.scheduler import FCFS


def run(policy_name: str):
    cfg = get_config("llama-2-7b").reduced()
    prof = dataclasses.replace(V100_LLAMA2_7B, capacity_tokens=300)
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    insts = [LLMInstance(cfg, params, prof, FCFS(), n_slots=4,
                         cache_len=128, instance_id=i) for i in range(2)]
    rng = np.random.default_rng(0)
    reqs = [Request(prompt_tokens=int(rng.integers(10, 60)),
                    decode_tokens=int(rng.integers(5, 50)))
            for _ in range(10)]
    rr = 0
    for r in reqs:
        if policy_name == "round_robin":
            pick = rr % 2
            rr += 1
        else:  # workload-aware impact heuristic (Eq. 1-2)
            scores = impact.mixing_per_instance(
                prof, r.prompt_tokens, r.decode_tokens,
                [i.resident_tokens() + sum(q.prompt_tokens
                                           for q in i.queue)
                 for i in insts])
            pick = int(np.argmax(scores))
        insts[pick].submit(r)
        for inst in insts:      # interleave engine iterations
            inst.step()
    while any(len(i.completed) + len([s for s in i.slots if s]) <
              0 or i.queue or any(i.slots) for i in insts):
        progressed = False
        for inst in insts:
            if inst.queue or any(inst.slots):
                inst.step()
                progressed = True
        if not progressed:
            break
    stats = summarize(reqs)
    print(f"{policy_name:14s} e2e={stats['e2e_mean']:.2f}s "
          f"ttft={stats['ttft_mean']:.3f}s n={stats['n']}")
    return stats


if __name__ == "__main__":
    print("== quickstart: 10 requests, 2 tiny real JAX instances ==")
    run("round_robin")
    run("impact")
