"""Train the workload-guided RL router (paper §5.3/§6) in the calibrated
cluster simulator and compare against round-robin + heuristics.

By default training uses the batched multi-episode runner (8 concurrent
episodes, one shared replay buffer, async learner); pass --sequential
for the paper-faithful per-decision loop.  --hetero trains on the
heterogeneous scenario stream (mixed V100/A100 clusters, bursty and
diurnal arrivals) instead of the fixed paper setup.

--predictor trains a decode-bucket predictor first and routes on its
d-hat during RL training (no oracle decode lengths in the loop).
--vec steps all episodes on the vectorized structure-of-arrays
simulator (one fused pool; identical decisions to the Python stepper).

  PYTHONPATH=src python examples/train_router_rl.py [n_episodes]
      [--sequential] [--hetero] [--predictor] [--vec]
"""
import os
import sys
import time

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

from repro.core import batched_rl, rl_router as rl          # noqa: E402
from repro.core.policies import make_policy                 # noqa: E402
from repro.core.profiles import V100_LLAMA2_7B              # noqa: E402
from repro.core.simulator import Cluster, run_heuristic     # noqa: E402
from repro.core.workload import (Scenario, generate,        # noqa: E402
                                 scenario_stream, to_requests)
from repro.training.train_loop import train_router          # noqa: E402

PROF = V100_LLAMA2_7B
N, RATE, M = 400, 20.0, 4


def reqs(seed):
    return to_requests(generate(N, seed=seed), rate=RATE, seed=seed + 5000)


def scen(seed, name):
    """Homogeneous paper-setup scenario WITH prompt content kept, so the
    learned length predictor can replace the oracle decode length."""
    samples = generate(N, seed=seed)
    return Scenario.homogeneous(
        PROF, M, to_requests(samples, rate=RATE, seed=seed + 5000),
        name=name, samples=samples)


if __name__ == "__main__":
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    episodes = int(args[0]) if args else 12
    sequential = "--sequential" in sys.argv
    hetero = "--hetero" in sys.argv
    use_predictor = "--predictor" in sys.argv
    backend = ("jax" if "--jax" in sys.argv
               else "vec" if "--vec" in sys.argv else "py")
    for name in ("round_robin", "jsq", "impact_greedy"):
        st = run_heuristic(Cluster(PROF, M), reqs(991),
                           make_policy(name, PROF))
        print(f"{name:16s} e2e={st['e2e_mean']:7.2f}s "
              f"ttft={st['ttft_mean']:6.2f}s preempt={st['preemptions']}")
    cfg = rl.RouterConfig(variant="guided", n_instances=M,
                          explore_episodes=max(episodes - 4, 2),
                          q_arch="decomposed", seed=0)
    if hetero:
        scen_fn = scenario_stream(0, n_requests=N)
        bcfg = batched_rl.BatchedRLConfig(m_max=6, backend=backend)
    else:
        scen_fn = lambda ep: scen(100 + ep, f"paper-{ep}")  # noqa: E731
        bcfg = batched_rl.BatchedRLConfig(m_max=M, backend=backend)
    predictor = None
    if use_predictor:
        from repro.core.predictor import quick_bucket_predictor
        print("training length predictor (d-hat replaces the oracle)...")
        predictor = quick_bucket_predictor(PROF, n_train=2000, epochs=2)
    t0 = time.time()
    out = train_router(
        cfg, scen_fn, episodes, batched=not sequential, batch_cfg=bcfg,
        length_predictor=predictor,
        valid_fn=lambda: scen(555, "valid"),
        verbose=True)
    dt = time.time() - t0
    mode = "sequential" if sequential else f"batched/{backend}"
    print(f"[{mode}] {episodes} episodes in {dt:.1f}s "
          f"({episodes / dt:.2f} eps/s)")
    st = batched_rl.evaluate_scenarios(
        cfg, out["agent"], [Scenario.homogeneous(PROF, M, reqs(991))])[0]
    print(f"{'rl_guided':16s} e2e={st['e2e_mean']:7.2f}s "
          f"ttft={st['ttft_mean']:6.2f}s preempt={st['preemptions']} "
          f"router_wait={st['router_wait_mean']:.2f}s")
