"""Train the workload-guided RL router (paper §5.3/§6) in the calibrated
cluster simulator and compare against round-robin + heuristics.

  PYTHONPATH=src python examples/train_router_rl.py [n_episodes]
"""
import sys

import numpy as np

from repro.core import rl_router as rl
from repro.core.policies import make_policy
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.simulator import Cluster, run_heuristic
from repro.core.workload import generate, to_requests

PROF = V100_LLAMA2_7B
N, RATE, M = 400, 20.0, 4


def reqs(seed):
    return to_requests(generate(N, seed=seed), rate=RATE, seed=seed + 5000)


if __name__ == "__main__":
    episodes = int(sys.argv[1]) if len(sys.argv) > 1 else 12
    for name in ("round_robin", "jsq", "impact_greedy"):
        st = run_heuristic(Cluster(PROF, M), reqs(991),
                           make_policy(name, PROF))
        print(f"{name:16s} e2e={st['e2e_mean']:7.2f}s "
              f"ttft={st['ttft_mean']:6.2f}s preempt={st['preemptions']}")
    cfg = rl.RouterConfig(variant="guided", n_instances=M,
                          explore_episodes=max(episodes - 4, 2),
                          q_arch="decomposed", seed=0)
    out = rl.train(cfg, PROF, lambda ep: reqs(100 + ep), episodes,
                   valid_fn=lambda: reqs(555), verbose=True)
    st = rl.evaluate(cfg, PROF, out["agent"], reqs(991))
    print(f"{'rl_guided':16s} e2e={st['e2e_mean']:7.2f}s "
          f"ttft={st['ttft_mean']:6.2f}s preempt={st['preemptions']} "
          f"router_wait={st['router_wait_mean']:.2f}s")
