"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``.  Configs are
plain frozen dataclasses (hashable -> usable as jit static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block configuration (routed + optional shared)."""

    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    n_shared: int = 0             # shared (always-on) experts, deepseek-style
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    impl: str = "ragged"     # "dense" | "ragged" | "ep" (EP shard_map)


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek-V2 / MiniCPM3 style)."""

    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MambaConfig:
    """Mamba-1 selective SSM block configuration."""

    d_inner: int
    d_state: int = 16
    d_conv: int = 4
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)
    chunk: int = 256              # chunked-scan chunk length


@dataclass(frozen=True)
class ModelConfig:
    """A decoder-only LM backbone (covers all 10 assigned archs)."""

    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # layer pattern: repeated period of layer kinds
    # ("attn" | "mamba" | "cross").
    layer_pattern: Tuple[str, ...] = ("attn",)
    # which positions in the period use MoE instead of a dense FFN
    moe_pattern: Tuple[bool, ...] = (False,)
    activation: str = "silu"      # silu | gelu | relu
    gated_mlp: bool = True        # GLU-style gate (GeGLU / SwiGLU)
    attention: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    logit_softcap: float = 0.0   # gemma/grok final-logit softcap (0=off)
    scale_embeddings: bool = False  # gemma: multiply embeddings by sqrt(d)
    tie_embeddings: bool = False
    dense_first_layer: bool = False   # deepseek-moe: layer 0 uses a dense FFN
    dense_first_d_ff: int = 0         # hidden dim for that dense first layer
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    # modality frontends (stubs: input_specs() provides precomputed embeddings)
    input_mode: str = "tokens"    # tokens | embeddings (audio stub)
    n_codebooks: int = 0          # musicgen: parallel codebook heads
    vision_tokens: int = 0        # llama-3.2-vision: # of image tokens
    vision_dim: int = 0           # dim of the (stub) vision embeddings
    max_seq_len: int = 32768
    dtype: str = "bfloat16"
    # int8-quantized KV cache (per-token-per-head dynamic scales): halves
    # the decode step's dominant HBM term (beyond-paper serving
    # optimization; §Perf hillclimb 3)
    kv_cache_dtype: str = "bfloat16"      # "bfloat16" | "int8"
    # sub-quadratic? (permits long_500k decode)
    subquadratic: bool = False
    use_pallas: bool = False      # swap in Pallas kernels (TPU target)
    remat: bool = True
    # unroll every internal lax.scan (layers, blocked attention, chunked
    # CE, ssm chunks).  Used by the dry-run's shallow analysis compiles:
    # XLA cost_analysis counts a while-loop body ONCE regardless of trip
    # count, so roofline flops are extrapolated from two unrolled
    # shallow-depth compiles instead.
    scan_unroll: bool = False    # activation ckpting on the layer scan

    # ---- derived helpers -------------------------------------------------
    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def n_periods(self) -> int:
        assert self.n_scan_layers % self.period == 0, (
            f"{self.name}: {self.n_scan_layers} layers not divisible by "
            f"period {self.period}")
        return self.n_scan_layers // self.period

    @property
    def n_scan_layers(self) -> int:
        """Layers inside the scan (excludes a special dense first layer)."""
        return self.n_layers - (1 if self.dense_first_layer else 0)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def dt_rank(self) -> int:
        if self.mamba is None:
            return 0
        return self.mamba.dt_rank or -(-self.d_model // 16)

    def layer_kind(self, pos: int) -> str:
        return self.layer_pattern[pos % self.period]

    def layer_is_moe(self, pos: int) -> bool:
        return self.moe_pattern[pos % self.period]

    def count_params(self) -> int:
        """Analytic parameter count (matches init_params; roofline)."""
        from repro.models.params import count_params
        return count_params(self)

    def count_active_params(self) -> int:
        from repro.models.params import count_params
        return count_params(self, active_only=True)

    def reduced(self, **overrides) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=max(self.period * 2,
                         2 + (1 if self.dense_first_layer else 0)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads > 1 else 1,
            d_ff=128,
            vocab_size=512,
            head_dim=16,
            max_seq_len=128,
            dtype="float32",
            remat=False,
        )
        if self.dense_first_layer:
            changes["dense_first_d_ff"] = 128
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2), d_expert=32,
                n_shared=min(self.moe.n_shared, 1), impl="ragged")
        if self.mla is not None:
            changes["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                       qk_nope_head_dim=16, qk_rope_head_dim=8,
                                       v_head_dim=16)
        if self.mamba is not None:
            changes["mamba"] = dataclasses.replace(
                self.mamba, d_inner=128, d_state=8, dt_rank=8, chunk=16)
        if self.vision_tokens:
            changes["vision_tokens"] = 16
            changes["vision_dim"] = 32
        changes.update(overrides)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str   # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """The assigned shape set for an architecture (long_500k only for
    sub-quadratic archs, per the assignment)."""
    if cfg.subquadratic:
        return ALL_SHAPES
    return (TRAIN_4K, PREFILL_32K, DECODE_32K)
