"""jax version compatibility (the repo supports jax>=0.4.30).

Centralizes the handful of symbols whose home moved between jax 0.4 and
0.5/0.6 so call sites stay clean.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):            # jax >= 0.6
    shard_map = jax.shard_map
else:                                    # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map_04

    def shard_map(f=None, /, **kw):
        # the replication-check kwarg was renamed check_rep -> check_vma
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        if f is None:
            return lambda g: _shard_map_04(g, **kw)
        return _shard_map_04(f, **kw)
