"""gemma-7b  [dense]  [arXiv:2403.08295; hf]

28L d_model=3072 16H (GQA kv=16, i.e. MHA) d_ff=24576 vocab=256000,
GeGLU, head_dim=256, RoPE.  Gemma scales embeddings by sqrt(d_model) and
softcaps final logits.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    activation="gelu",         # GeGLU
    gated_mlp=True,
    scale_embeddings=True,
    tie_embeddings=True,
    logit_softcap=30.0,
    rope_theta=10000.0,
    max_seq_len=32768,
)
