"""starcoder2-7b  [dense]  [arXiv:2402.19173; hf]

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152, GQA, RoPE,
non-gated GELU MLP (d_ff = 4*d).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    head_dim=128,
    activation="gelu",
    gated_mlp=False,
    rope_theta=1e6,
    max_seq_len=32768,
)
