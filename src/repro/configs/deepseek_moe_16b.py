"""deepseek-moe-16b  [moe]  [arXiv:2401.06066; hf]

28L d_model=2048 16H (MHA kv=16) vocab=102400; fine-grained MoE:
64 routed experts (d_expert=1408) top-6 + 2 shared experts; first layer
is a dense FFN (d_ff=10944).
"""
from repro.common.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    head_dim=128,
    dense_first_layer=True,
    dense_first_d_ff=10944,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  impl="ep"),
    moe_pattern=(True,),
    activation="silu",
    gated_mlp=True,
    max_seq_len=32768,
)
