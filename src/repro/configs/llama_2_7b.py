"""llama-2-7b  [dense]  [arXiv:2307.09288; hf]

The paper's own serving model (Touvron et al. 2023): 32L d_model=4096
32H (MHA) d_ff=11008 vocab=32000.  Used by the reproduction experiments
(profiling gradients, router training) and as the 11th config.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-2-7b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=32000,
    head_dim=128,
    activation="silu",
    gated_mlp=True,
    max_seq_len=4096,
)
