"""llama-3.2-vision-11b  [vlm]  [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 -- gated
cross-attention image layers every 5th layer (8 of 40).  BACKBONE ONLY:
the vision tower is a stub; ``input_specs()`` provides precomputed patch
embeddings [B, 1024, 1280] consumed via a linear projection.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    layer_pattern=("attn", "attn", "attn", "attn", "cross"),
    moe_pattern=(False,) * 5,
    vision_tokens=1024,
    vision_dim=1280,
    activation="silu",
    gated_mlp=True,
    rope_theta=5e5,
    max_seq_len=32768,
)
