"""jamba-v0.1-52b  [hybrid]  [arXiv:2403.19887; hf]

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; Mamba+attention
1:7 interleave (attention at offset 4 of every 8-layer block), MoE 16
experts top-2 on every other layer.  Sub-quadratic enough for long_500k:
only 4/32 layers hold KV (SP-sharded); the rest carry O(1) SSM state.
"""
from repro.common.config import MambaConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=65536,
    head_dim=128,
    layer_pattern=("mamba", "mamba", "mamba", "mamba",
                   "attn", "mamba", "mamba", "mamba"),
    moe_pattern=(False, True, False, True, False, True, False, True),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=14336, impl="ep"),
    mamba=MambaConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    activation="silu",
    gated_mlp=True,
    subquadratic=True,
    max_seq_len=524288,
)
