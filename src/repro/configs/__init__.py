"""Architecture registry: ``--arch <id>`` -> ModelConfig."""
from __future__ import annotations

from typing import Dict

from repro.common.config import ModelConfig

from repro.configs.gemma_7b import CONFIG as _gemma
from repro.configs.starcoder2_7b import CONFIG as _starcoder2
from repro.configs.minicpm3_4b import CONFIG as _minicpm3
from repro.configs.qwen3_0_6b import CONFIG as _qwen3
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.musicgen_medium import CONFIG as _musicgen
from repro.configs.llama_3_2_vision_11b import CONFIG as _llama_vision
from repro.configs.jamba_v0_1_52b import CONFIG as _jamba
from repro.configs.llama_2_7b import CONFIG as _llama2

REGISTRY: Dict[str, ModelConfig] = {
    "gemma-7b": _gemma,
    "starcoder2-7b": _starcoder2,
    "minicpm3-4b": _minicpm3,
    "qwen3-0.6b": _qwen3,
    "falcon-mamba-7b": _falcon_mamba,
    "grok-1-314b": _grok,
    "deepseek-moe-16b": _deepseek,
    "musicgen-medium": _musicgen,
    "llama-3.2-vision-11b": _llama_vision,
    "jamba-v0.1-52b": _jamba,
    "llama-2-7b": _llama2,
}

ASSIGNED = tuple(k for k in REGISTRY if k != "llama-2-7b")


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
