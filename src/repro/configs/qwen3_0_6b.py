"""qwen3-0.6b  [dense]  [hf:Qwen/Qwen3-8B family; hf]

28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936 -- qk_norm, GQA,
head_dim=128 (explicit, 16*128 != d_model).
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=3072,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    activation="silu",
    gated_mlp=True,
    tie_embeddings=True,
    rope_theta=1e6,
    max_seq_len=32768,
)
