"""musicgen-medium  [audio]  [arXiv:2306.05284; hf]

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048 -- decoder-only
over EnCodec tokens.  BACKBONE ONLY: the EnCodec frontend is a stub;
``input_specs()`` provides precomputed frame embeddings [B,S,d] (sum of
the 4 codebook embeddings), and the head predicts the 2048-way codebook.
"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    input_mode="embeddings",
    n_codebooks=4,
    activation="gelu",
    gated_mlp=False,
    max_seq_len=32768,
)
