"""grok-1-314b  [moe]  [hf:xai-org/grok-1; unverified]

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072, MoE 8 experts
top-2, logit softcap.  Expert-parallel (EP over the data axis) + TP.
"""
from repro.common.config import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768, impl="ep"),
    moe_pattern=(True,),
    logit_softcap=30.0,
    activation="gelu",
    gated_mlp=True,
    max_seq_len=32768,
)
