"""falcon-mamba-7b  [ssm]  [arXiv:2410.05355; unverified]

64L d_model=4096, attention-free mamba-1 blocks (d_inner=8192,
ssm_state=16, d_conv=4, dt_rank=256), vocab=65024.  Sub-quadratic:
runs the long_500k cell (decode state is O(1) in context length).
"""
from repro.common.config import MambaConfig, ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,                    # pure SSM: no FFN sub-block
    vocab_size=65024,
    head_dim=64,
    attention="none",
    layer_pattern=("mamba",),
    mamba=MambaConfig(d_inner=8192, d_state=16, d_conv=4, dt_rank=256),
    subquadratic=True,
    max_seq_len=524288,
)
