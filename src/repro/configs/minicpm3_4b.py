"""minicpm3-4b  [dense, MLA]  [hf:openbmb/MiniCPM3-4B; hf]

62L d_model=2560 40H d_ff=6400 vocab=73448 -- multi-head latent attention
(compressed KV cache: kv_lora_rank=256 + 32 rope dims per token).
"""
from repro.common.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    head_dim=96,               # qk_nope(64) + qk_rope(32)
    attention="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                  qk_nope_head_dim=64, qk_rope_head_dim=32,
                  v_head_dim=64),
    activation="silu",
    gated_mlp=True,
    max_seq_len=32768,
)
