"""Seeded fault injection + per-instance health tracking.

A production router is ranked by how it behaves when the fleet is NOT
healthy: nodes crash and restart, stragglers serve at a fraction of
nominal speed, and tenants burst together.  This module makes those
conditions first-class and *deterministic*:

  * :class:`FaultSchedule` -- an immutable, seed-constructible script of
    :class:`Crash` (fail at ``t``, optionally restart ``restart_after``
    seconds later), :class:`Straggler` (a ``[t0, t1)`` window during
    which one instance's iteration times are scaled by ``factor``) and
    :class:`TenantBurst` (correlated extra arrivals for one tenant)
    events.  The same schedule replays bit-identically against the
    Python stepper, the vectorized simulator, and the real-engine
    adapter -- all three expose ``fail_instance(idx, requeue=...)``,
    ``recover_instance(idx)`` and ``set_speed_factor(idx, f)``.
  * :class:`ChaosInjector` -- applies a schedule's due events at the top
    of each gateway tick.  Crash orphans are handed to an optional
    callback (the gateway's bounded-retry failover) instead of being
    silently requeued.
  * :class:`HealthTracker` -- per-instance EWMA of *realized* TBT plus
    a decayed bad-event rate (cancels, hedges), driving a circuit
    breaker: an instance whose EWMA exceeds ``breaker_factor`` x the
    fleet median is removed from every policy's candidate set for
    ``cooldown_s``, then re-probed.  The tracker never opens the breaker
    on the entire alive fleet (guarded fallback: a degraded instance
    beats no instance).

Determinism contract: every float the tracker consumes is a bit-equal
request field on the py and vec backends (TBT is derived as
``(finished - first_token) / (decoded - 1)`` rather than from the
vec-synthesized ``token_times``), and per-instance completion order is
identical, so health decisions -- and therefore routing decisions --
stay bit-exact under injected faults (tests/test_chaos.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request

# -- fault schedule ----------------------------------------------------------

#: tie-break rank for events sharing a timestamp: a crash precedes the
#: recovery of another instance, slowdowns apply last
_KIND_RANK = {"fail": 0, "recover": 1, "slow": 2}


@dataclass(frozen=True)
class Crash:
    t: float
    instance: int
    restart_after: Optional[float] = None   # None = permanent loss


@dataclass(frozen=True)
class Straggler:
    t0: float
    t1: float
    instance: int
    factor: float = 3.0                     # iteration-time multiplier


@dataclass(frozen=True)
class TenantBurst:
    t0: float
    t1: float
    tenant: str
    rate: float = 4.0                       # extra arrivals/s in window


@dataclass(frozen=True)
class FaultSchedule:
    crashes: Tuple[Crash, ...] = ()
    stragglers: Tuple[Straggler, ...] = ()
    bursts: Tuple[TenantBurst, ...] = ()

    @classmethod
    def random(cls, seed: int, m: int, horizon: float,
               n_crashes: int = 1, n_stragglers: int = 1,
               n_bursts: int = 0, tenants: Sequence[str] = ("default",),
               restart_range: Tuple[float, float] = (5.0, 20.0),
               slow_range: Tuple[float, float] = (2.0, 5.0),
               burst_rate: float = 4.0) -> "FaultSchedule":
        """Seed-driven schedule: faults land in the first 60% of the
        horizon so their fallout is observable before the run ends."""
        rng = np.random.default_rng(seed)
        crashes = tuple(
            Crash(float(rng.uniform(0.1 * horizon, 0.6 * horizon)),
                  int(rng.integers(0, m)),
                  float(rng.uniform(*restart_range)))
            for _ in range(n_crashes))
        stragglers = []
        for _ in range(n_stragglers):
            t0 = float(rng.uniform(0.1 * horizon, 0.6 * horizon))
            dur = float(rng.uniform(0.1 * horizon, 0.3 * horizon))
            stragglers.append(
                Straggler(t0, min(t0 + dur, horizon),
                          int(rng.integers(0, m)),
                          float(rng.uniform(*slow_range))))
        bursts = []
        for _ in range(n_bursts):
            t0 = float(rng.uniform(0.1 * horizon, 0.7 * horizon))
            bursts.append(
                TenantBurst(t0, min(t0 + 0.15 * horizon, horizon),
                            tenants[int(rng.integers(0, len(tenants)))],
                            burst_rate))
        return cls(crashes, tuple(stragglers), tuple(bursts))

    def events(self) -> List[Tuple[float, str, int, float]]:
        """Flatten to a time-sorted ``(t, kind, instance, arg)`` list
        with a deterministic tie order (kind rank, then instance)."""
        ev: List[Tuple[float, str, int, float]] = []
        for c in self.crashes:
            ev.append((c.t, "fail", c.instance, 0.0))
            if c.restart_after is not None:
                ev.append((c.t + c.restart_after, "recover",
                           c.instance, 0.0))
        for s in self.stragglers:
            ev.append((s.t0, "slow", s.instance, s.factor))
            ev.append((s.t1, "slow", s.instance, 1.0))
        ev.sort(key=lambda e: (e[0], _KIND_RANK[e[1]], e[2]))
        return ev


def inject_bursts(requests: Sequence[Request],
                  schedule: FaultSchedule, seed: int = 0
                  ) -> List[Request]:
    """Correlated tenant bursts: clone the burst tenant's own request
    shapes at Poisson arrivals inside each burst window (fresh rids, so
    the originals are untouched).  Returns base + burst requests; a
    no-op for schedules without bursts."""
    out = list(requests)
    if not schedule.bursts or not requests:
        return out
    rng = np.random.default_rng(seed)
    next_rid = max(r.rid for r in requests) + 1
    for b in schedule.bursts:
        donors = [r for r in requests if r.tenant == b.tenant] \
            or list(requests)
        t = b.t0
        while True:
            t += float(rng.exponential(1.0 / b.rate))
            if t >= b.t1:
                break
            d = donors[int(rng.integers(0, len(donors)))]
            out.append(Request(prompt_tokens=d.prompt_tokens,
                               decode_tokens=d.decode_tokens,
                               arrival=t, task=d.task, rid=next_rid,
                               tenant=b.tenant))
            next_rid += 1
    return out


# -- injector ----------------------------------------------------------------

class ChaosInjector:
    """Replay a :class:`FaultSchedule` against any Cluster-protocol
    backend, applying every event whose time has come at the top of a
    tick.  ``on_orphans`` (gateway failover) takes ownership of crash
    fallout; without it orphans requeue centrally like the legacy
    ``Cluster.fail_instance`` path."""

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self._events = schedule.events()
        self._i = 0
        self.log: List[Tuple[float, str, int, float]] = []

    @property
    def pending(self) -> int:
        return len(self._events) - self._i

    def step(self, cluster, t: float, on_orphans=None
             ) -> List[Tuple[str, int, float]]:
        applied: List[Tuple[str, int, float]] = []
        while self._i < len(self._events) \
                and self._events[self._i][0] <= t:
            _, kind, idx, arg = self._events[self._i]
            self._i += 1
            if idx >= cluster.m:
                continue            # schedule written for a larger fleet
            if kind == "fail":
                if idx not in cluster.alive():
                    continue        # already down
                orphans = cluster.fail_instance(
                    idx, requeue=(on_orphans is None))
                if on_orphans is not None:
                    on_orphans(orphans)
            elif kind == "recover":
                cluster.recover_instance(idx)
            else:
                cluster.set_speed_factor(idx, arg)
            self.log.append((t, kind, idx, arg))
            applied.append((kind, idx, arg))
        return applied


# -- health tracking ---------------------------------------------------------

class HealthTracker:
    """Per-instance health from *realized* service quality.

    Signal 1 is an EWMA of each completion's mean time-between-tokens,
    computed as ``(finished - first_token) / (decoded - 1)`` -- a pure
    function of bit-equal request fields on every backend.  Signal 2 is
    a decayed count of bad events (client cancels, hedged re-dispatches)
    attributed to the instance.  ``assess`` maps both into a degradation
    score in [0, 1] (0 = at the fleet median, 1 = at the breaker
    threshold) and opens a circuit breaker at score >= 1: the instance
    leaves every policy's candidate set for ``cooldown_s``, after which
    its history is forgotten and fresh samples decide again."""

    def __init__(self, m: int, alpha: float = 0.3,
                 breaker_factor: float = 2.5, min_samples: int = 8,
                 cooldown_s: float = 30.0, bad_weight: float = 0.25,
                 bad_decay: float = 0.995):
        self.alpha = alpha
        self.factor = breaker_factor
        self.min_samples = min_samples
        self.cooldown_s = cooldown_s
        self.bad_weight = bad_weight
        self.bad_decay = bad_decay
        self.m = 0
        self.ewma: List[float] = []
        self.n: List[int] = []
        self.bad: List[float] = []
        self.open_until: List[float] = []
        self.trips = 0
        self.ensure(m)

    def ensure(self, m: int):
        """Grow to ``m`` instances (autoscaling adds healthy nodes)."""
        while self.m < m:
            self.ewma.append(0.0)
            self.n.append(0)
            self.bad.append(0.0)
            self.open_until.append(-float("inf"))
            self.m += 1

    def reset(self, idx: int):
        """Forget an instance's history (it recovered as a fresh node)."""
        self.ewma[idx] = 0.0
        self.n[idx] = 0
        self.bad[idx] = 0.0
        self.open_until[idx] = -float("inf")

    def on_complete(self, idx: int, req: Request):
        if req.first_token is None or req.finished is None \
                or req.decoded < 2:
            return
        x = (req.finished - req.first_token) / (req.decoded - 1)
        if self.n[idx] == 0:
            self.ewma[idx] = x
        else:
            self.ewma[idx] = (self.alpha * x
                              + (1.0 - self.alpha) * self.ewma[idx])
        self.n[idx] += 1

    def on_bad(self, idx: int):
        self.bad[idx] += 1.0

    def assess(self, t: float, alive: Sequence[int]
               ) -> Tuple[np.ndarray, np.ndarray]:
        """-> (healthy mask [m], degradation scores [m] in [0, 1])."""
        m = self.m
        scores = np.zeros(m)
        sampled = [i for i in alive if self.n[i] >= self.min_samples]
        med = (float(np.median([self.ewma[i] for i in sampled]))
               if sampled else 0.0)
        for i in range(m):
            self.bad[i] *= self.bad_decay
            rel = 0.0
            if med > 0.0 and self.n[i] >= self.min_samples:
                rel = (self.ewma[i] / med - 1.0) / (self.factor - 1.0)
            s = rel + self.bad_weight * self.bad[i]
            scores[i] = min(max(s, 0.0), 1.0)
        mask = np.ones(m, bool)
        for i in range(m):
            if t < self.open_until[i]:
                mask[i] = False
            elif scores[i] >= 1.0:
                # trip: open for cooldown_s, then forget and re-probe
                self.open_until[i] = t + self.cooldown_s
                self.ewma[i] = 0.0
                self.n[i] = 0
                self.bad[i] = 0.0
                self.trips += 1
                mask[i] = False
        if len(alive) and not any(mask[i] for i in alive):
            # guarded fallback: never breaker-out the whole alive fleet
            for i in alive:
                mask[i] = True
        return mask, scores
