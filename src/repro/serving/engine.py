"""A real JAX LLM-instance engine: slot-based continuous batching over the
model zoo, with a virtual clock driven by the calibrated hardware profile.

One ``LLMInstance`` = one model replica (on the production mesh: one
"model"-axis slice).  It owns
  * a jitted prefill (batch-1) + slot-insert + gang decode step,
  * a local admission queue ordered by an instance-level scheduler
    (FCFS / bin-packing / least-work-left),
  * a paged-token capacity budget with preemption (newest-first eviction,
    as in vLLM) when decode growth overflows the budget,
  * per-request lifecycle metrics (TTFT / TBT / E2E) on the virtual clock.

The engine is exercised with reduced configs on CPU (examples, tests); the
discrete-event simulator in ``repro.core.simulator`` reproduces the paper's
timing experiments at scale using the same Request/scheduler abstractions.
"""
from __future__ import annotations

import functools
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.prefix_cache import PrefixCache
from repro.core.profiles import HardwareProfile
from repro.models import model as model_lib
from repro.serving import trace as _trace
from repro.serving.request import Phase, Request
from repro.serving.scheduler import InstanceScheduler


@functools.lru_cache(maxsize=32)
def _build_fns(cfg: ModelConfig, cache_len: int):
    prefill = jax.jit(
        lambda params, tokens: model_lib.prefill(params, cfg, tokens=tokens,
                                                 cache_len=cache_len))

    def insert(cache, new, slot):
        def one(path, full, small):
            names = [str(getattr(k, "key", "")) for k in path]
            axis = 1 if "layers" in names else 0
            start = [0] * full.ndim
            start[axis] = slot
            return jax.lax.dynamic_update_slice(full, small.astype(
                full.dtype), tuple(start))
        out = jax.tree_util.tree_map_with_path(one, cache, new)
        return out

    insert_j = jax.jit(insert, donate_argnums=(0,))
    decode = jax.jit(
        lambda params, cache, toks: model_lib.decode_step(params, cfg, cache,
                                                          tokens=toks),
        donate_argnums=(1,))
    return prefill, insert_j, decode


class LLMInstance:
    def __init__(self, cfg: ModelConfig, params, profile: HardwareProfile,
                 scheduler: InstanceScheduler, n_slots: int = 8,
                 cache_len: int = 256, instance_id: int = 0,
                 prefix_cache_tokens: int = 0, prefix_block: int = 32):
        assert cfg.input_mode == "tokens", "engine path uses token inputs"
        self.cfg, self.params, self.profile = cfg, params, profile
        self.scheduler = scheduler
        self.n_slots, self.cache_len = n_slots, cache_len
        self.instance_id = instance_id
        self.prefill_fn, self.insert_fn, self.decode_fn = _build_fns(
            cfg, cache_len)
        self.cache = model_lib.init_cache(cfg, n_slots, cache_len)
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.next_tokens = np.zeros((n_slots,), np.int32)
        self.queue: deque = deque()
        self.clock = 0.0
        self.completed: List[Request] = []
        self.failed = False
        # straggler model: scales every virtual-clock iteration time
        # (1.0 = nominal), same semantics as SimInstance.speed_factor
        self.speed_factor = 1.0
        # prefix/KV cache model (core.prefix_cache): the real prefill
        # still runs in full (model correctness -- the reduced configs
        # here don't share KV across slots), but the VIRTUAL clock
        # charges only the uncached suffix, which is the quantity the
        # simulator's fidelity harness validates.
        self.prefix_cache = (PrefixCache(prefix_cache_tokens,
                                         prefix_block)
                             if prefix_cache_tokens > 0 else None)
        # lifecycle tracing (serving.trace).  The virtual clock is
        # advanced BEFORE the decode pass (see step()), so first-token
        # and completion stamps land at the iteration's END -- the same
        # anchor as the simulator, letting fidelity deltas compare
        # like-for-like.  ``trace_instance`` is the id used in events --
        # EngineClusterAdapter.set_trace rewrites it to the adapter
        # index so lanes line up with the gateway's routing ids.
        self.trace = _trace.NULL
        self.trace_instance = instance_id

    # -- router-visible state ----------------------------------------------
    @property
    def resident(self) -> List[Request]:
        return [r for r in self.slots if r is not None]

    def resident_tokens(self) -> int:
        return sum(r.total_context for r in self.resident)

    def free_tokens(self) -> int:
        return self.profile.capacity_tokens - self.resident_tokens() \
            - sum(r.prompt_tokens + r.decoded for r in self.queue)

    def load_summary(self) -> Dict:
        res = self.resident
        return {
            "n_resident": len(res),
            "n_queued": len(self.queue),
            "p_tokens": [r.prompt_tokens for r in res],
            "d_tokens": [r.decoded for r in res],
            "resident_tokens": self.resident_tokens(),
            "free_tokens": self.free_tokens(),
            "clock": self.clock,
        }

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request):
        req.phase = Phase.INSTANCE_QUEUE
        req.instance = self.instance_id
        req.routed_at = self.clock
        self.queue.append(req)

    # -- one engine iteration -------------------------------------------------
    def step(self) -> List[Request]:
        """Admit + prefill (at most one request/iteration, vLLM-style) then
        gang-decode every active slot.  Returns requests completed at this
        iteration; advances the virtual clock."""
        if self.failed:
            return []
        prefill_tokens = 0
        # admission (scheduler's choice among queued)
        free_slot = next((i for i, s in enumerate(self.slots) if s is None),
                         None)
        if free_slot is not None and self.queue:
            budget = self.profile.capacity_tokens - self.resident_tokens()
            pick = self.scheduler.pick(list(self.queue), budget,
                                       self.profile)
            if pick is not None:
                req = self.queue[pick]
                del self.queue[pick]
                self._admit(req, free_slot)
                cached = 0
                if self.prefix_cache is not None and req.prefix_hashes:
                    cached = self.prefix_cache.admit(req.prompt_tokens,
                                                     req.prefix_hashes)
                    req.cached_prefix = cached
                # cached prefix costs no prefill compute on the virtual
                # clock; it re-enters iteration_time as resident
                # context below (same split as SimInstance)
                prefill_tokens += req.prompt_tokens - cached
                if self.trace.enabled:
                    self.trace.emit(self.clock, _trace.EV_INST_ADMIT,
                                    req.rid, self.trace_instance,
                                    req.tenant, {"cached": int(cached)})
                    self.trace.emit(self.clock, _trace.EV_PREFILL_DONE,
                                    req.rid, self.trace_instance,
                                    req.tenant)
        # charge the iteration BEFORE running the decode pass: the
        # resident-context term is the pre-decode sum and the tokens
        # produced this iteration are stamped at its END, exactly like
        # SimInstance._iteration (TTFT/E2E anchors compare
        # like-for-like in the fidelity harness)
        resident_other = max(self.resident_tokens() - prefill_tokens, 0)
        self.clock += self.profile.iteration_time(
            prefill_tokens, resident_other) * self.speed_factor
        completions = self._decode_iteration()
        # capacity enforcement: evict newest-admitted if over budget
        while (self.resident_tokens() > self.profile.capacity_tokens
               and len(self.resident) > 1):
            self._preempt_newest()
        return completions

    def _admit(self, req: Request, slot: int):
        toks = req.tokens
        if toks is None:
            rng = np.random.default_rng(req.rid)
            toks = rng.integers(0, self.cfg.vocab_size,
                                size=(req.prompt_tokens,))
        toks = jnp.asarray(np.asarray(toks, np.int32)[None, :])
        logits, small = self.prefill_fn(self.params, toks)
        self.cache = self.insert_fn(self.cache, small, slot)
        self.slots[slot] = req
        self.next_tokens[slot] = int(jnp.argmax(logits[0]))
        req.phase = Phase.DECODE
        req.prefilled = req.prompt_tokens
        req.prefill_done = self.clock

    def _decode_iteration(self) -> List[Request]:
        active = [i for i, r in enumerate(self.slots) if r is not None]
        done: List[Request] = []
        if not active:
            return done
        toks = jnp.asarray(self.next_tokens)
        logits, self.cache = self.decode_fn(self.params, self.cache, toks)
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        tr = self.trace
        for i in active:
            r = self.slots[i]
            r.decoded += 1
            if r.first_token is None:
                r.first_token = self.clock
                if tr.enabled:
                    tr.emit(self.clock, _trace.EV_FIRST_TOKEN, r.rid,
                            self.trace_instance, r.tenant)
            r.token_times.append(self.clock)
            self.next_tokens[i] = nxt[i]
            if r.decoded >= r.decode_tokens:
                r.phase = Phase.DONE
                r.finished = self.clock
                if tr.enabled:
                    tr.emit(self.clock, _trace.EV_COMPLETE, r.rid,
                            self.trace_instance, r.tenant)
                if self.prefix_cache is not None and r.full_hashes:
                    self.prefix_cache.insert(r.full_hashes)
                self.completed.append(r)
                self.slots[i] = None
                done.append(r)
        return done

    def _preempt_newest(self):
        cands = [(r.prefill_done or 0.0, i) for i, r in
                 enumerate(self.slots) if r is not None]
        if len(cands) <= 1:     # never evict the last resident (liveness)
            return
        _, i = max(cands)
        req = self.slots[i]
        self.slots[i] = None
        if self.trace.enabled:
            self.trace.emit(self.clock, _trace.EV_PREEMPT, req.rid,
                            self.trace_instance, req.tenant,
                            {"lost": int(req.prefilled + req.decoded)})
        req.reset_progress()
        self.queue.appendleft(req)

    # -- fault injection (cluster manager) -----------------------------------
    def fail(self) -> List[Request]:
        """Kill the instance; return in-flight + queued requests for
        re-routing (idempotent: their progress is reset)."""
        self.failed = True
        if self.trace.enabled:
            self.trace.emit(self.clock, _trace.EV_FAIL, -1,
                            self.trace_instance)
        orphans = [r for r in self.slots if r is not None] + list(self.queue)
        self.slots = [None] * self.n_slots
        self.queue.clear()
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        for r in orphans:
            r.reset_progress()
            r.phase = Phase.QUEUED
            r.instance = None
            # the attempt died: clear timing stamps so TTFT/TBT/E2E
            # measure the attempt that actually serves the request
            r.first_token = None
            r.token_times = []
            r.prefill_done = None
        return orphans

    def recover(self):
        """Undo :meth:`fail`: the instance comes back empty (cold KV)
        at its current clock and resumes accepting work."""
        self.failed = False
        if self.trace.enabled:
            self.trace.emit(self.clock, _trace.EV_RECOVER, -1,
                            self.trace_instance)

    def steal(self, req: Request) -> bool:
        """Withdraw a routed request for hedged re-dispatch; returns
        False if it is no longer here (completed this step)."""
        if req in self.queue:
            self.queue.remove(req)
        else:
            slot = next((i for i, r in enumerate(self.slots)
                         if r is req), None)
            if slot is None:
                return False
            self.slots[slot] = None
        req.reset_progress()
        req.phase = Phase.QUEUED
        req.instance = None
        req.first_token = None
        req.token_times = []
        req.prefill_done = None
        return True
