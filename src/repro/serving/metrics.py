"""Streaming SLO metrics for the serving gateway.

An online router cannot wait for the episode to end and run
``summarize()`` over a materialized request list: operators watch
*rolling* latency percentiles and SLO attainment while traffic is
flowing.  This module provides the two streaming estimators the gateway
publishes and the tracker that aggregates them per tenant:

  * ``WindowedReservoir`` -- exact quantiles over a sliding time window
    (the last W seconds of samples); what a dashboard's "P95 over the
    last 5 minutes" panel reads.  Memory is bounded by the arrival rate
    times the window.
  * ``P2Quantile`` -- the P-square algorithm (Jain & Chlamtac 1985):
    a constant-memory estimate of a lifetime quantile over an unbounded
    stream, for long-running deployments where keeping every sample is
    not an option.  Accuracy vs numpy quantiles is covered by
    tests/test_gateway.py.
  * ``StreamMetrics`` -- per-metric (TTFT / TBT / E2E) windowed +
    lifetime percentiles, per-tenant breakdowns, SLO-attainment and
    shed counters, snapshot() for reporting.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.serving.request import Request


class P2Quantile:
    """P-square single-quantile estimator: O(1) memory, O(1) update.

    Keeps 5 markers whose heights track the quantile ``q`` of everything
    ever added.  Small-n behavior: with fewer than 5 samples ``value()``
    is the exact ``np.quantile`` of what has arrived; from the 5th
    sample on it switches to the marker estimate, which needs on the
    order of tens of samples to converge for tail quantiles (the middle
    marker starts at the sample median and drifts toward ``q``).
    Readers that must be accurate at tiny lifetime counts should keep
    the early samples and use exact quantiles until the estimator has
    warmed up -- ``_MetricTrack.report`` does exactly that (falls back
    to ``np.quantile`` over the first ``_EXACT_KEEP`` samples while the
    lifetime count is still within them)."""

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0,1), got {q}")
        self.q = q
        self._init: list = []          # first 5 samples, sorted lazily
        self.n = 0
        # marker heights / positions / desired positions (after init)
        self._h: Optional[np.ndarray] = None
        self._pos: Optional[np.ndarray] = None
        self._des: Optional[np.ndarray] = None
        self._inc = np.array([0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0])

    def add(self, x: float):
        self.n += 1
        if self._h is None:
            self._init.append(float(x))
            if len(self._init) == 5:
                self._init.sort()
                self._h = np.array(self._init, float)
                self._pos = np.arange(1.0, 6.0)
                self._des = np.array(
                    [1.0, 1.0 + 2.0 * self.q, 1.0 + 4.0 * self.q,
                     3.0 + 2.0 * self.q, 5.0])
            return
        h, pos = self._h, self._pos
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = int(np.searchsorted(h, x, side="right")) - 1
        pos[k + 1:] += 1.0
        self._des += self._inc
        # adjust the three interior markers with the parabolic formula
        for i in (1, 2, 3):
            d = self._des[i] - pos[i]
            if (d >= 1.0 and pos[i + 1] - pos[i] > 1.0) or \
                    (d <= -1.0 and pos[i - 1] - pos[i] < -1.0):
                s = 1.0 if d >= 1.0 else -1.0
                hp = h[i] + s / (pos[i + 1] - pos[i - 1]) * (
                    (pos[i] - pos[i - 1] + s) * (h[i + 1] - h[i])
                    / (pos[i + 1] - pos[i])
                    + (pos[i + 1] - pos[i] - s) * (h[i] - h[i - 1])
                    / (pos[i] - pos[i - 1]))
                if h[i - 1] < hp < h[i + 1]:
                    h[i] = hp
                else:                      # linear fallback
                    j = i + int(s)
                    h[i] = h[i] + s * (h[j] - h[i]) / (pos[j] - pos[i])
                pos[i] += s

    def value(self) -> Optional[float]:
        if self.n == 0:
            return None
        if self._h is None:
            xs = sorted(self._init)
            return float(np.quantile(xs, self.q))
        return float(self._h[2])


class WindowedReservoir:
    """Samples from the last ``window`` seconds; exact quantiles via
    numpy over the retained slice.  ``max_samples`` bounds memory under
    extreme rates (oldest dropped first -- the window shrinks)."""

    def __init__(self, window: float = 300.0, max_samples: int = 65536):
        self.window = window
        self.max_samples = max_samples
        self._buf: deque = deque()     # (t, value)
        self.total = 0                 # lifetime count

    def add(self, t: float, x: float):
        self.total += 1
        self._buf.append((t, float(x)))
        if len(self._buf) > self.max_samples:
            self._buf.popleft()

    def _prune(self, now: float):
        cut = now - self.window
        buf = self._buf
        while buf and buf[0][0] < cut:
            buf.popleft()

    def values(self, now: Optional[float] = None) -> np.ndarray:
        if now is not None:
            self._prune(now)
        return np.array([v for _, v in self._buf])

    def quantile(self, q, now: Optional[float] = None):
        xs = self.values(now)
        if xs.size == 0:
            return None
        out = np.quantile(xs, q)
        return float(out) if np.ndim(out) == 0 else out

    def __len__(self) -> int:
        return len(self._buf)


@dataclass(frozen=True)
class SLO:
    """Per-request latency objectives (seconds); None = not enforced.
    A request attains the SLO iff every configured bound holds."""
    ttft_s: Optional[float] = 10.0
    tbt_s: Optional[float] = 0.5
    e2e_s: Optional[float] = 60.0

    def attained(self, req: Request) -> bool:
        if self.ttft_s is not None and (req.ttft is None
                                        or req.ttft > self.ttft_s):
            return False
        if self.tbt_s is not None and req.tbt is not None \
                and req.tbt > self.tbt_s:
            return False
        if self.e2e_s is not None and (req.e2e is None
                                       or req.e2e > self.e2e_s):
            return False
        return True


METRIC_KEYS = ("ttft", "tbt", "e2e")


_EXACT_KEEP = 64      # early lifetime samples kept for exact small-n quantiles


class _MetricTrack:
    """One latency metric: sliding-window reservoir + lifetime P2 set.

    The first ``_EXACT_KEEP`` lifetime samples are also kept verbatim:
    while the lifetime count is still within that prefix the ``_life``
    quantiles are exact (``np.quantile``) instead of the still-warming
    P-square estimate, whose tail markers are unreliable at tens of
    samples (see ``P2Quantile``)."""

    def __init__(self, window: float, quantiles: Sequence[float]):
        self.win = WindowedReservoir(window)
        self.p2 = {q: P2Quantile(q) for q in quantiles}
        self._exact: list = []

    def add(self, t: float, x: float):
        self.win.add(t, x)
        if len(self._exact) < _EXACT_KEEP:
            self._exact.append(float(x))
        for est in self.p2.values():
            est.add(x)

    def report(self, now: float, quantiles: Sequence[float]) -> Dict:
        out = {}
        for q in quantiles:
            v = self.win.quantile(q, now)
            out[f"p{int(q * 100)}"] = v
        small_n = 0 < self.win.total <= len(self._exact)
        for q, est in self.p2.items():
            out[f"p{int(q * 100)}_life"] = (
                float(np.quantile(self._exact, q)) if small_n
                else est.value())
        out["n_window"] = len(self.win)
        out["n_life"] = self.win.total
        return out


class _TenantStats:
    def __init__(self, window: float, quantiles: Sequence[float]):
        self.metrics = {k: _MetricTrack(window, quantiles)
                        for k in METRIC_KEYS}
        self.completed = 0
        self.shed = 0
        self.cancelled = 0
        self.admitted = 0
        self.slo_attained = 0
        self.orphaned = 0        # routed to an instance that crashed
        self.retried = 0         # bounded-backoff re-admissions
        self.hedged = 0          # hedged re-dispatches (stragglers)


class _Attribution:
    """Joins routing decisions to request actuals.

    At decision time the gateway records, per request: the length
    estimate ``d_hat`` the policy saw, the regret of the chosen
    instance against the r_mixing yardstick (``max(scores) -
    scores[chosen]``, 0 when the policy picked the yardstick's argmax),
    and whether it agreed with that argmax.  At completion the decision
    is joined to the realized decode length, yielding predictor drift
    (|d_hat - d| quantiles, bucket accuracy when the predictor exposes
    ``bucket_of``) and per-policy decision quality in ``report()``.
    Decisions whose request never completes (shed downstream, failed
    instance) stay in ``open`` and are reported as ``unjoined``."""

    def __init__(self, policy: str, bucket_of, window: float,
                 quantiles: Sequence[float]):
        self.policy = policy
        self.bucket_of = bucket_of
        self.quantiles = quantiles
        self.open: Dict[int, Tuple[int, float, bool]] = {}
        self.n_decisions = 0
        self.n_agree = 0
        self.regret = _MetricTrack(window, quantiles)
        self.abs_err = _MetricTrack(window, quantiles)
        self.n_joined = 0
        self.bucket_hits = 0
        self.bucket_total = 0

    def on_decision(self, rid: int, d_hat: int, regret: float,
                    agree: bool, now: float):
        self.n_decisions += 1
        self.n_agree += int(agree)
        self.regret.add(now, max(float(regret), 0.0))
        self.open[rid] = (int(d_hat), float(regret), bool(agree))

    def on_complete(self, req: Request, now: float):
        dec = self.open.pop(req.rid, None)
        if dec is None:
            return
        d_hat, _, _ = dec
        self.n_joined += 1
        actual = int(req.decode_tokens)
        self.abs_err.add(now, abs(d_hat - actual))
        if self.bucket_of is not None:
            self.bucket_total += 1
            self.bucket_hits += int(self.bucket_of(d_hat)
                                    == self.bucket_of(actual))

    def report(self, now: float) -> Dict:
        return {
            "policy": self.policy,
            "decisions": self.n_decisions,
            "agree_rate": (self.n_agree / self.n_decisions
                           if self.n_decisions else None),
            "regret": self.regret.report(now, self.quantiles),
            "drift": {
                "joined": self.n_joined,
                "unjoined": len(self.open),
                "abs_err": self.abs_err.report(now, self.quantiles),
                "bucket_accuracy": (self.bucket_hits / self.bucket_total
                                    if self.bucket_total else None),
            },
        }


@dataclass
class StreamMetrics:
    """Rolling gateway metrics: call ``on_admit`` / ``on_shed`` /
    ``on_complete`` from the serving loop, read ``snapshot(now)``."""

    window: float = 300.0
    quantiles: Tuple[float, ...] = (0.5, 0.95, 0.99)
    slo: SLO = field(default_factory=SLO)

    def __post_init__(self):
        self._all = _TenantStats(self.window, self.quantiles)
        self._tenants: Dict[str, _TenantStats] = {}
        self._attr: Optional[_Attribution] = None

    # -- decision attribution ------------------------------------------
    def enable_attribution(self, policy: str = "?", bucket_of=None):
        """Turn on routing-decision attribution.  ``bucket_of`` is the
        length predictor's realized-length bucketizer (None when the
        predictor has no bucket vocabulary, e.g. the oracle); idempotent
        -- re-enabling keeps the existing join state."""
        if self._attr is None:
            self._attr = _Attribution(policy, bucket_of, self.window,
                                      self.quantiles)

    def on_decision(self, req: Request, d_hat: int, regret: float,
                    agree: bool, now: Optional[float] = None):
        """One routing decision (no-op until ``enable_attribution``)."""
        if self._attr is not None:
            t = now if now is not None else req.arrival
            self._attr.on_decision(req.rid, d_hat, regret, agree, t)

    def _tenant(self, tenant: str) -> _TenantStats:
        st = self._tenants.get(tenant)
        if st is None:
            st = self._tenants[tenant] = _TenantStats(self.window,
                                                      self.quantiles)
        return st

    def on_admit(self, tenant: str = "default"):
        self._all.admitted += 1
        self._tenant(tenant).admitted += 1

    def on_shed(self, tenant: str = "default"):
        self._all.shed += 1
        self._tenant(tenant).shed += 1

    def on_cancel(self, tenant: str = "default"):
        """A deferred request whose client deadline passed was dropped
        from the overflow queue."""
        self._all.cancelled += 1
        self._tenant(tenant).cancelled += 1

    def on_evict(self, tenant: str = "default", shed: bool = True):
        """Weighted-fair eviction pushed an already-admitted request
        back out of the bounded queue.  ``shed=True`` moves it from the
        admitted to the shed column; ``shed=False`` (defer mode: the
        request returns to the client overflow and will be re-admitted)
        only reverses the admit, so offered = admitted + shed keeps
        counting each request exactly once either way."""
        for st in (self._all, self._tenant(tenant)):
            st.admitted -= 1
            if shed:
                st.shed += 1

    def on_orphan(self, tenant: str = "default"):
        """A routed request's instance crashed under it."""
        self._all.orphaned += 1
        self._tenant(tenant).orphaned += 1

    def on_retry(self, tenant: str = "default"):
        """A crash orphan was scheduled for backoff re-admission."""
        self._all.retried += 1
        self._tenant(tenant).retried += 1

    def on_hedge(self, tenant: str = "default"):
        """A stuck request was withdrawn for hedged re-dispatch."""
        self._all.hedged += 1
        self._tenant(tenant).hedged += 1

    def on_complete(self, req: Request, tenant: str = "default"):
        now = req.finished if req.finished is not None else 0.0
        if self._attr is not None:
            self._attr.on_complete(req, now)
        ok = self.slo.attained(req)
        for st in (self._all, self._tenant(tenant)):
            st.completed += 1
            st.slo_attained += int(ok)
            for key, val in (("ttft", req.ttft), ("tbt", req.tbt),
                             ("e2e", req.e2e)):
                if val is not None:
                    st.metrics[key].add(now, val)

    # -- reporting -----------------------------------------------------
    def _report_one(self, st: _TenantStats, now: float) -> Dict:
        offered = st.admitted + st.shed
        return {
            "admitted": st.admitted,
            "shed": st.shed,
            "shed_rate": st.shed / offered if offered else 0.0,
            "cancelled": st.cancelled,
            "completed": st.completed,
            "orphaned": st.orphaned,
            "retried": st.retried,
            "hedged": st.hedged,
            "slo_attained": st.slo_attained,
            "slo_rate": (st.slo_attained / st.completed
                         if st.completed else None),
            **{k: st.metrics[k].report(now, self.quantiles)
               for k in METRIC_KEYS},
        }

    def snapshot(self, now: float) -> Dict:
        out = self._report_one(self._all, now)
        out["tenants"] = {t: self._report_one(st, now)
                          for t, st in sorted(self._tenants.items())}
        # per-tenant shed burden: the tenant's share of all shedding
        # over its share of all offered traffic.  1.0 = sheds in
        # proportion to its traffic; > 1 absorbs more than its share
        # (the weighted-fair queue pushes burden onto over-share
        # tenants and drives protected tenants toward 0).
        tot_shed = sum(st.shed for st in self._tenants.values())
        tot_off = sum(st.admitted + st.shed
                      for st in self._tenants.values())
        for t, st in self._tenants.items():
            offered = st.admitted + st.shed
            if tot_shed and offered and tot_off:
                out["tenants"][t]["shed_burden"] = \
                    (st.shed / tot_shed) / (offered / tot_off)
            else:
                out["tenants"][t]["shed_burden"] = None
        out["shed_fairness"] = self.shed_fairness()
        if self._attr is not None:
            out["attribution"] = self._attr.report(now)
        return out

    def shed_fairness(self) -> Optional[float]:
        """Jain's fairness index over per-tenant admit rates
        (admitted / offered): 1.0 means every tenant saw the same
        admission probability, 1/n means one tenant absorbed all the
        shedding.  The per-tenant shed-fairness signal the weighted-fair
        queue is judged by (None until any tenant has offered load)."""
        rates = []
        for st in self._tenants.values():
            offered = st.admitted + st.shed
            if offered:
                rates.append(st.admitted / offered)
        if not rates:
            return None
        x = np.array(rates, float)
        denom = len(x) * float((x ** 2).sum())
        return float(x.sum()) ** 2 / denom if denom else 1.0


def format_snapshot(snap: Dict) -> str:
    """Human-readable one-table rendering of ``snapshot()``."""
    def row(name, d):
        e2e, ttft = d["e2e"], d["ttft"]

        def f(v):
            return f"{v:7.2f}" if v is not None else "      -"
        slo = d["slo_rate"]
        slo_s = f"{slo:.1%}" if slo is not None else "-"
        return (f"{name:<12s} n={d['completed']:<5d} "
                f"shed={d['shed']:<4d} "
                f"e2e p50/p95/p99={f(e2e.get('p50'))}{f(e2e.get('p95'))}"
                f"{f(e2e.get('p99'))}  ttft p95={f(ttft.get('p95'))}  "
                f"slo={slo_s}")
    lines = [row("ALL", snap)]
    for t, d in snap.get("tenants", {}).items():
        lines.append(row(t, d))
    return "\n".join(lines)
