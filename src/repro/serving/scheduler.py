"""Instance-level batching schedulers (paper §A.1).

All are non-preemptive in admission order: once a request starts it is
prioritized over ones that have not (the engine enforces that; preemption
for memory is a separate mechanism).  The router is deliberately DISTINCT
from these (paper §5: optimize routing for ANY instance-level scheduler).
"""
from __future__ import annotations

from typing import List, Optional, Protocol

from repro.core.profiles import HardwareProfile
from repro.serving.request import Request


class InstanceScheduler(Protocol):
    name: str

    def pick(self, queue: List[Request], free_tokens: int,
             profile: HardwareProfile) -> Optional[int]:
        """Index into queue of the next request to admit, or None."""


def _admission_tokens(r: Request) -> int:
    """KV needed AT ADMISSION (prompt + any pre-preemption progress) --
    vLLM semantics: decode growth is handled later by preemption, not by
    reserving the (unknown) full output length up front."""
    return r.prompt_tokens + r.decoded


def _predicted_total(r: Request, profile: HardwareProfile) -> int:
    return r.prompt_tokens + r.decode_tokens


class FCFS:
    """First-come-first-served (vLLM default; Yu et al. 2022)."""
    name = "fcfs"

    def pick(self, queue, free_tokens, profile):
        if not queue:
            return None
        if _admission_tokens(queue[0]) <= free_tokens:
            return 0
        return None


class BinPacking:
    """Largest PREDICTED-size request whose admission cost fits
    (S^3-style packing on the predicted output length; Jin et al. 2023).
    Ties broken FCFS."""
    name = "bin_packing"

    def pick(self, queue, free_tokens, profile):
        best, best_size = None, -1
        for i, r in enumerate(queue):
            if _admission_tokens(r) > free_tokens:
                continue
            size = _predicted_total(r, profile)
            if size > best_size:
                best, best_size = i, size
        return best


class LeastWorkLeft:
    """Smallest remaining decode first."""
    name = "least_work_left"

    def pick(self, queue, free_tokens, profile):
        best, best_d = None, None
        for i, r in enumerate(queue):
            if _admission_tokens(r) > free_tokens:
                continue
            if best_d is None or r.decode_tokens < best_d:
                best, best_d = i, r.decode_tokens
        return best


SCHEDULERS = {c.name: c for c in (FCFS, BinPacking, LeastWorkLeft)}


def get_scheduler(name: str) -> InstanceScheduler:
    return SCHEDULERS[name]()
