"""Cluster-wide request tracing: lifecycle spans with bounded memory.

Aggregate percentiles (``serving.metrics``) say *that* a policy won;
they cannot say *why*.  This module records the per-request lifecycle
event stream -- arrival, admission/backpressure, the routing decision
(with its score breakdown), instance-level prefill/decode progress,
preemption, completion -- as cheap structured records that every
execution backend emits identically:

  * the Python reference stepper (``core.simulator.SimInstance``) and
    the real engine (``serving.engine.LLMInstance``) emit events inline
    at their mutation sites;
  * the vectorized simulator (``core.vecsim``) buffers events as packed
    per-round numpy arrays inside its fused loop and drains them to
    records at span boundaries, so tracing never de-vectorizes the hot
    path; the drained stream is *identical* to the Python stepper's on
    the seeded parity scenarios (tests/test_trace.py);
  * the gateway (``serving.gateway``) emits the admission-side events
    (arrive/admit/shed/defer/evict/cancel) plus one ``route`` event per
    decision carrying the decision attribution.

Cost discipline: the default recorder is :data:`NULL` (a class whose
``enabled`` is False), so every emission site in the hot path pays one
attribute check and nothing else.  A live :class:`TraceRecorder` is a
ring buffer (``capacity`` events, oldest dropped first) with
deterministic head sampling: whether a request is traced is a pure
function of its rid, so the py and vec backends -- and a re-run --
sample the same requests.

Event schema (every event is ``(t, etype, rid, instance, tenant,
data)``; ``data`` is None or a flat dict -- the full field reference
lives in docs/TRACING.md):

  ============== ======================== ==========================
  etype          emitter                  data fields
  ============== ======================== ==========================
  arrive         gateway                  prompt
  admit          gateway                  --
  defer          gateway                  --
  shed           gateway                  --
  evict          gateway                  mode ("shed"|"defer")
  cancel         gateway                  --
  route          gateway                  inst, d_hat, wait, regret,
                                          forced?, + policy explain()
  inst_admit     sim / vecsim / engine    cached (prefix-cache tokens)
  prefill_chunk  sim / vecsim             tokens (chunked prefill only)
  prefill_done   sim / vecsim / engine    --
  first_token    sim / vecsim / engine    --
  preempt        sim / vecsim / engine    lost (progress tokens lost)
  complete       sim / vecsim / engine    --
  fail           sim / vecsim / engine    -- (rid = -1; instance event)
  recover        sim / vecsim / engine    -- (rid = -1; instance event)
  retry          gateway                  retries, due (backoff target)
  hedge          gateway                  inst (instance stolen from)
  ============== ======================== ==========================

Timestamps are simulated seconds on the emitting clock: gateway events
use the cluster clock, instance events the instance's virtual clock
(which may trail the cluster clock -- an ``inst_admit`` can carry a
smaller t than its ``route``).  :func:`canonical` orders a stream by
``(t, rid, etype-rank, instance)``, which is the equality contract the
py-vs-vec parity tests assert: the backends iterate in different orders
(instance-major vs round-major) but produce the same event *set* with
bit-identical timestamps.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Tuple

# -- event types ------------------------------------------------------------

EV_ARRIVE = "arrive"
EV_ADMIT = "admit"
EV_DEFER = "defer"
EV_SHED = "shed"
EV_EVICT = "evict"
EV_CANCEL = "cancel"
EV_ROUTE = "route"
EV_INST_ADMIT = "inst_admit"
EV_PREFILL_CHUNK = "prefill_chunk"
EV_PREFILL_DONE = "prefill_done"
EV_FIRST_TOKEN = "first_token"
EV_PREEMPT = "preempt"
EV_COMPLETE = "complete"
EV_FAIL = "fail"
EV_RECOVER = "recover"
EV_RETRY = "retry"
EV_HEDGE = "hedge"

#: canonical intra-timestamp rank (lifecycle order within one request)
EVENT_ORDER: Dict[str, int] = {
    EV_ARRIVE: 0, EV_ADMIT: 1, EV_DEFER: 2, EV_SHED: 3, EV_EVICT: 4,
    EV_CANCEL: 5, EV_ROUTE: 6, EV_INST_ADMIT: 7, EV_PREFILL_CHUNK: 8,
    EV_PREFILL_DONE: 9, EV_FIRST_TOKEN: 10, EV_PREEMPT: 11,
    EV_COMPLETE: 12, EV_FAIL: 13, EV_RECOVER: 14, EV_RETRY: 15,
    EV_HEDGE: 16,
}

EVENT_TYPES: Tuple[str, ...] = tuple(EVENT_ORDER)

#: (t, etype, rid, instance, tenant, data)
Event = Tuple[float, str, int, int, str, Optional[dict]]


def canonical(events) -> List[Event]:
    """Sort an event stream into the canonical order used for parity
    comparison and export: (t, rid, lifecycle rank, instance)."""
    return sorted(events,
                  key=lambda e: (e[0], e[2], EVENT_ORDER[e[1]], e[3]))


# -- recorders --------------------------------------------------------------

class NullRecorder:
    """The default no-trace recorder: emission sites check ``enabled``
    and skip event construction entirely, so an untraced run pays one
    attribute load per site."""

    enabled = False

    def sampled(self, rid: int) -> bool:
        return False

    def emit(self, t: float, etype: str, rid: int, instance: int = -1,
             tenant: str = "", data: Optional[dict] = None):
        pass

    def counter(self, t: float, name: str, value: float,
                instance: int = -1):
        pass

    def __len__(self) -> int:
        return 0


#: process-wide default recorder (shared, stateless)
NULL = NullRecorder()


class TraceRecorder:
    """Bounded-memory lifecycle recorder.

    ``capacity`` bounds the ring buffer (oldest events dropped first;
    ``dropped`` counts the loss).  ``sample`` in [0, 1] head-samples
    whole requests: the decision is a deterministic hash of the rid
    (salted by ``seed``), so every backend -- and every re-run -- traces
    the same subset, and a sampled request keeps its *complete*
    lifecycle.  Events with ``rid < 0`` (instance-scoped, e.g. ``fail``)
    are always recorded -- ``sample=0.0`` traces only those."""

    enabled = True

    def __init__(self, capacity: int = 262_144, sample: float = 1.0,
                 seed: int = 0):
        if not 0.0 <= sample <= 1.0:
            raise ValueError(f"sample must be in [0,1], got {sample}")
        self.capacity = capacity
        self.sample = sample
        self.seed = seed
        # Knuth multiplicative hash threshold in 32-bit space
        self._thresh = int(sample * (1 << 32))
        self._buf: deque = deque(maxlen=capacity)
        self.counters: List[Tuple[float, str, float, int]] = []
        self.n_emitted = 0

    def sampled(self, rid: int) -> bool:
        if self.sample >= 1.0:
            return True
        h = ((rid + self.seed) * 2654435761) & 0xFFFFFFFF
        return h < self._thresh

    def emit(self, t: float, etype: str, rid: int, instance: int = -1,
             tenant: str = "", data: Optional[dict] = None):
        if rid >= 0 and not self.sampled(rid):
            return
        self.n_emitted += 1
        self._buf.append((float(t), etype, int(rid), int(instance),
                          tenant, data))

    def counter(self, t: float, name: str, value: float,
                instance: int = -1):
        """Counter-track sample (queue depth, KV occupancy, backlog):
        kept out of the lifecycle stream so parity comparison and
        sampling never see them."""
        self.counters.append((float(t), name, float(value),
                              int(instance)))

    @property
    def dropped(self) -> int:
        return self.n_emitted - len(self._buf)

    def __len__(self) -> int:
        return len(self._buf)

    def events(self) -> List[Event]:
        """The retained stream in canonical order."""
        return canonical(self._buf)

    def raw_events(self) -> List[Event]:
        """The retained stream in emission order (debugging only --
        emission order is backend-dependent)."""
        return list(self._buf)
