"""Trace & metrics export: Chrome trace-event JSON and a Prometheus /
JSON metrics registry.

Two consumers, two formats:

  * ``chrome_trace(recorder)`` renders a ``serving.trace`` recorder into
    the Chrome trace-event format (the JSON Perfetto / chrome://tracing
    load directly).  Layout: pid 0 is the router (queued spans per
    request, admission instants, queue-depth counter); pid ``1 + i`` is
    instance ``i`` (prefill / decode spans, first-token / preempt
    instants, KV-occupancy and backlog counters).  Within a pid,
    requests are packed onto lanes (tids) greedily -- a lane is reused
    as soon as its previous span ends -- so the lane count visualizes
    effective concurrency, not slot identity.
  * ``MetricsRegistry`` is a flat name->value gauge registry with
    Prometheus text-exposition and JSON renderers.  It ingests nested
    dicts (``StreamMetrics.snapshot()``, ``DQNAgent.telemetry()``) by
    flattening keys, so the gateway's SLO metrics, decision-attribution
    block, and RL-training telemetry all land in one scrape target.

``python -m repro.serving.obs --validate trace.json`` checks a trace
file against the schema (CI's trace-smoke step); exits nonzero on any
violation.
"""
from __future__ import annotations

import json
import re
from typing import Dict, List, Optional

from repro.serving import trace as tr

_US = 1e6          # trace timestamps are seconds; Chrome wants microseconds

# router-side instants (pid 0); everything else rides an instance pid
_ROUTER_INSTANTS = {tr.EV_ADMIT: "admit", tr.EV_DEFER: "defer",
                    tr.EV_SHED: "shed", tr.EV_CANCEL: "cancel",
                    tr.EV_EVICT: "evict", tr.EV_ROUTE: "route",
                    tr.EV_RETRY: "retry"}
_INSTANCE_INSTANTS = {tr.EV_FIRST_TOKEN: "first_token",
                      tr.EV_PREEMPT: "preempt", tr.EV_FAIL: "fail",
                      tr.EV_RECOVER: "recover", tr.EV_HEDGE: "hedge"}


class _Lanes:
    """Greedy lane packer: first lane whose previous span has ended."""

    def __init__(self):
        self.ends: List[float] = []

    def take(self, start: float, end: float) -> int:
        for i, e in enumerate(self.ends):
            if e <= start:
                self.ends[i] = end
                return i
        self.ends.append(end)
        return len(self.ends) - 1


def _spans_for(events) -> List[dict]:
    """Reconstruct per-request spans from one rid's canonical events:
    queued (arrive -> route, router pid), prefill (inst_admit ->
    prefill_done) and decode (prefill_done -> complete) per visit --
    a preemption closes the open span and the next inst_admit opens a
    fresh prefill, so re-runs show as separate spans on the lane."""
    spans = []
    queued_at: Optional[float] = None
    open_span: Optional[dict] = None

    def close(t: float):
        nonlocal open_span
        if open_span is not None:
            open_span["t1"] = t
            spans.append(open_span)
            open_span = None

    for t, etype, rid, inst, tenant, data in events:
        if etype == tr.EV_ARRIVE:
            queued_at = t
        elif etype == tr.EV_ROUTE and queued_at is not None:
            spans.append({"name": "queued", "pid": 0, "t0": queued_at,
                          "t1": t, "rid": rid, "tenant": tenant,
                          "args": data or {}})
            queued_at = None
        elif etype == tr.EV_INST_ADMIT:
            close(t)
            open_span = {"name": "prefill", "pid": 1 + inst, "t0": t,
                         "rid": rid, "tenant": tenant,
                         "args": data or {}}
        elif etype == tr.EV_PREFILL_DONE:
            close(t)
            open_span = {"name": "decode", "pid": 1 + inst, "t0": t,
                         "rid": rid, "tenant": tenant, "args": {}}
        elif etype in (tr.EV_COMPLETE, tr.EV_PREEMPT, tr.EV_HEDGE):
            # a hedge withdraws the request from its instance, ending
            # whatever span the doomed attempt had open
            close(t)
    if open_span is not None:          # request still in flight at end
        close(open_span["t0"])
    return spans


def chrome_trace(recorder, title: str = "repro-router") -> Dict:
    """Render a recorder into a Chrome trace-event JSON document."""
    out: List[dict] = []
    by_rid: Dict[int, list] = {}
    instances = set()
    for ev in recorder.events():
        if ev[3] >= 0:
            instances.add(ev[3])
        if ev[2] >= 0:
            by_rid.setdefault(ev[2], []).append(ev)
        name = _INSTANCE_INSTANTS.get(ev[1])
        if name is not None:
            out.append({"name": name, "ph": "i", "s": "p",
                        "pid": 1 + ev[3] if ev[3] >= 0 else 0, "tid": 0,
                        "ts": ev[0] * _US,
                        "args": dict(ev[5] or {}, rid=ev[2])})
        name = _ROUTER_INSTANTS.get(ev[1])
        if name is not None and name != "route":
            out.append({"name": name, "ph": "i", "s": "p", "pid": 0,
                        "tid": 0, "ts": ev[0] * _US,
                        "args": dict(ev[5] or {}, rid=ev[2])})
    spans = [s for evs in by_rid.values() for s in _spans_for(evs)]
    spans.sort(key=lambda s: (s["t0"], s["t1"], s["rid"]))
    lanes: Dict[int, _Lanes] = {}
    for s in spans:
        lane = lanes.setdefault(s["pid"], _Lanes()).take(s["t0"], s["t1"])
        out.append({"name": s["name"], "ph": "X", "pid": s["pid"],
                    "tid": lane, "ts": s["t0"] * _US,
                    "dur": max(s["t1"] - s["t0"], 0.0) * _US,
                    "cat": s["tenant"] or "default",
                    "args": dict(s["args"], rid=s["rid"])})
    for t, name, value, inst in recorder.counters:
        pid = 1 + inst if inst >= 0 else 0
        if inst >= 0:
            instances.add(inst)
        out.append({"name": name, "ph": "C", "pid": pid, "tid": 0,
                    "ts": t * _US, "args": {name: value}})
    meta = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "router"}},
            {"name": "process_sort_index", "ph": "M", "pid": 0, "tid": 0,
             "args": {"sort_index": 0}}]
    for i in sorted(instances):
        meta.append({"name": "process_name", "ph": "M", "pid": 1 + i,
                     "tid": 0, "args": {"name": f"instance {i}"}})
        meta.append({"name": "process_sort_index", "ph": "M",
                     "pid": 1 + i, "tid": 0,
                     "args": {"sort_index": 1 + i}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms",
            "otherData": {"title": title,
                          "n_emitted": recorder.n_emitted,
                          "dropped": recorder.dropped}}


def validate_chrome_trace(doc) -> List[str]:
    """Schema check for ``chrome_trace`` output (and, loosely, any
    chrome://tracing JSON-object-format document).  Returns a list of
    violations; empty means valid."""
    errs: List[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a 'traceEvents' list"]
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        return ["'traceEvents' must be a list"]
    for i, e in enumerate(evs):
        where = f"traceEvents[{i}]"
        if not isinstance(e, dict):
            errs.append(f"{where}: not an object")
            continue
        for k in ("name", "ph", "pid"):
            if k not in e:
                errs.append(f"{where}: missing '{k}'")
        ph = e.get("ph")
        if ph not in ("X", "C", "M", "i", "B", "E"):
            errs.append(f"{where}: unknown ph {ph!r}")
        if ph != "M":
            ts = e.get("ts")
            if not isinstance(ts, (int, float)) or ts < 0:
                errs.append(f"{where}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"{where}: X event needs dur >= 0")
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                errs.append(f"{where}: C event needs non-empty args")
        if len(errs) >= 50:
            errs.append("... (truncated)")
            break
    return errs


# -- metrics registry ---------------------------------------------------

_NAME_OK = re.compile(r"[^a-zA-Z0-9_:]")


def _metric_name(*parts: str) -> str:
    name = "_".join(p for p in parts if p)
    name = _NAME_OK.sub("_", name)
    if name and name[0].isdigit():
        name = "_" + name
    return name


class MetricsRegistry:
    """Flat gauge registry; everything a scrape of the router exposes.

    ``ingest`` flattens nested dicts key-by-key (lists and non-numeric
    leaves are skipped, ``None`` leaves are skipped), so the gateway's
    ``snapshot()`` -- including the ``attribution`` / drift block -- and
    the agent's ``telemetry()`` land as e.g.::

        gateway_e2e_p95, gateway_attribution_agree_rate,
        gateway_attribution_drift_abs_err_p50, rl_loss, rl_td_abs_mean
    """

    def __init__(self):
        self._vals: Dict[str, float] = {}

    def set(self, name: str, value) -> None:
        if isinstance(value, bool):
            value = int(value)
        if isinstance(value, (int, float)):
            self._vals[_metric_name(name)] = float(value)

    def ingest(self, mapping: Dict, prefix: str = "") -> None:
        for k, v in mapping.items():
            name = _metric_name(prefix, str(k))
            if isinstance(v, dict):
                self.ingest(v, prefix=name)
            else:
                self.set(name, v)

    def ingest_snapshot(self, snap: Dict, prefix: str = "gateway"):
        self.ingest(snap, prefix=prefix)

    def ingest_rl(self, telemetry: Dict, prefix: str = "rl"):
        self.ingest(telemetry, prefix=prefix)

    def to_json(self) -> Dict[str, float]:
        return dict(sorted(self._vals.items()))

    def to_prometheus(self) -> str:
        lines = []
        for name, val in sorted(self._vals.items()):
            lines.append(f"# TYPE {name} gauge")
            lines.append(f"{name} {val:.10g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def save(self, path: str) -> None:
        """Write the registry; ``.prom`` extension selects the text
        exposition format, anything else gets JSON."""
        if path.endswith(".prom"):
            with open(path, "w") as f:
                f.write(self.to_prometheus())
        else:
            with open(path, "w") as f:
                json.dump(self.to_json(), f, indent=1, sort_keys=True)

    def __len__(self) -> int:
        return len(self._vals)

    def __getitem__(self, name: str) -> float:
        return self._vals[_metric_name(name)]


def write_trace(recorder, path: str, title: str = "repro-router"):
    """chrome_trace -> JSON file (the ``--trace PATH`` implementation)."""
    doc = chrome_trace(recorder, title=title)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.serving.obs",
        description="validate trace / metrics artifacts")
    ap.add_argument("--validate", metavar="TRACE_JSON", required=True,
                    help="Chrome trace-event JSON file to check")
    ap.add_argument("--metrics", metavar="METRICS_JSON", default=None,
                    help="optional metrics-registry JSON to check")
    args = ap.parse_args(argv)
    with open(args.validate) as f:
        doc = json.load(f)
    errs = validate_chrome_trace(doc)
    n_ev = len(doc.get("traceEvents", [])) if isinstance(doc, dict) else 0
    if errs:
        for e in errs:
            print(f"INVALID {args.validate}: {e}")
        return 1
    print(f"OK {args.validate}: {n_ev} trace events")
    if args.metrics:
        with open(args.metrics) as f:
            m = json.load(f)
        bad = not isinstance(m, dict) or not m or any(
            not isinstance(v, (int, float)) or isinstance(v, bool)
            for v in m.values())
        if bad:
            print(f"INVALID {args.metrics}: expected a non-empty "
                  "{name: number} object")
            return 1
        print(f"OK {args.metrics}: {len(m)} metrics")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
