"""Online serving gateway: streaming request router with pluggable
policies, an in-loop length predictor, and rolling SLO metrics.

The closed-loop entry point (``ManagedCluster.serve(reqs)``) consumes a
pre-materialized request list; a production router is an *open-loop*
service -- requests arrive continuously whether or not the cluster is
keeping up, and each must be routed on arrival.  The gateway provides
that loop as a first-class subsystem:

  * an open-loop arrival stream (any ``workload.Scenario`` --
    poisson/bursty/diurnal patterns, multi-tenant task mixes via
    ``workload.make_tenant_scenario``) delivered by simulated wall
    clock, with a bounded admission queue and backpressure: at
    saturation new arrivals are **shed** (rejected, counted per tenant)
    or **deferred** (held in a client-side overflow queue);
  * one ``RoutingPolicy`` decision per tick (``serving.policies``: rr /
    jsq / mixing / rl are one-line swaps), plus the SLA watchdog from
    the RL env (a defer on a request that has waited past
    ``defer_timeout`` is overridden with the best-impact placement);
  * the learned length predictor in the hot path via
    ``MicroBatchPredictor``: arrivals of each tick are predicted in ONE
    padded jitted forward (micro-batching), LRU-cached per prompt
    content, and stamped onto the request as d-hat -- no oracle decode
    lengths anywhere in the routing path;
  * ``serving.metrics.StreamMetrics``: windowed P50/P95/P99
    TTFT/TBT/E2E, per-tenant breakdowns, SLO attainment, shed counters.

With an unbounded queue, the oracle length service, and the RL policy,
the gateway reproduces ``ManagedCluster.serve`` decision for decision
(tests/test_gateway.py) -- the closed-loop path is a special case of
this subsystem.

The gateway fronts either the discrete-event simulator ``Cluster`` or
real ``serving.engine.LLMInstance`` replicas (``EngineClusterAdapter``).
"""
from __future__ import annotations

import heapq
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import predictor as pred_lib
from repro.core import rl_router as rl
from repro.core import workload as wl
from repro.core.simulator import Cluster
from repro.serving import trace as tr_lib
from repro.serving.chaos import ChaosInjector, FaultSchedule, \
    HealthTracker
from repro.serving.metrics import SLO, StreamMetrics
from repro.serving.request import Phase, Request, summarize


# -- length services --------------------------------------------------------

class OracleLength:
    """Ground-truth decode lengths (parity tests / upper bound)."""
    name = "oracle"

    def prefetch(self, pairs: Sequence[Tuple[Request, object]]):
        pass

    def estimate(self, req: Request) -> int:
        return req.decode_tokens


class MicroBatchPredictor:
    """The learned bucket predictor in the serving hot path.

    ``prefetch`` runs once per arrival window (tick): every new arrival
    whose prompt content is not LRU-cached is encoded and predicted in
    one jitted forward padded to ``batch_pad`` rows -- so the predictor
    costs one dispatch per window, not one per request, and the XLA
    executable compiles exactly once.  Results are stamped on the
    request (``predicted_bucket`` / ``predicted_decode``) and cached by
    prompt content, so repeated prompts (retries, templated traffic)
    skip the network entirely."""
    name = "microbatch"

    def __init__(self, predictor: pred_lib.BucketPredictor,
                 batch_pad: int = 16, cache_size: int = 4096,
                 default_bucket: int = 3):
        self.predictor = predictor
        self.batch_pad = batch_pad
        self.cache_size = cache_size
        self._cache: OrderedDict = OrderedDict()   # key -> (bucket, d_hat)
        self.hits = 0
        self.misses = 0
        self.forwards = 0            # jitted dispatch count
        self.default_d = max(
            int(predictor.bucket_upper_tokens(default_bucket)), 1)

    @staticmethod
    def _key(sample) -> tuple:
        return (sample.task_id, sample.token_ids.tobytes())

    def _stamp(self, req: Request, bucket: int, d_hat: int):
        req.predicted_bucket = bucket
        req.predicted_decode = pred_lib.serviceable_decode(
            self.predictor.profile, d_hat, req.prompt_tokens)

    def prefetch(self, pairs: Sequence[Tuple[Request, object]]):
        todo: List[Tuple[tuple, Request, object]] = []
        for req, sample in pairs:
            if sample is None:
                continue
            key = self._key(sample)
            hit = self._cache.get(key)
            if hit is not None:
                self.hits += 1
                self._cache.move_to_end(key)
                self._stamp(req, *hit)
            else:
                self.misses += 1
                todo.append((key, req, sample))
        if not todo:
            return
        # one padded jitted forward per batch_pad window (predict()
        # owns the pad/chunk/compile-once logic)
        buckets = self.predictor.predict([s for _, _, s in todo],
                                         chunk=self.batch_pad)
        self.forwards += -(-len(todo) // self.batch_pad)
        for (key, req, _), b in zip(todo, buckets):
            d_hat = max(int(self.predictor.bucket_upper_tokens(int(b))),
                        1)
            self._cache[key] = (int(b), d_hat)
            self._stamp(req, int(b), d_hat)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)

    def estimate(self, req: Request) -> int:
        if req.predicted_decode is not None:
            return req.predicted_decode
        return self.default_d

    def bucket_of(self, decode_tokens: int) -> int:
        """Ground-truth bucket for a realized decode length (drift
        bucket-accuracy join in StreamMetrics)."""
        return self.predictor.bucket_of(decode_tokens)


# -- real-engine backend ----------------------------------------------------

class _EngineInstanceView:
    """Adapt one ``LLMInstance`` to the simulator-instance surface the
    policies and the state featurizer read."""

    def __init__(self, engine):
        self.engine = engine

    @property
    def profile(self):
        return self.engine.profile

    @property
    def failed(self):
        return self.engine.failed

    @property
    def residents(self):
        return self.engine.resident

    @property
    def queue(self):
        return self.engine.queue

    @property
    def n_slots(self):
        return self.engine.n_slots

    @property
    def clock(self):
        return self.engine.clock

    def resident_token_sum(self) -> float:
        return self.engine.resident_tokens()

    def queued_prompt_sum(self) -> float:
        return sum(r.prompt_tokens for r in self.engine.queue)

    def free_tokens(self) -> float:
        return self.engine.free_tokens()

    def outstanding_tokens(self) -> float:
        todo = 0.0
        for r in self.engine.resident:
            todo += (r.prompt_tokens - r.prefilled) + max(
                r.decode_tokens - r.decoded, 0)
        for r in self.engine.queue:
            todo += r.prompt_tokens + r.decode_tokens
        return todo

    @property
    def prefix_cache(self):
        return getattr(self.engine, "prefix_cache", None)

    @property
    def speed_factor(self) -> float:
        return self.engine.speed_factor


class EngineClusterAdapter:
    """Drive real JAX ``LLMInstance`` replicas behind the gateway with
    the ``Cluster`` protocol (central queue, route, dt-advance).  Each
    engine runs its virtual clock up to the gateway tick; idle engines
    are fast-forwarded without burning iterations."""

    def __init__(self, engines, dt: float = 0.02):
        self.engines = list(engines)
        self.instances = [_EngineInstanceView(e) for e in self.engines]
        self.profile = self.engines[0].profile
        self.profiles = tuple(e.profile for e in self.engines)
        self.dt = dt
        self.central: deque = deque()
        self.t = 0.0
        self.completed: List[Request] = []
        self.queue_len_trace: List[int] = []

    @property
    def m(self) -> int:
        return len(self.engines)

    def set_trace(self, trace):
        """Attach a TraceRecorder to every engine (Cluster parity);
        instance ids in the events are adapter indices."""
        for i, e in enumerate(self.engines):
            e.trace = trace
            e.trace_instance = i

    def alive(self) -> List[int]:
        return [i for i, e in enumerate(self.engines) if not e.failed]

    def enqueue(self, req: Request):
        req.phase = Phase.QUEUED
        self.central.append(req)

    def route(self, idx: int) -> Request:
        req = self.central.popleft()
        self.engines[idx].submit(req)
        return req

    def advance(self) -> List[Request]:
        self.t += self.dt
        done: List[Request] = []
        for e in self.engines:
            if e.failed:
                e.clock = self.t
                continue
            while e.clock < self.t:
                if not e.queue and not any(
                        s is not None for s in e.slots):
                    e.clock = self.t
                    break
                done.extend(e.step())
        self.completed.extend(done)
        self.queue_len_trace.append(len(self.central))
        return done

    # -- fault injection (Cluster parity) ------------------------------
    def fail_instance(self, idx: int, requeue: bool = True
                      ) -> List[Request]:
        orphans = self.engines[idx].fail()
        if requeue:
            for r in orphans:
                self.central.appendleft(r)
        return orphans

    def recover_instance(self, idx: int):
        e = self.engines[idx]
        e.clock = max(e.clock, self.t)
        e.recover()

    def set_speed_factor(self, idx: int, factor: float):
        self.engines[idx].speed_factor = float(factor)

    def steal(self, req: Request) -> bool:
        if req.instance is None:
            return False
        return self.engines[req.instance].steal(req)


# -- the gateway ------------------------------------------------------------

@dataclass
class GatewayConfig:
    dt: float = 0.02                 # the paper's router cadence
    queue_cap: int = 0               # admission queue bound; 0 = unbounded
    on_full: str = "shed"            # "shed" | "defer" at saturation
    routes_per_tick: int = 1
    defer_timeout: float = 5.0       # SLA watchdog (RouterConfig parity)
    alpha: float = 0.5               # Eq.(1)/(2) balance for the watchdog
    scheduler: str = "fcfs"
    chunked_prefill: int = 0
    n_slots: Optional[int] = None
    max_time: float = 36_000.0
    metrics_window: float = 300.0
    quantiles: Tuple[float, ...] = (0.5, 0.95, 0.99)
    slo: SLO = field(default_factory=SLO)
    # simulator backend for the built-in cluster: "py" (SimInstance
    # reference stepper) or "vec" (core.vecsim structure-of-arrays)
    backend: str = "py"
    # per-instance prefix/KV cache model (core.prefix_cache); 0 = off
    prefix_cache_tokens: int = 0
    prefix_block: int = 32
    # client timeouts: a DEFERRED request whose deadline has passed is
    # dropped from the overflow queue and counted as ``cancelled``.
    # Requests may carry their own absolute ``deadline``; otherwise
    # ``default_deadline_s`` (seconds after arrival; None = no client
    # timeout) applies.
    default_deadline_s: Optional[float] = None
    # autoscaling: evaluate ``scale_up_when(shed_rate, p95_e2e)`` each
    # tick and add an instance at most once per ``scale_window``
    scale_window: float = 60.0
    # per-tenant admission quotas / weighted-fair shedding.  With
    # ``tenant_weights`` set, a full queue no longer sheds whoever
    # happens to arrive: each tenant's fair share of the bounded queue
    # is queue_cap * w_t / sum(w), and at saturation the request shed
    # is taken from the tenant MOST over its share -- evicting the
    # newest queued request of an over-share tenant to admit an
    # under-share arrival.  Tenants absent from the dict get
    # ``default_tenant_weight``.  None (default) keeps the old
    # tenant-blind behaviour.
    tenant_weights: Optional[Dict[str, float]] = None
    default_tenant_weight: float = 1.0
    # decision attribution: score every routing decision against the
    # r_mixing yardstick and join it to the request's eventual actuals
    # (per-policy regret + predictor drift in snapshot()).  Enabled
    # implicitly whenever a trace recorder is attached.
    attribution: bool = False
    # counter-track cadence (simulated seconds) for queue depth / KV
    # occupancy / backlog samples while tracing
    trace_counter_every: float = 1.0
    # -- chaos / failover (serving.chaos) ------------------------------
    # fault schedule replayed against the cluster at tick boundaries
    chaos: Optional[FaultSchedule] = None
    # failover: crash orphans re-enter admission with a bounded retry
    # budget and exponential backoff instead of instantly requeueing;
    # after ``max_retries`` failed attempts the request is shed
    failover: bool = False
    max_retries: int = 3
    retry_backoff_s: float = 0.25
    # hedged re-dispatch: a routed request still tokenless after
    # ``hedge_after_s`` is withdrawn from its (straggling) instance and
    # re-routed, at most ``max_hedges`` times.  None = off.
    hedge_after_s: Optional[float] = None
    max_hedges: int = 1
    # health tracker / circuit breaker knobs (HealthTracker); the
    # tracker runs whenever failover or chaos is on
    health_alpha: float = 0.3
    breaker_factor: float = 2.5
    breaker_min_samples: int = 8
    breaker_cooldown_s: float = 30.0


class Gateway:
    """Event-driven serving gateway over a cluster backend."""

    def __init__(self, cfg: GatewayConfig, profiles, policy,
                 length=None, cluster=None, scale_up_when=None,
                 trace=None):
        self.cfg = cfg
        self.trace = trace if trace is not None else tr_lib.NULL
        if cluster is not None:
            self.cluster = cluster
            if trace is not None:
                set_tr = getattr(cluster, "set_trace", None)
                if set_tr is not None:
                    set_tr(trace)
        else:
            profiles = tuple(profiles)
            self.cluster = Cluster(
                profiles, len(profiles), cfg.scheduler, cfg.dt,
                cfg.chunked_prefill, cfg.n_slots, backend=cfg.backend,
                prefix_cache_tokens=cfg.prefix_cache_tokens,
                prefix_block=cfg.prefix_block, trace=trace)
        self.policy = policy
        self.length = length or OracleLength()
        self.metrics = StreamMetrics(window=cfg.metrics_window,
                                     quantiles=cfg.quantiles,
                                     slo=cfg.slo)
        # decision attribution (regret vs the r_mixing yardstick +
        # predictor drift): on whenever requested or whenever tracing
        # is -- the joined actuals feed snapshot()'s attribution block
        self._attr = bool(cfg.attribution) or self.trace.enabled
        if self._attr:
            self.metrics.enable_attribution(
                policy=getattr(policy, "name", "?"),
                bucket_of=getattr(self.length, "bucket_of", None))
        self._last_counter = -float("inf")
        self.shed: List[Request] = []
        self.cancelled: List[Request] = []
        # minimal autoscaling hook: ``scale_up_when(shed_rate, p95_e2e)``
        # -> bool is evaluated every tick; when it fires,
        # ``cluster.add_instance`` runs at most once per
        # ``cfg.scale_window`` of simulated time
        self.scale_up_when = scale_up_when
        self.scale_events: List[float] = []
        self._last_scale = -float("inf")
        self._last_scale_check = -float("inf")
        self._overflow: deque = deque()
        self._overflow_deadlines = False   # any deferred req has one?
        self._n_admitted = 0
        # per-tenant occupancy of the bounded admission queue (the
        # weighted-fair share bookkeeping; maintained even without
        # tenant_weights -- it is two dict ops per request)
        self._q_tenant: Dict[str, int] = {}
        # -- chaos / failover ------------------------------------------
        self.chaos = (ChaosInjector(cfg.chaos)
                      if cfg.chaos is not None else None)
        self.health = (HealthTracker(
            self.cluster.m, alpha=cfg.health_alpha,
            breaker_factor=cfg.breaker_factor,
            min_samples=cfg.breaker_min_samples,
            cooldown_s=cfg.breaker_cooldown_s)
            if (cfg.failover or self.chaos is not None) else None)
        self._retry_q: List[Tuple[float, int, Request]] = []  # heap
        self._retry_seq = 0
        # routed-but-tokenless requests eligible for hedging:
        # rid -> (req, gateway dispatch time)
        self._inflight: Dict[int, Tuple[Request, float]] = {}
        self.orphaned = 0
        self.hedged = 0
        # stateful policies (the online trainer) may attach to the
        # gateway: ``bind`` runs once, before any tick
        bind = getattr(policy, "bind", None)
        if bind is not None:
            bind(self)

    # -- admission / backpressure --------------------------------------
    def _queue_full(self) -> bool:
        cap = self.cfg.queue_cap
        return bool(cap) and len(self.cluster.central) >= cap

    def _admit(self, req: Request):
        if self.cfg.default_deadline_s is not None \
                and req.deadline is None:
            req.deadline = req.arrival + self.cfg.default_deadline_s
        tr = self.trace
        if self._queue_full() and not self._fair_evict_for(req):
            if self.cfg.on_full == "shed":
                req.phase = Phase.SHED
                self.shed.append(req)
                self.metrics.on_shed(req.tenant)
                if tr.enabled:
                    tr.emit(self.cluster.t, tr_lib.EV_SHED, req.rid,
                            -1, req.tenant)
            else:                       # defer: client-side overflow
                self._overflow.append(req)
                if req.deadline is not None:
                    self._overflow_deadlines = True
                if tr.enabled:
                    tr.emit(self.cluster.t, tr_lib.EV_DEFER, req.rid,
                            -1, req.tenant)
            return
        self.cluster.enqueue(req)
        self._n_admitted += 1
        self._q_tenant[req.tenant] = \
            self._q_tenant.get(req.tenant, 0) + 1
        self.metrics.on_admit(req.tenant)
        if tr.enabled:
            tr.emit(self.cluster.t, tr_lib.EV_ADMIT, req.rid, -1,
                    req.tenant)

    # -- weighted-fair shedding ----------------------------------------
    def _tenant_weight(self, tenant: str) -> float:
        w = self.cfg.tenant_weights
        return w.get(tenant, self.cfg.default_tenant_weight) if w \
            else self.cfg.default_tenant_weight

    def _fair_evict_for(self, req: Request) -> bool:
        """At saturation, try to make room for ``req`` by evicting the
        newest queued request of the tenant most over its weighted fair
        share.  Returns True if a slot was freed; False means the
        arrival itself is the (equal-)worst offender and takes the
        shed/defer as before.  No-op without ``tenant_weights``.

        Shares are computed over the tenants currently OCCUPYING the
        queue (plus the arrival): tenants that appeared once and went
        idle must not keep diluting everyone else's entitlement."""
        if self.cfg.tenant_weights is None:
            return False
        cap = self.cfg.queue_cap
        tenants = {t for t, n in self._q_tenant.items() if n > 0}
        tenants.add(req.tenant)
        total_w = sum(self._tenant_weight(t) for t in tenants)
        if total_w <= 0:
            return False

        def over(tenant: str, occupancy: int) -> float:
            share = cap * self._tenant_weight(tenant) / total_w
            return occupancy - share
        over_arrival = over(req.tenant,
                            self._q_tenant.get(req.tenant, 0) + 1)
        victim_tenant = None
        worst = over_arrival
        for t in tenants:
            n = self._q_tenant.get(t, 0)
            if n > 0 and t != req.tenant and over(t, n) > worst:
                worst = over(t, n)
                victim_tenant = t
        if victim_tenant is None:
            return False
        return self._evict_newest(victim_tenant)

    def _evict_newest(self, tenant: str) -> bool:
        """Push the newest queued (not yet routed) request of a tenant
        back out of the central queue: shed under ``on_full="shed"``,
        returned to the client-side overflow under ``"defer"`` (defer
        mode stays lossless -- the displaced request retries like any
        deferred arrival)."""
        central = self.cluster.central
        victim = None
        for r in reversed(central):
            if r.tenant == tenant:
                victim = r
                break
        if victim is None:
            return False
        central.remove(victim)
        self._n_admitted -= 1
        self._q_tenant[tenant] -= 1
        if self._q_tenant[tenant] == 0:
            del self._q_tenant[tenant]      # bound the dict's growth
        if self.cfg.on_full == "shed":
            victim.phase = Phase.SHED
            self.shed.append(victim)
            self.metrics.on_evict(tenant)
        else:
            victim.phase = Phase.QUEUED
            self._overflow.append(victim)
            if victim.deadline is not None:
                self._overflow_deadlines = True
            self.metrics.on_evict(tenant, shed=False)
        if self.trace.enabled:
            self.trace.emit(self.cluster.t, tr_lib.EV_EVICT,
                            victim.rid, -1, tenant,
                            {"mode": self.cfg.on_full})
        return True

    def _cancel_expired(self):
        """Client timeouts: deferred requests whose deadline has passed
        leave the overflow queue (the client hung up; re-admitting the
        work would burn capacity on an answer nobody reads).  O(queue)
        per tick, paid only while some deferred request actually
        carries a deadline."""
        if not self._overflow or not self._overflow_deadlines:
            return
        now = self.cluster.t
        keep: deque = deque()
        for req in self._overflow:
            if req.deadline is not None and now > req.deadline:
                req.phase = Phase.CANCELLED
                self.cancelled.append(req)
                self.metrics.on_cancel(req.tenant)
                if self.trace.enabled:
                    self.trace.emit(now, tr_lib.EV_CANCEL, req.rid,
                                    -1, req.tenant)
            else:
                keep.append(req)
        self._overflow = keep

    def _drain_overflow(self):
        self._cancel_expired()
        while self._overflow and not self._queue_full():
            req = self._overflow.popleft()
            self.cluster.enqueue(req)
            self._n_admitted += 1
            self._q_tenant[req.tenant] = \
                self._q_tenant.get(req.tenant, 0) + 1
            self.metrics.on_admit(req.tenant)
            if self.trace.enabled:
                self.trace.emit(self.cluster.t, tr_lib.EV_ADMIT,
                                req.rid, -1, req.tenant,
                                {"retry": True})

    def _maybe_scale_up(self):
        """Closed-loop elastic scale-out: fire the user predicate on
        the live shed rate and windowed P95 E2E, rate-limited to one
        ``add_instance`` per ``scale_window`` of simulated time.  The
        predicate (and its exact-quantile read over the metrics window)
        is consulted at most once per simulated second, not per tick."""
        if self.scale_up_when is None:
            return
        now = self.cluster.t
        if now - self._last_scale < self.cfg.scale_window:
            return
        if now - self._last_scale_check < 1.0:
            return
        self._last_scale_check = now
        st = self.metrics._all
        offered = st.admitted + st.shed
        shed_rate = st.shed / offered if offered else 0.0
        p95 = st.metrics["e2e"].win.quantile(0.95, now)
        if not self.scale_up_when(shed_rate,
                                  0.0 if p95 is None else p95):
            return
        add = getattr(self.cluster, "add_instance", None)
        if add is None:
            return
        add(self.cfg.scheduler, self.cfg.chunked_prefill)
        self._last_scale = now
        self.scale_events.append(now)

    # -- chaos / failover ----------------------------------------------
    def _apply_chaos(self):
        """Apply the fault schedule's due events at the tick boundary.
        With failover on, crash orphans go through the bounded-retry
        path; otherwise they requeue immediately (legacy semantics) but
        with the gateway's tenant-occupancy bookkeeping kept
        consistent."""
        if self.chaos is None:
            return
        on_orphans = (self._on_orphans if self.cfg.failover
                      else self._requeue_orphans)
        for kind, idx, _ in self.chaos.step(self.cluster,
                                            self.cluster.t, on_orphans):
            if kind == "recover" and self.health is not None:
                self.health.reset(idx)

    def _requeue_orphans(self, orphans: List[Request]):
        for req in orphans:
            self._inflight.pop(req.rid, None)
            self.orphaned += 1
            self.metrics.on_orphan(req.tenant)
            self.cluster.central.appendleft(req)
            self._q_tenant[req.tenant] = \
                self._q_tenant.get(req.tenant, 0) + 1

    def _on_orphans(self, orphans: List[Request]):
        """Failover: orphaned requests re-enter admission with a
        bounded retry budget and exponential backoff; past the budget
        they are shed (the outage consumed them)."""
        now = self.cluster.t
        cfg = self.cfg
        for req in orphans:
            self._inflight.pop(req.rid, None)
            self.orphaned += 1
            self.metrics.on_orphan(req.tenant)
            req.retries += 1
            if req.retries > cfg.max_retries:
                req.phase = Phase.SHED
                self.shed.append(req)
                self._n_admitted -= 1
                self.metrics.on_evict(req.tenant)   # admitted -> shed
                if self.trace.enabled:
                    self.trace.emit(now, tr_lib.EV_SHED, req.rid, -1,
                                    req.tenant,
                                    {"retries": int(req.retries - 1)})
                continue
            due = now + cfg.retry_backoff_s * (2.0 ** (req.retries - 1))
            heapq.heappush(self._retry_q,
                           (due, self._retry_seq, req))
            self._retry_seq += 1
            self.metrics.on_retry(req.tenant)
            if self.trace.enabled:
                self.trace.emit(now, tr_lib.EV_RETRY, req.rid, -1,
                                req.tenant,
                                {"retries": int(req.retries),
                                 "due": float(due)})

    def _drain_retries(self):
        """Re-admit retries whose backoff has elapsed.  They re-enter
        at the FRONT of the central queue: they are the stream's oldest
        requests and have already paid their backoff delay."""
        now = self.cluster.t
        while self._retry_q and self._retry_q[0][0] <= now:
            _, _, req = heapq.heappop(self._retry_q)
            req.phase = Phase.QUEUED
            self.cluster.central.appendleft(req)
            self._q_tenant[req.tenant] = \
                self._q_tenant.get(req.tenant, 0) + 1

    def _update_health(self):
        """Stamp the tracker's verdict onto the cluster: policies and
        the featurizer consult ``health_mask`` / ``health_scores``
        through duck-typed getattr, so every backend -- py, vec, engine
        adapter -- gets the same candidate-set filtering."""
        if self.health is None:
            return
        cluster = self.cluster
        self.health.ensure(cluster.m)
        mask, scores = self.health.assess(cluster.t, cluster.alive())
        cluster.health_mask = mask
        cluster.health_scores = scores

    def _hedge_stuck(self):
        """Hedged re-dispatch: a routed request still tokenless past
        ``hedge_after_s`` is withdrawn from its (straggling) instance
        and re-enters the central queue for a fresh placement."""
        cfg = self.cfg
        if cfg.hedge_after_s is None or not self._inflight:
            return
        cluster = self.cluster
        now = cluster.t
        is_vec = getattr(cluster, "is_vec", False)
        for rid, (req, t0) in list(self._inflight.items()):
            if now - t0 <= cfg.hedge_after_s \
                    or req.hedges >= cfg.max_hedges:
                continue
            if is_vec:
                cluster.pool.sync_request(cluster.gid_of(req))
            if req.first_token is not None or req.phase is Phase.DONE:
                del self._inflight[rid]     # progressing; leave it be
                continue
            src = req.instance
            if not cluster.steal(req):
                del self._inflight[rid]
                continue
            del self._inflight[rid]
            req.hedges += 1
            self.hedged += 1
            if self.health is not None and src is not None:
                self.health.on_bad(int(src))
            self.metrics.on_hedge(req.tenant)
            if self.trace.enabled:
                self.trace.emit(now, tr_lib.EV_HEDGE, req.rid,
                                -1 if src is None else int(src),
                                req.tenant,
                                {"inst": -1 if src is None
                                 else int(src)})
            req.phase = Phase.QUEUED
            cluster.central.appendleft(req)
            self._q_tenant[req.tenant] = \
                self._q_tenant.get(req.tenant, 0) + 1

    # -- routing -------------------------------------------------------
    def _route_some(self):
        cfg = self.cfg
        cluster = self.cluster
        tr = self.trace
        for _ in range(cfg.routes_per_tick):
            if not cluster.central:
                return
            head = cluster.central[0]
            d_hat = max(int(self.length.estimate(head)), 1)
            a = self.policy.route(cluster, head, d_hat)
            deferred = a is None or a >= cluster.m
            scores = None
            forced = False
            if deferred and cluster.t - head.arrival > cfg.defer_timeout:
                # SLA watchdog: force the best-impact placement (the
                # same override RoutingEnv.step applies)
                scores = rl.mixing_scores(cluster, head, d_hat,
                                          cfg.alpha)
                a = int(np.argmax(scores))
                deferred = False
                forced = True
                on_forced = getattr(self.policy, "on_forced", None)
                if on_forced is not None:
                    # the online trainer charges the watchdog's
                    # sla_penalty to the deferring decision (RoutingEnv
                    # reward parity)
                    on_forced(int(a))
            if deferred:
                return
            self._q_tenant[head.tenant] -= 1
            if self._q_tenant[head.tenant] == 0:
                del self._q_tenant[head.tenant]
            if self._attr:
                # uniform yardstick across ALL policies: the r_mixing
                # score vector this decision faced.  Regret is the
                # score gap to the mixing-argmax (0 for the heuristic
                # itself) -- joined to actuals at completion time.
                if scores is None:
                    scores = rl.mixing_scores(cluster, head, d_hat,
                                              cfg.alpha)
                best = int(np.argmax(scores))
                regret = float(scores[best] - scores[a])
                self.metrics.on_decision(head, d_hat, regret,
                                         agree=(a == best))
                if tr.enabled:
                    data = {"inst": int(a), "d_hat": int(d_hat),
                            "wait": float(cluster.t - head.arrival),
                            "regret": regret}
                    if forced:
                        data["forced"] = True
                    explain = getattr(self.policy, "explain", None)
                    if explain is not None:
                        ex = explain(cluster, head, d_hat)
                        if ex:
                            data.update(ex)
                    tr.emit(cluster.t, tr_lib.EV_ROUTE, head.rid,
                            int(a), head.tenant, data)
            cluster.route(a)
            if cfg.hedge_after_s is not None:
                self._inflight[head.rid] = (head, cluster.t)

    def _sample_counters(self):
        """Counter-track samples for the Perfetto export: router queue
        depth plus per-instance KV occupancy and outstanding backlog."""
        tr = self.trace
        t = self.cluster.t
        tr.counter(t, "queue_depth", len(self.cluster.central))
        for i, inst in enumerate(self.cluster.instances):
            tr.counter(t, "kv_tokens", inst.resident_token_sum(), i)
            tr.counter(t, "backlog", inst.outstanding_tokens(), i)

    # -- serving loop --------------------------------------------------
    def run(self, scenario_or_requests, samples=None) -> Dict:
        """Serve one open-loop stream to completion (or ``max_time``).

        Accepts a ``workload.Scenario`` (its ``samples`` feed the
        length service) or a plain request list.  Returns closed-loop
        summary stats + the streaming ``snapshot``."""
        if isinstance(scenario_or_requests, wl.Scenario):
            requests = scenario_or_requests.requests
            samples = scenario_or_requests.samples
        else:
            requests = list(scenario_or_requests)
        if samples is None:
            samples = [None] * len(requests)
        order = sorted(range(len(requests)),
                       key=lambda i: requests[i].arrival)
        stream = [(requests[i], samples[i]) for i in order]
        cluster = self.cluster
        cfg = self.cfg
        tr = self.trace
        i, n = 0, len(stream)
        track_health = self.health is not None
        # stateful-policy tick hooks (the online trainer): resolved once
        # -- None for every stock policy, so the loop pays one branch
        on_pre_route = getattr(self.policy, "on_pre_route", None)
        on_tick = getattr(self.policy, "on_tick", None)
        on_run_end = getattr(self.policy, "on_run_end", None)
        while True:
            self._apply_chaos()
            self._update_health()
            new: List[Tuple[Request, object]] = []
            while i < n and stream[i][0].arrival <= cluster.t:
                new.append(stream[i])
                i += 1
            if new:
                self.length.prefetch(new)
            self._drain_overflow()      # deferred clients retry first
            self._drain_retries()       # elapsed-backoff crash orphans
            for req, _ in new:
                if tr.enabled:
                    tr.emit(req.arrival, tr_lib.EV_ARRIVE, req.rid,
                            -1, req.tenant,
                            {"prompt": int(req.prompt_tokens)})
                self._admit(req)
            self._hedge_stuck()
            if on_pre_route is not None:
                # every request enqueued this tick (arrivals, drained
                # overflow, retries, hedge requeues) is still in
                # cluster.central here
                on_pre_route(cluster)
            self._route_some()
            done_now = cluster.advance()
            for r in done_now:
                if self._inflight:
                    self._inflight.pop(r.rid, None)
                if track_health and r.instance is not None:
                    self.health.on_complete(int(r.instance), r)
                self.metrics.on_complete(r, r.tenant)
            if on_tick is not None:
                on_tick(cluster, done_now)
            self._drain_overflow()
            self._maybe_scale_up()
            if tr.enabled and (cluster.t - self._last_counter
                               >= cfg.trace_counter_every):
                self._last_counter = cluster.t
                self._sample_counters()
            if (i >= n and not self._overflow and not self._retry_q
                    and len(cluster.completed) >= self._n_admitted):
                break
            if cluster.t > cfg.max_time:
                break
        if on_run_end is not None:
            on_run_end()
        if getattr(cluster, "is_vec", False):
            cluster.sync_all()   # in-flight requests on truncated runs
            for r in self.shed:
                r.phase = Phase.SHED   # fair-evicted: arena says QUEUED
        stats = summarize(requests)
        stats["preemptions"] = sum(r.preemptions for r in requests)
        stats["shed"] = len(self.shed)
        stats["cancelled"] = len(self.cancelled)
        stats["admitted"] = self._n_admitted
        stats["scaled"] = len(self.scale_events)
        stats["orphaned"] = self.orphaned
        stats["hedged"] = self.hedged
        stats["retried"] = sum(r.retries for r in requests)
        if self.health is not None:
            stats["breaker_trips"] = self.health.trips
        stats["policy"] = getattr(self.policy, "name", "?")
        stats["snapshot"] = self.metrics.snapshot(cluster.t)
        return stats
