"""Request objects and lifecycle metrics shared by the engine, the
discrete-event simulator, and the router."""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import List, Optional


class Phase(enum.Enum):
    QUEUED = "queued"          # at the central router
    INSTANCE_QUEUE = "iqueue"  # admitted to an instance's local queue
    PREFILL = "prefill"
    DECODE = "decode"
    PREEMPTED = "preempted"
    DONE = "done"
    SHED = "shed"              # rejected at the gateway (backpressure)
    CANCELLED = "cancelled"    # client deadline passed while deferred


_ids = itertools.count()


@dataclass
class Request:
    prompt_tokens: int
    decode_tokens: int                  # ground-truth output length
    arrival: float = 0.0
    task: str = "unknown"               # sentiment/entity/qna/... (Table 1)
    rid: int = field(default_factory=lambda: next(_ids))
    predicted_bucket: Optional[int] = None   # router's length prediction
    predicted_decode: Optional[int] = None   # d-hat tokens (predictor)
    tenant: str = "default"                  # gateway multi-tenant label
    tokens: Optional[list] = None            # real token ids (engine path)
    deadline: Optional[float] = None         # client gives up after this t
    # prefix-cache identity (core.prefix_cache): per-block hash chain of
    # the prompt, and of the full prompt+response context (inserted at
    # completion so the NEXT turn of the conversation can hit it).
    # ``None`` opts the request out of the cache model entirely.
    prefix_hashes: Optional[tuple] = None
    full_hashes: Optional[tuple] = None

    # lifecycle (filled by engine/simulator)
    phase: Phase = Phase.QUEUED
    instance: Optional[int] = None
    routed_at: Optional[float] = None
    prefill_done: Optional[float] = None
    first_token: Optional[float] = None      # TTFT anchor
    finished: Optional[float] = None
    decoded: int = 0                         # output tokens produced so far
    prefilled: int = 0                       # prompt tokens processed
    admitted_idx: int = -1                   # admission order (eviction)
    token_times: List[float] = field(default_factory=list)
    preemptions: int = 0
    cached_prefix: int = 0                   # prefill tokens served from cache
    retries: int = 0                         # crash-orphan re-admissions
    hedges: int = 0                          # hedged re-dispatches

    # -- metrics -----------------------------------------------------------
    @property
    def e2e(self) -> Optional[float]:
        if self.finished is None:
            return None
        return self.finished - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def tbt(self) -> Optional[float]:
        """Mean time between output tokens."""
        if len(self.token_times) < 2:
            return None
        spans = [b - a for a, b in zip(self.token_times, self.token_times[1:])]
        return sum(spans) / len(spans)

    def reset_progress(self):
        """Preemption: work is lost; request restarts its prefill."""
        self.decoded = 0
        self.prefilled = 0
        self.cached_prefix = 0
        self.phase = Phase.PREEMPTED
        self.preemptions += 1

    @property
    def total_context(self) -> int:
        return self.prefilled + self.decoded


def summarize(requests) -> dict:
    done = [r for r in requests if r.finished is not None]
    if not done:
        return {"n": 0}
    e2e = [r.e2e for r in done]
    ttft = [r.ttft for r in done if r.ttft is not None]
    tbt = [r.tbt for r in done if r.tbt is not None]
    mean = lambda xs: sum(xs) / max(len(xs), 1)
    return {
        "n": len(done),
        "e2e_mean": mean(e2e), "e2e_max": max(e2e),
        "ttft_mean": mean(ttft) if ttft else None,
        "tbt_mean": mean(tbt) if tbt else None,
        "makespan": max(r.finished for r in done) - min(r.arrival
                                                        for r in done),
        "preemptions": sum(r.preemptions for r in done),
    }
