"""Sim-vs-engine fidelity harness: does the simulator predict the
engine?

The whole routing stack -- RL training, the heuristics, every benchmark
-- runs on the discrete-event simulator; production serves on real
``LLMInstance`` engines.  The simulator is only trustworthy if, given
the same calibrated ``HardwareProfile``, it produces the same latency
*distributions* the engine does.  This module quantifies that: it
replays ONE gateway arrival stream through

  * the Python-stepper simulator (``Cluster(backend="py")``),
  * the vectorized simulator (``Cluster(backend="vec")``), and
  * real jax engines (``EngineClusterAdapter`` over ``LLMInstance``),

each behind an identically-configured ``Gateway`` under one
``RoutingPolicy``, and reports per-percentile TTFT / TBT / E2E deltas
between every backend pair.  The engine stamps first-token and
completion at the iteration's END -- its virtual clock advances before
the decode pass, the same anchor as ``SimInstance._iteration`` -- so on
a shared profile the virtual-clock deltas are zero and any residual is
a real modelling gap, not an anchoring artifact.  With a calibrated
profile the deltas stay inside a narrow band --
``benchmarks/bench_fidelity.py`` gates that band in CI.

The stream is engine-sized (prompts from a small set of lengths so the
engine pays a bounded number of prefill retraces; decode lengths within
the reduced KV budget) and fully deterministic, so fidelity reports are
reproducible across machines: every clock involved is virtual.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import HardwareProfile, profile_to_json
from repro.serving.gateway import (EngineClusterAdapter, Gateway,
                                   GatewayConfig)
from repro.serving.policies import make_gateway_policy
from repro.serving.request import Request

METRICS = ("ttft", "tbt", "e2e")


@dataclass(frozen=True)
class FidelityConfig:
    """One replayed stream + the serving shape it runs on."""
    n_requests: int = 48
    rate: float = 4.0                  # mean arrival rate (req/s)
    seed: int = 0
    n_instances: int = 2
    n_slots: int = 4
    cache_len: int = 128               # engine KV cache length per slot
    capacity_tokens: int = 400         # profile KV budget (engine-sized)
    # prompts drawn from a FIXED length set: the engine jit-compiles one
    # prefill executable per distinct prompt length
    prompt_lengths: Tuple[int, ...] = (16, 32, 48, 64)
    decode_range: Tuple[int, int] = (4, 48)
    policy: str = "mixing"
    dt: float = 0.02
    max_time: float = 600.0
    quantiles: Tuple[float, ...] = (0.5, 0.95, 0.99)
    # any core.backends registry names; pairwise deltas are reported
    # for every pair, so the default covers both sim steppers, the
    # jitted jax round loop and the real engine
    backends: Tuple[str, ...] = ("py", "vec", "jax", "engine")
    # multi-turn session stream + prefix-cache model: follow-up prompts
    # extend the prior turn's context in whole ``prefix_block`` blocks
    # (prompt lengths stay on a bounded ladder of block multiples, so
    # the engine still pays a bounded number of prefill retraces) and
    # every backend runs a per-instance PrefixCache of
    # ``prefix_cache_tokens``.  Validates that the sim's hit/miss
    # prefill cost tracks the engine's suffix-only virtual clock.
    sessions: bool = False
    prefix_cache_tokens: int = 0
    prefix_block: int = 16
    # saturating stream: arrivals land in back-to-back bursts of
    # ~2*n_slots ladder-top prompts with high-biased decodes, so the
    # resident KV footprint overflows ``capacity_tokens`` and every
    # backend must preempt and queue.  This makes the PREEMPTION path
    # part of the fidelity surface: the report gains per-pair
    # preemption deltas and ``bench_fidelity`` gates that sim and
    # engine preempt alike, not just that their latencies match when
    # nothing contends.
    saturate: bool = False


def serving_profile(profile: HardwareProfile,
                    fcfg: FidelityConfig) -> HardwareProfile:
    """Clamp a (possibly datacenter-sized) profile to the harness's
    engine-sized serving shape so sim and engine share one budget."""
    return dataclasses.replace(
        profile,
        capacity_tokens=min(profile.capacity_tokens,
                            fcfg.capacity_tokens),
        max_batch=fcfg.n_slots)


def make_stream(fcfg: FidelityConfig) -> List[tuple]:
    """The deterministic arrival stream as (prompt, decode, arrival)
    specs -- each backend materializes its own fresh Request objects.
    With ``fcfg.sessions``, specs are 5-tuples that append the
    per-block (prefix_hashes, full_hashes) chains of a growing
    multi-turn conversation."""
    rng = np.random.default_rng(fcfg.seed)
    if fcfg.saturate:
        # bursts of 2*n_slots simultaneous ladder-top requests: with
        # high-biased decodes the per-request peak KV footprint times
        # n_slots residents exceeds the profile budget, so backends
        # must preempt (and the overflow half of each burst queues)
        g = max(2 * fcfg.n_slots, 2)
        n_groups = -(-fcfg.n_requests // g)
        group_t = np.cumsum(rng.exponential(g / fcfg.rate,
                                            size=n_groups))
        p_top = int(max(fcfg.prompt_lengths))
        lo, hi = fcfg.decode_range
        d_lo = max(lo, hi - max((hi - lo) // 4, 1))
        out = []
        for gi in range(n_groups):
            for j in range(g):
                if len(out) >= fcfg.n_requests:
                    break
                d = int(rng.integers(d_lo, hi + 1))
                out.append((p_top, d, float(group_t[gi]) + j * 1e-3))
        return out
    if not fcfg.sessions:
        gaps = rng.exponential(1.0 / fcfg.rate, size=fcfg.n_requests)
        arrivals = np.cumsum(gaps)
        lengths = rng.choice(fcfg.prompt_lengths, size=fcfg.n_requests)
        lo, hi = fcfg.decode_range
        decodes = rng.integers(lo, hi + 1, size=fcfg.n_requests)
        return [(int(p), int(d), float(t))
                for p, d, t in zip(lengths, decodes, arrivals)]
    B = fcfg.prefix_block
    # context ladder bounded so the engine compiles few prefill shapes
    # and every turn fits the engine-sized KV budget
    max_blocks = min(int(fcfg.capacity_tokens * 0.9) // B, 10)
    n_sessions = max(fcfg.n_requests // 3, 1)
    starts = np.cumsum(rng.exponential(3.0 / fcfg.rate,
                                       size=n_sessions))
    out: List[tuple] = []
    sid = 0
    while len(out) < fcfg.n_requests:
        t = float(starts[sid % n_sessions]) + (sid // n_sessions) * 30.0
        chain: List[tuple] = []
        p_blocks = int(rng.integers(1, 3))
        for _turn in range(int(rng.integers(2, 5))):
            d_blocks = int(rng.integers(1, 3))
            if p_blocks + d_blocks > max_blocks:
                break
            while len(chain) < p_blocks + d_blocks:
                chain.append((fcfg.seed, sid, len(chain)))
            out.append((p_blocks * B, d_blocks * B, t,
                        tuple(chain[:p_blocks]),
                        tuple(chain[:p_blocks + d_blocks])))
            t += 1.0 + float(rng.exponential(1.0))
            p_blocks = p_blocks + d_blocks + 1
        sid += 1
    out.sort(key=lambda x: x[2])
    return out[:fcfg.n_requests]


def _requests(stream: Sequence[tuple]) -> List[Request]:
    out = []
    for spec in stream:
        p, d, t = spec[:3]
        hashes = spec[3:] if len(spec) > 3 else (None, None)
        out.append(Request(prompt_tokens=p, decode_tokens=d, arrival=t,
                           tenant="fidelity", prefix_hashes=hashes[0],
                           full_hashes=hashes[1]))
    return out


def _gateway_cfg(fcfg: FidelityConfig, backend: str) -> GatewayConfig:
    return GatewayConfig(dt=fcfg.dt, n_slots=fcfg.n_slots,
                         max_time=fcfg.max_time,
                         backend=backend if backend != "engine" else "py",
                         prefix_cache_tokens=fcfg.prefix_cache_tokens,
                         prefix_block=fcfg.prefix_block)


def _percentiles(vals: List[float], quantiles: Sequence[float]) -> Dict:
    out = {}
    arr = np.array([v for v in vals if v is not None], float)
    for q in quantiles:
        key = f"p{int(q * 100)}"
        out[key] = float(np.quantile(arr, q)) if arr.size else None
    out["mean"] = float(arr.mean()) if arr.size else None
    out["n"] = int(arr.size)
    return out


def _backend_cluster(backend: str, profile: HardwareProfile,
                     fcfg: FidelityConfig, model_cfg, params):
    if backend != "engine":
        return None                      # Gateway builds the sim cluster
    import jax
    from repro.models import params as params_lib
    from repro.serving.engine import LLMInstance
    from repro.serving.scheduler import get_scheduler
    if params is None:
        if model_cfg is None:
            raise ValueError("backend 'engine' needs model_cfg (and "
                             "optionally params)")
        params = params_lib.init_params(jax.random.PRNGKey(0), model_cfg)
    engines = [LLMInstance(model_cfg, params, profile,
                           get_scheduler("fcfs"), n_slots=fcfg.n_slots,
                           cache_len=fcfg.cache_len, instance_id=i,
                           prefix_cache_tokens=fcfg.prefix_cache_tokens,
                           prefix_block=fcfg.prefix_block)
               for i in range(fcfg.n_instances)]
    return EngineClusterAdapter(engines, dt=fcfg.dt)


def run_backend(backend: str, profile: HardwareProfile,
                fcfg: FidelityConfig, stream, model_cfg=None,
                params=None) -> Dict:
    """Serve the stream on one backend; returns the percentile report."""
    prof = serving_profile(profile, fcfg)
    reqs = _requests(stream)
    cluster = _backend_cluster(backend, prof, fcfg, model_cfg, params)
    gw = Gateway(_gateway_cfg(fcfg, backend),
                 (prof,) * fcfg.n_instances,
                 make_gateway_policy(fcfg.policy), cluster=cluster)
    stats = gw.run(reqs)
    done = [r for r in reqs if r.finished is not None]
    report = {m: _percentiles([getattr(r, m) for r in done],
                              fcfg.quantiles) for m in METRICS}
    report["completed"] = len(done)
    report["preemptions"] = int(sum(r.preemptions for r in reqs))
    report["makespan"] = (max(r.finished for r in done)
                          - min(r.arrival for r in done)) if done else None
    report["shed"] = stats["shed"]
    caches = [getattr(inst, "prefix_cache", None)
              for inst in gw.cluster.instances]
    hit = sum(c.hit_tokens for c in caches if c is not None)
    look = sum(c.lookup_tokens for c in caches if c is not None)
    report["cache_hit_rate"] = (hit / look) if look else None
    return report


def _deltas(a: Dict, b: Dict, quantiles: Sequence[float]) -> Dict:
    """Per-metric percentile deltas b - a (absolute and relative)."""
    out = {}
    for m in METRICS:
        md = {}
        for q in quantiles:
            key = f"p{int(q * 100)}"
            va, vb = a[m].get(key), b[m].get(key)
            if va is None or vb is None:
                md[key] = {"abs": None, "rel": None}
            else:
                md[key] = {"abs": vb - va,
                           "rel": (vb - va) / va if va else None}
        out[m] = md
    # preemption fidelity: do both backends preempt, and comparably?
    pa, pb = a["preemptions"], b["preemptions"]
    out["preemptions"] = {"a": pa, "b": pb, "abs": pb - pa,
                          "both_preempt": bool(pa > 0 and pb > 0)}
    return out


def run_fidelity(profile: HardwareProfile,
                 fcfg: Optional[FidelityConfig] = None,
                 model_cfg=None, params=None) -> Dict:
    """The harness: one stream, every configured backend, all pairwise
    percentile deltas.  ``model_cfg``/``params`` are only needed when
    ``fcfg.backends`` includes ``"engine"``."""
    fcfg = fcfg or FidelityConfig()
    stream = make_stream(fcfg)
    backends = {}
    for backend in fcfg.backends:
        backends[backend] = run_backend(backend, profile, fcfg, stream,
                                        model_cfg, params)
    deltas = {}
    names = list(fcfg.backends)
    for i, a in enumerate(names):
        for b in names[i + 1:]:
            deltas[f"{b}_vs_{a}"] = _deltas(backends[a], backends[b],
                                            fcfg.quantiles)
    return {
        "profile": profile_to_json(serving_profile(profile, fcfg)),
        "config": dataclasses.asdict(fcfg),
        "backends": backends,
        "deltas": deltas,
    }


def save_report(report: Dict, path: str):
    with open(path, "w") as f:
        json.dump(report, f, indent=2)


def format_report(report: Dict) -> str:
    """Human-readable fidelity table (per-backend percentiles + the
    headline engine-vs-sim deltas)."""
    lines = []

    def f(v):
        return f"{v:8.3f}" if v is not None else "       -"
    for name, rep in report["backends"].items():
        lines.append(
            f"{name:>7s}  n={rep['completed']:<3d} "
            f"e2e p50/p95={f(rep['e2e']['p50'])}{f(rep['e2e']['p95'])}  "
            f"ttft p95={f(rep['ttft']['p95'])}  "
            f"tbt p95={f(rep['tbt']['p95'])}  "
            f"preempt={rep['preemptions']}")
    for pair, d in report["deltas"].items():
        e95 = d["e2e"]["p95"]["rel"]
        t95 = d["ttft"]["p95"]["rel"]
        lines.append(f"{pair:>16s}: e2e p95 rel delta="
                     f"{e95:+.3f}" if e95 is not None else
                     f"{pair:>16s}: e2e p95 rel delta=-")
        if t95 is not None:
            lines[-1] += f"  ttft p95 rel delta={t95:+.3f}"
    return "\n".join(lines)
