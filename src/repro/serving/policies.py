"""Pluggable routing policies for the serving gateway.

One ``RoutingPolicy`` protocol unifies the repo's previously scattered
routing paths -- the heuristic baselines in ``core.policies`` (driven
through ``simulator.run_heuristic``), the r_mixing heuristic embedded in
``RoutingEnv.guidance_bonus``, and the trained RL agent driven by
``ManagedCluster.serve`` -- so any of them is a one-line swap in the
gateway / ``launch.serve``:

    route(cluster, req, d_hat) -> Optional[int]

returns an instance index, or ``None`` / ``>= cluster.m`` to defer the
head-of-queue request.  ``d_hat`` is the gateway's decode-length
estimate (the micro-batched learned predictor in production, the oracle
in parity tests); policies never read ``req.decode_tokens`` directly.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

import numpy as np

from repro.core import policies as legacy, rl_router as rl
from repro.core import state as state_lib
from repro.core.prefix_cache import hit_fractions


def healthy_candidates(cluster):
    """Alive instances minus any the gateway's circuit breaker has
    opened on (``cluster.health_mask``, stamped per tick by the
    gateway's HealthTracker).  Falls back to the full alive set if the
    mask would empty it -- a degraded instance beats no instance."""
    alive = cluster.alive()
    hm = getattr(cluster, "health_mask", None)
    if hm is None:
        return alive
    ok = [i for i in alive if i >= len(hm) or hm[i]]
    return ok or alive


@runtime_checkable
class RoutingPolicy(Protocol):
    """Structural protocol: ``route`` is required.  Policies MAY also
    provide ``explain(cluster, req, d_hat) -> dict`` returning the
    per-instance score breakdown behind the same decision ``route``
    would make (r_mixing terms, loads, cache-hit fractions, Q-values);
    the gateway attaches it to the ``route`` trace event for decision
    attribution.  ``explain`` must be read-only: it is called AFTER
    ``route`` on the same state and must not perturb the decision
    stream (the traced-vs-untraced overhead gate enforces this)."""

    name: str

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        ...


class RoundRobinPolicy:
    """Alternate over alive (and non-breakered) instances (the paper's
    primary baseline)."""
    name = "rr"

    def __init__(self):
        self._next = 0

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        alive = healthy_candidates(cluster)
        if not alive:
            return None
        idx = alive[self._next % len(alive)]
        self._next += 1
        return idx


class LeastOutstandingWork:
    """JSQ on estimated outstanding tokens.  Unlike the legacy oracle
    JSQ (§A.2.1) the queue-side estimate uses d_hat bookkeeping per
    routed request, so it works with a learned predictor."""
    name = "jsq"

    def __init__(self):
        self._est: dict = {}           # rid -> d_hat at routing time

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        alive = healthy_candidates(cluster)
        if not alive:
            return None
        loads = self._loads(cluster, alive)
        pick = alive[int(np.argmin(loads))]
        self._est[req.rid] = d_hat
        return pick

    def _loads(self, cluster, alive):
        loads = []
        for i in alive:
            inst = cluster.instances[i]
            todo = 0.0
            for r in inst.residents:
                todo += (r.prompt_tokens - r.prefilled) + max(
                    self._est.get(r.rid, r.decode_tokens) - r.decoded, 0)
            for r in inst.queue:
                todo += r.prompt_tokens + self._est.get(r.rid,
                                                        r.decode_tokens)
            loads.append(todo)
        return loads

    def explain(self, cluster, req, d_hat: int) -> dict:
        """Estimated outstanding-token load per alive instance (the
        argmin is the pick)."""
        alive = healthy_candidates(cluster)
        return {"loads": [float(x)
                          for x in self._loads(cluster, alive)],
                "alive": list(alive)}


class PrefixAffinityPolicy:
    """Sticky-session baseline (llama-balancer's prompt-cache routing):
    send the request to the alive instance holding its longest cached
    prefix; break ties -- including the all-miss cold path -- by least
    outstanding tokens.  Purely greedy on cache affinity, no workload
    mixing: the baseline the cache-weighted heuristics must beat."""
    name = "sticky"

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        alive = healthy_candidates(cluster)
        if not alive:
            return None
        fracs = hit_fractions(cluster, req)
        best = max(fracs[i] for i in alive)
        tied = [i for i in alive if fracs[i] == best]
        if len(tied) == 1:
            return tied[0]
        loads = [cluster.instances[i].outstanding_tokens() for i in tied]
        return tied[int(np.argmin(loads))]

    def explain(self, cluster, req, d_hat: int) -> dict:
        """Per-instance cached-prefix hit fraction + the tie-break
        outstanding-token loads."""
        return {"hit_frac": [float(f)
                             for f in hit_fractions(cluster, req)],
                "loads": [float(inst.outstanding_tokens())
                          for inst in cluster.instances]}


class MixingImpactPolicy:
    """The paper's workload-impact heuristic (Eq. 1-2) with the
    capacity-fit defer correction -- exactly the prior that guides the
    RL router, served standalone.  ``cache_weight > 0`` adds the
    per-instance prefix-cache hit fraction to the scores ("mixing+cache"
    in the factory), trading load balance against prefill reuse."""
    name = "mixing"

    def __init__(self, alpha: float = 0.5,
                 defer_prior_bias: float = -0.05,
                 cache_weight: float = 0.0):
        self.alpha = alpha
        self.defer_prior_bias = defer_prior_bias
        self.cache_weight = cache_weight
        if cache_weight:
            self.name = "mixing+cache"

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        if not cluster.alive():
            return None
        scores = rl.mixing_scores(cluster, req, d_hat, self.alpha,
                                  cache_weight=self.cache_weight)
        bonus = rl.guidance_from_scores(cluster, req, d_hat, scores,
                                        self.defer_prior_bias)
        a = int(np.argmax(bonus))
        return a if a < cluster.m else None

    def explain(self, cluster, req, d_hat: int) -> dict:
        """The r_mixing score vector (with this policy's cache weight)
        and the capacity-corrected guidance bonus whose argmax is the
        decision."""
        scores = rl.mixing_scores(cluster, req, d_hat, self.alpha,
                                  cache_weight=self.cache_weight)
        bonus = rl.guidance_from_scores(cluster, req, d_hat, scores,
                                        self.defer_prior_bias)
        out = {"scores": [float(s) for s in scores],
               "bonus": [float(b) for b in bonus]}
        if self.cache_weight:
            out["hit_frac"] = [float(f)
                               for f in hit_fractions(cluster, req)]
        return out


class RLPolicy:
    """A trained DQN router behind the gateway.  Decision math is
    identical to ``ManagedCluster.serve`` (greedy masked Q + guidance
    prior, decomposed-arch aware), so a gateway with the oracle
    predictor reproduces the closed-loop path decision for decision
    (tests/test_gateway.py::test_policy_parity_with_managed_cluster)."""
    name = "rl"

    def __init__(self, agent, router_cfg: rl.RouterConfig):
        self.agent = agent
        self.cfg = router_cfg

    def hot_swap(self, params, target=None):
        """Atomically publish refreshed Q weights onto the served agent.

        Each swap is a single attribute rebinding (one reference store,
        atomic under the GIL) of an immutable param tree: a concurrent
        ``route`` reads ``self.agent.params`` exactly once per decision
        and sees either the old or the new tree in full -- never a torn
        mix of layers (pinned by tests/test_online.py).  The online
        trainer calls this between arrival windows; admission never
        pauses."""
        self.agent.params = params
        if target is not None:
            self.agent.target = target

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        cfg = self.cfg
        mask = state_lib.action_mask(cluster)
        w_sel = cfg.guidance_floor if cfg.variant == "guided" else 0.0
        scores = rl.mixing_scores(cluster, req, d_hat, cfg.alpha,
                                  cache_weight=cfg.cache_weight)
        bonus = rl.guidance_from_scores(cluster, req, d_hat, scores,
                                        cfg.defer_prior_bias)
        if (self.agent.cfg.q_arch == "decomposed"
                or cluster.m + 1 == self.agent.cfg.n_actions):
            s = state_lib.featurize(
                cluster, cluster.profile, n_buckets=cfg.n_buckets,
                include_impact=cfg.include_impact_features,
                predict_decode=lambda r: d_hat, alpha=cfg.alpha,
                include_hardware=cfg.include_hardware_features,
                include_cache=cfg.include_cache_features,
                include_health=cfg.include_health_features)
            prior = w_sel * bonus if w_sel else None
            return int(self.agent.act(
                s, mask, epsilon=0.0, prior=prior,
                q_squash=cfg.q_squash if w_sel else 0.0))
        # fixed-m MLP cannot score a resized cluster: fall back to the
        # guidance heuristic (same degradation as ManagedCluster)
        bonus[~mask] = -np.inf
        return int(np.argmax(bonus))

    def explain(self, cluster, req, d_hat: int) -> dict:
        """Decompose the greedy decision: raw Q-values, the guidance
        prior actually added, and the selection vector ``sel`` whose
        masked argmax is the action ``route`` returns."""
        from repro.core import dqn
        cfg = self.cfg
        mask = state_lib.action_mask(cluster)
        w_sel = cfg.guidance_floor if cfg.variant == "guided" else 0.0
        scores = rl.mixing_scores(cluster, req, d_hat, cfg.alpha,
                                  cache_weight=cfg.cache_weight)
        bonus = rl.guidance_from_scores(cluster, req, d_hat, scores,
                                        cfg.defer_prior_bias)
        out = {"scores": [float(s) for s in scores],
               "bonus": [float(b) for b in bonus]}
        if not (self.agent.cfg.q_arch == "decomposed"
                or cluster.m + 1 == self.agent.cfg.n_actions):
            sel = np.where(mask, bonus, -np.inf)
            out["sel"] = [float(x) for x in sel]
            out["fallback"] = True
            return out
        s = state_lib.featurize(
            cluster, cluster.profile, n_buckets=cfg.n_buckets,
            include_impact=cfg.include_impact_features,
            predict_decode=lambda r: d_hat, alpha=cfg.alpha,
            include_hardware=cfg.include_hardware_features,
            include_cache=cfg.include_cache_features,
            include_health=cfg.include_health_features)
        q = np.asarray(dqn.q_values(self.agent.cfg, self.agent.params,
                                    np.asarray(s, np.float32)[None]))[0]
        out["q"] = [float(x) for x in q]
        sel = q.astype(np.float64).copy()
        squash = cfg.q_squash if w_sel else 0.0
        if squash > 0:
            masked = np.where(mask, sel, -np.inf)
            ref = float(masked.max()) if np.isfinite(masked).any() else 0.0
            sel = squash * np.tanh(sel - ref)
        if w_sel:
            prior = w_sel * bonus
            out["prior"] = [float(x) for x in prior]
            sel = sel + prior
        sel[~mask] = -np.inf
        out["sel"] = [float(x) for x in sel]
        return out


class LegacyPolicyAdapter:
    """Wrap a ``core.policies`` heuristic (oracle decode lengths) into
    the gateway protocol -- for baseline comparisons only."""

    def __init__(self, policy):
        self.policy = policy
        self.name = f"legacy:{policy.name}"

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        return self.policy.act(cluster)


def restore_rl_policy(router_cfg: rl.RouterConfig, checkpoint_dir: str,
                      m: Optional[int] = None) -> RLPolicy:
    """Rebuild the agent for an m-wide action space and restore its
    weights from a ``training.checkpoint`` directory (the artifact
    ``ManagedCluster.save_router`` / the trainers write)."""
    from repro.training.checkpoint import CheckpointManager
    agent = rl.make_agent(router_cfg, m=m)
    out = CheckpointManager(checkpoint_dir).restore(agent.state_dict())
    if out is None:
        raise FileNotFoundError(
            f"no router checkpoint under {checkpoint_dir}")
    agent.load_state_dict(out[0])
    return RLPolicy(agent, router_cfg)


def make_gateway_policy(name: str, router_cfg: Optional[rl.RouterConfig]
                        = None, agent=None, profile=None,
                        checkpoint_dir: Optional[str] = None,
                        m: Optional[int] = None):
    """Policy factory: ``rr`` | ``jsq`` | ``mixing`` | ``mixing+cache``
    | ``sticky`` | ``rl`` (needs an ``agent`` or ``checkpoint_dir``),
    or any ``core.policies`` name (oracle-length legacy baselines,
    adapter-wrapped)."""
    cfg = router_cfg or rl.RouterConfig()
    if name in ("rr", "round_robin"):
        return RoundRobinPolicy()
    if name == "jsq":
        return LeastOutstandingWork()
    if name == "sticky":
        return PrefixAffinityPolicy()
    if name == "mixing+cache":
        return MixingImpactPolicy(
            alpha=cfg.alpha, defer_prior_bias=cfg.defer_prior_bias,
            cache_weight=cfg.cache_weight or 0.5)
    if name == "mixing":
        return MixingImpactPolicy(alpha=cfg.alpha,
                                  defer_prior_bias=cfg.defer_prior_bias)
    if name == "rl":
        if agent is not None:
            return RLPolicy(agent, cfg)
        if checkpoint_dir is not None:
            return restore_rl_policy(cfg, checkpoint_dir, m=m)
        raise ValueError("policy 'rl' needs agent= or checkpoint_dir=")
    return LegacyPolicyAdapter(legacy.make_policy(name, profile))
