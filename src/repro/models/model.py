"""Model assembly: scan-over-layers decoder supporting every assigned arch.

Three entry points:
  forward_train(params, cfg, tokens, ...)          -> (logits, aux)
  prefill(params, cfg, tokens, cache_len, ...)     -> (last_logits, cache)
  decode_step(params, cfg, cache, tokens, ...)     -> (logits, cache)

The layer stack is grouped into ``n_periods`` repetitions of a
``period``-long pattern (e.g. jamba: 7 mamba + 1 attn); parameters are
stacked with a leading n_periods axis and the stack is traversed with
``lax.scan`` so HLO size is independent of depth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mamba_mod
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ops


def ffn_forward(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    act = ops.activation(cfg.activation)
    h = x @ p["w_up"]
    if cfg.gated_mlp:
        h = act(x @ p["w_gate"]) * h
    else:
        h = act(h)
    return h @ p["w_down"]


def cache_kv_heads(cfg: ModelConfig) -> int:
    """KV-head count as stored in the decode cache.  When KV doesn't
    divide the TP axis but H does, the cache stores EXPANDED heads (full
    H, "model"-sharded): per-device bytes shrink vs a replicated/hd-split
    layout and -- critically -- the per-step all-gather of the whole cache
    (q heads sharded vs cache hd sharded) disappears."""
    from repro.distributed import context as dist_ctx
    tp = dist_ctx.tp_size()
    if tp > 1 and cfg.n_kv_heads % tp != 0 and cfg.n_heads % tp == 0:
        return cfg.n_heads
    return cfg.n_kv_heads


def _empty_cache_entry(cfg: ModelConfig, kind: str, batch: int,
                       cache_len: int, dtype):
    hd, kv = cfg.head_dim, cfg.n_kv_heads
    if kind not in ("mamba",) and cfg.attention != "mla":
        kv = cache_kv_heads(cfg)
    if kind == "mamba":
        m = cfg.mamba
        return {"conv": jnp.zeros((batch, m.d_conv - 1, m.d_inner), dtype),
                "ssm": jnp.zeros((batch, m.d_inner, m.d_state), dtype)}
    if kind == "cross":
        return {"k": jnp.zeros((batch, cfg.vision_tokens, kv, hd), dtype),
                "v": jnp.zeros((batch, cfg.vision_tokens, kv, hd), dtype)}
    if cfg.attention == "mla":
        m = cfg.mla
        return {"ckv": jnp.zeros((batch, cache_len, m.kv_lora_rank), dtype),
                "kr": jnp.zeros((batch, cache_len, m.qk_rope_head_dim),
                                dtype)}
    if cfg.kv_cache_dtype == "int8":
        return {"k": jnp.zeros((batch, cache_len, kv, hd), jnp.int8),
                "v": jnp.zeros((batch, cache_len, kv, hd), jnp.int8),
                "k_scale": jnp.ones((batch, cache_len, kv), jnp.bfloat16),
                "v_scale": jnp.ones((batch, cache_len, kv), jnp.bfloat16)}
    return {"k": jnp.zeros((batch, cache_len, kv, hd), dtype),
            "v": jnp.zeros((batch, cache_len, kv, hd), dtype)}


def init_cache(cfg: ModelConfig, batch: int, cache_len: int) -> Dict:
    """Zero-filled decode cache (also the donation target for serve_step)."""
    dtype = jnp.dtype(cfg.dtype)
    layers = []
    for pos in range(cfg.period):
        kind = cfg.layer_kind(pos)
        entry = _empty_cache_entry(cfg, kind, batch, cache_len, dtype)
        layers.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_periods,) + x.shape),
            entry))
    cache: Dict[str, Any] = {"layers": layers,
                             "pos": jnp.zeros((batch,), jnp.int32)}
    if cfg.dense_first_layer:
        cache["first_layer"] = _empty_cache_entry(
            cfg, "attn", batch, cache_len, dtype)
    return cache


# ---------------------------------------------------------------------------
# single-layer forward
# ---------------------------------------------------------------------------

def _layer(p: Dict, cfg: ModelConfig, pos_in_period: int, x: jax.Array,
           positions: jax.Array, mode: str, cache_entry, vis: Optional[
               jax.Array], cache_len: int):
    """One layer.  Returns (x, new_cache_entry, aux)."""
    kind = cfg.layer_kind(pos_in_period)
    h = ops.rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_entry = cache_entry
    if kind == "mamba":
        if mode == "decode":
            y, new_entry = mamba_mod.mamba_decode(p["mamba"], cfg, h,
                                                  cache_entry)
        else:
            y, states = mamba_mod.mamba_seq(p["mamba"], cfg, h)
            if mode == "prefill":
                new_entry = states
    elif kind == "cross":
        if mode == "decode":
            vis_kv = cache_entry
        else:
            vis_kv = attn.vision_kv(p["attn"], cfg, vis)
            if mode == "prefill":
                new_entry = vis_kv
        y = attn.cross_attention(p["attn"], cfg, h, vis_kv)
        y = y * jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(y.dtype)
    elif cfg.attention == "mla":
        if mode == "train":
            y = mla_mod.mla_train(p["attn"], cfg, h, positions)
        elif mode == "prefill":
            y, new_entry = mla_mod.mla_prefill(p["attn"], cfg, h, positions,
                                               cache_len)
        else:
            y, new_entry = mla_mod.mla_decode(p["attn"], cfg, h, positions,
                                              cache_entry)
    else:
        if mode == "train":
            y = attn.self_attention_train(p["attn"], cfg, h, positions)
        elif mode == "prefill":
            y, new_entry = attn.self_attention_prefill(p["attn"], cfg, h,
                                                       positions, cache_len)
        else:
            y, new_entry = attn.self_attention_decode(p["attn"], cfg, h,
                                                      positions, cache_entry)
    x = x + y
    aux = jnp.zeros((), jnp.float32)
    if "ln2" in p:
        h2 = ops.rmsnorm(x, p["ln2"], cfg.norm_eps)
        if "moe" in p:
            y2, aux = moe_mod.moe_layer(p["moe"], cfg, h2)
        else:
            y2 = ffn_forward(p["ffn"], cfg, h2)
        if kind == "cross":
            y2 = y2 * jnp.tanh(
                p["gate_ffn"].astype(jnp.float32)).astype(y2.dtype)
        x = x + y2
    return x, new_entry, aux


# ---------------------------------------------------------------------------
# full stack
# ---------------------------------------------------------------------------

def _embed_inputs(params: Dict, cfg: ModelConfig, tokens, embeds):
    if cfg.input_mode == "tokens":
        x = params["embed"][tokens]
        if cfg.scale_embeddings:
            x = x * jnp.asarray(jnp.sqrt(float(cfg.d_model)), x.dtype)
    else:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    return x


def _head(params: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = ops.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        logits = x @ params["embed"].T
    else:
        logits = x @ params["lm_head"]
    return ops.softcap(logits, cfg.logit_softcap)


def _run_stack(params: Dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array, mode: str, cache: Optional[Dict],
               vis: Optional[jax.Array], cache_len: int):
    """Apply first_layer (if any) + the scanned periodic stack."""
    aux_total = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    if cfg.dense_first_layer:
        entry = cache.get("first_layer") if cache else None
        x, new_entry, aux = _layer(params["first_layer"], cfg, 0, x,
                                   positions, mode, entry, vis, cache_len)
        aux_total += aux
        if new_cache is not None and mode in ("prefill", "decode"):
            new_cache["first_layer"] = new_entry

    from repro.distributed import context as dist_ctx

    def body(carry, xs):
        h, aux_acc = carry
        h = dist_ctx.constrain_batch(h)
        layer_params, layer_cache = xs
        new_entries = []
        for pos in range(cfg.period):
            entry = None if layer_cache is None else layer_cache[pos]
            h, new_entry, aux = _layer(layer_params[pos], cfg, pos, h,
                                       positions, mode, entry, vis,
                                       cache_len)
            new_entries.append(new_entry)
        h = dist_ctx.constrain_batch(h)
        return (h, aux_acc + aux), (new_entries if mode != "train" else 0)

    if cfg.remat and mode == "train":
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    layer_cache_xs = None if cache is None else cache["layers"]
    xs = (params["layers"], layer_cache_xs)
    (x, aux_total), cache_out = jax.lax.scan(
        body, (x, aux_total), xs, unroll=True if cfg.scan_unroll else 1)
    if new_cache is not None and mode in ("prefill", "decode"):
        new_cache["layers"] = cache_out
    return x, new_cache, aux_total


def forward_train(params: Dict, cfg: ModelConfig, tokens=None,
                  embeds=None, vision=None, positions=None):
    """Full-sequence forward (no cache).  Returns (logits [B,S,V], aux)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if vision is not None and "vision_proj" in params:
        vis = vision.astype(x.dtype) @ params["vision_proj"]
    else:
        vis = None
    x, _, aux = _run_stack(params, cfg, x, positions, "train", None, vis, s)
    return _head(params, cfg, x), aux


def prefill(params: Dict, cfg: ModelConfig, tokens=None, embeds=None,
            vision=None, cache_len: int = 0, lengths=None):
    """Process the prompt, build the decode cache.

    Returns (last_token_logits [B,V], cache)."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[0], x.shape[1]
    cache_len = cache_len or cfg.max_seq_len
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if vision is not None and "vision_proj" in params:
        vis = vision.astype(x.dtype) @ params["vision_proj"]
    else:
        vis = None
    cache = init_cache(cfg, b, cache_len)
    x, cache, _ = _run_stack(params, cfg, x, positions, "prefill", cache,
                             vis, cache_len)
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    cache["pos"] = lengths
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
    return _head(params, cfg, last)[:, 0], cache


def decode_step(params: Dict, cfg: ModelConfig, cache: Dict, tokens=None,
                embeds=None):
    """One decode step for the whole batch.  tokens [B] (or embeds [B,1,d]).

    Returns (logits [B,V], new_cache)."""
    if tokens is not None:
        x = _embed_inputs(params, cfg, tokens[:, None], None)
    else:
        x = _embed_inputs(params, cfg, None, embeds)
    positions = cache["pos"]                        # [B]
    x, cache, _ = _run_stack(params, cfg, x, positions, "decode", cache,
                             None, 0)
    cache["pos"] = positions + 1
    return _head(params, cfg, x)[:, 0], cache


def forward_hidden(params: Dict, cfg: ModelConfig, tokens=None,
                   embeds=None, vision=None, positions=None):
    """Like forward_train but stops at the final-normed hidden states."""
    x = _embed_inputs(params, cfg, tokens, embeds)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if vision is not None and "vision_proj" in params:
        vis = vision.astype(x.dtype) @ params["vision_proj"]
    else:
        vis = None
    x, _, aux = _run_stack(params, cfg, x, positions, "train", None, vis, s)
    return ops.rmsnorm(x, params["final_norm"], cfg.norm_eps), aux


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict) -> Tuple[jax.Array,
                                                                  Dict]:
    """Next-token LM loss (+ MoE aux), with sequence-chunked CE so the
    full-vocab logits tensor is never materialized."""
    hidden, aux = forward_hidden(
        params, cfg,
        tokens=batch.get("tokens"),
        embeds=batch.get("embeds"),
        vision=batch.get("vision"))
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        w_head = params["embed"].T
    else:
        w_head = params["lm_head"]
    loss = ops.chunked_cross_entropy(hidden, w_head, batch["labels"],
                                     cfg.logit_softcap,
                                     unroll=cfg.scan_unroll)
    total = loss
    if cfg.moe is not None:
        total = total + cfg.moe.router_aux_weight * aux
    return total, {"lm_loss": loss, "moe_aux": aux}
