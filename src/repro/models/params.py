"""Parameter initialization + analytic counting for the model zoo.

Params are nested dicts of jnp arrays.  Layers that repeat are stacked with a
leading ``n_periods`` dimension (one stacked tree per position in the layer
period) so the forward pass can ``lax.scan`` over them.
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _dense(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _expert_storage(cfg: ModelConfig, data_shards: int) -> int:
    """Physical leading dim of routed-expert weights.

    For the expert-parallel path each of the ``data_shards`` devices owns one
    slot; experts are replicated ``R = shards // E`` times when E < shards
    (grad symmetrization handles training).  For non-EP impls it is just E.
    """
    e = cfg.moe.n_experts
    if cfg.moe.impl == "ep" and data_shards > 0:
        if e < data_shards:
            assert data_shards % e == 0, (e, data_shards)
            return data_shards
        assert e % data_shards == 0, (e, data_shards)
    return e


def init_ffn(key, cfg: ModelConfig, d_ff: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"w_up": _dense(ks[0], (d, d_ff), dtype),
         "w_down": _dense(ks[1], (d_ff, d), dtype)}
    if cfg.gated_mlp:
        p["w_gate"] = _dense(ks[2], (d, d_ff), dtype)
    return p


def init_moe(key, cfg: ModelConfig, dtype, data_shards: int) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    d, m = cfg.d_model, cfg.moe
    e_store = _expert_storage(cfg, data_shards)
    fe = m.d_expert
    # routed experts: stacked [E_store, ...]
    routed = {"w_up": _dense(ks[0], (e_store, d, fe), dtype, fan_in=d),
              "w_down": _dense(ks[1], (e_store, fe, d), dtype, fan_in=fe)}
    if cfg.gated_mlp:
        routed["w_gate"] = _dense(ks[2], (e_store, d, fe), dtype, fan_in=d)
    p = {"router": _dense(ks[3], (d, m.n_experts), jnp.float32),
         "routed": routed}
    if m.n_shared > 0:
        p["shared"] = init_ffn(ks[4], cfg, m.n_shared * fe, dtype)
    return p


def init_attn(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {"wq": _dense(ks[0], (d, h, hd), dtype, fan_in=d),
         "wk": _dense(ks[1], (d, kv, hd), dtype, fan_in=d),
         "wv": _dense(ks[2], (d, kv, hd), dtype, fan_in=d),
         "wo": _dense(ks[3], (h, hd, d), dtype, fan_in=h * hd)}
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dtype)
        p["k_norm"] = jnp.zeros((hd,), dtype)
    return p


def init_mla(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 6)
    d, h, m = cfg.d_model, cfg.n_heads, cfg.mla
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "wq_a": _dense(ks[0], (d, m.q_lora_rank), dtype),
        "q_norm_a": jnp.zeros((m.q_lora_rank,), dtype),
        "wq_b": _dense(ks[1], (m.q_lora_rank, h, qk), dtype,
                       fan_in=m.q_lora_rank),
        "wkv_a": _dense(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim),
                        dtype),
        "kv_norm_a": jnp.zeros((m.kv_lora_rank,), dtype),
        "wkv_b": _dense(ks[3], (m.kv_lora_rank, h,
                                m.qk_nope_head_dim + m.v_head_dim), dtype,
                        fan_in=m.kv_lora_rank),
        "wo": _dense(ks[4], (h, m.v_head_dim, d), dtype,
                     fan_in=h * m.v_head_dim),
    }
    return p


def init_mamba(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 5)
    d, m, dtr = cfg.d_model, cfg.mamba, cfg.dt_rank
    di, ds, dc = m.d_inner, m.d_state, m.d_conv
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": _dense(ks[0], (d, 2 * di), dtype),
        "conv_w": _dense(ks[1], (dc, di), dtype, fan_in=dc),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": _dense(ks[2], (di, dtr + 2 * ds), dtype, fan_in=di),
        "dt_proj": _dense(ks[3], (dtr, di), dtype, fan_in=dtr),
        "dt_bias": jnp.full((di,), -4.6, dtype),   # softplus^-1(0.01)
        "A_log": jnp.log(a).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense(ks[4], (di, d), dtype, fan_in=di),
    }


def init_layer(key, cfg: ModelConfig, pos_in_period: int, dtype,
               data_shards: int) -> Dict[str, Any]:
    kind = cfg.layer_pattern[pos_in_period]
    is_moe = cfg.moe_pattern[pos_in_period]
    ks = jax.random.split(key, 3)
    p: Dict[str, Any] = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    if kind == "mamba":
        p["mamba"] = init_mamba(ks[0], cfg, dtype)
    elif kind == "cross":
        p["attn"] = init_attn(ks[0], cfg, dtype)
        p["gate_attn"] = jnp.zeros((), dtype)
        p["gate_ffn"] = jnp.zeros((), dtype)
    elif cfg.attention == "mla":
        p["attn"] = init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = init_attn(ks[0], cfg, dtype)
    # feed-forward sub-block (absent for pure-SSM archs with d_ff == 0)
    has_ffn = is_moe or cfg.d_ff > 0
    if kind == "mamba" and cfg.d_ff == 0 and not is_moe:
        has_ffn = False
    if has_ffn:
        p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
        if is_moe:
            p["moe"] = init_moe(ks[1], cfg, dtype, data_shards)
        else:
            p["ffn"] = init_ffn(ks[1], cfg, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ModelConfig, data_shards: int = 0) -> Dict[str, Any]:
    """Initialize the full parameter tree.

    data_shards: size of the expert-parallel axis (only used when the MoE
    impl is "ep" to size physical expert storage).
    """
    dtype = _dtype(cfg)
    n_keys = 6 + cfg.period
    ks = jax.random.split(key, n_keys)
    params: Dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = _dense(ks[0], (cfg.vocab_size, cfg.d_model), dtype,
                                 fan_in=cfg.d_model)
    if cfg.vision_tokens:
        params["vision_proj"] = _dense(ks[1], (cfg.vision_dim, cfg.d_model),
                                       dtype)
    if cfg.dense_first_layer:
        first = {"ln1": jnp.zeros((cfg.d_model,), dtype),
                 "attn": (init_mla(ks[2], cfg, dtype)
                          if cfg.attention == "mla"
                          else init_attn(ks[2], cfg, dtype)),
                 "ln2": jnp.zeros((cfg.d_model,), dtype),
                 "ffn": init_ffn(ks[3], cfg,
                                 cfg.dense_first_d_ff or cfg.d_ff, dtype)}
        params["first_layer"] = first
    # stacked per-period-position layer params
    layers = []
    for p_idx in range(cfg.period):
        def one(k):
            return init_layer(k, cfg, p_idx, dtype, data_shards)
        layer_keys = jax.random.split(ks[6 + p_idx], cfg.n_periods)
        layers.append(jax.vmap(one)(layer_keys))
    params["layers"] = layers
    params["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if not cfg.tie_embeddings or cfg.input_mode != "tokens":
        params["lm_head"] = _dense(ks[4], (cfg.d_model, cfg.vocab_size),
                                   dtype)
    return params


def abstract_params(cfg: ModelConfig, data_shards: int = 0):
    """ShapeDtypeStruct tree of the params (no allocation) for dry-runs."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg, data_shards),
        jax.ShapeDtypeStruct((2,), jnp.uint32))


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Analytic parameter count.  active_only counts top-k routed experts
    (for MoE MODEL_FLOPS = 6 * N_active * D)."""
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    total = cfg.vocab_size * d if cfg.input_mode == "tokens" else 0
    if not cfg.tie_embeddings or cfg.input_mode != "tokens":
        total += d * cfg.vocab_size
    if cfg.vision_tokens:
        total += cfg.vision_dim * d

    def ffn_count(f):
        return d * f * (3 if cfg.gated_mlp else 2)

    def attn_count():
        if cfg.attention == "mla":
            m = cfg.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            return (d * m.q_lora_rank + m.q_lora_rank * h * qk
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * h * (m.qk_nope_head_dim
                                            + m.v_head_dim)
                    + h * m.v_head_dim * d
                    + m.q_lora_rank + m.kv_lora_rank)
        base = d * h * hd + 2 * d * kv * hd + h * hd * d
        if cfg.qk_norm:
            base += 2 * hd
        return base

    def mamba_count():
        m, dtr = cfg.mamba, cfg.dt_rank
        di, ds, dc = m.d_inner, m.d_state, m.d_conv
        return (d * 2 * di + dc * di + di + di * (dtr + 2 * ds)
                + dtr * di + di + di * ds + di + di * d)

    def moe_count():
        m = cfg.moe
        per = m.d_expert * d * (3 if cfg.gated_mlp else 2)
        n_routed = m.top_k if active_only else m.n_experts
        c = d * m.n_experts + n_routed * per
        if m.n_shared:
            c += ffn_count(m.n_shared * m.d_expert)
        return c

    total += d  # final_norm
    for i in range(cfg.n_scan_layers):
        kind = cfg.layer_kind(i)
        total += d  # ln1
        if kind == "mamba":
            total += mamba_count()
        else:
            total += attn_count()
        if kind == "cross":
            total += 2  # gates
        if cfg.layer_is_moe(i):
            total += d + moe_count()
        elif not (kind == "mamba" and cfg.d_ff == 0):
            total += d + ffn_count(cfg.d_ff)
    if cfg.dense_first_layer:
        total += 2 * d + attn_count() + ffn_count(
            cfg.dense_first_d_ff or cfg.d_ff)
    return total
