"""Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3).

Prefill/train use the standard (decompressed) path; decode uses the
*absorbed* path so per-step cost is O(S * (kv_lora + rope)) memory traffic —
the whole point of MLA's compressed KV cache.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import ops


def _project_q(p: Dict, cfg: ModelConfig, x: jax.Array,
               positions: jax.Array):
    m = cfg.mla
    q_lat = ops.rmsnorm(x @ p["wq_a"], p["q_norm_a"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_lat, p["wq_b"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = ops.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                            cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(p: Dict, cfg: ModelConfig, x: jax.Array,
                       positions: jax.Array):
    """Compressed latent ckv [B,S,r] and shared rotary key [B,S,rope]."""
    m = cfg.mla
    lat = x @ p["wkv_a"]
    ckv = ops.rmsnorm(lat[..., :m.kv_lora_rank], p["kv_norm_a"], cfg.norm_eps)
    k_rope = ops.apply_rope(lat[..., None, m.kv_lora_rank:], positions,
                            cfg.rope_theta)[..., 0, :]
    return ckv, k_rope


def mla_train(p: Dict, cfg: ModelConfig, x: jax.Array,
              positions: jax.Array) -> jax.Array:
    out, _ = mla_prefill(p, cfg, x, positions, cache_len=x.shape[1])
    return out


def mla_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array, cache_len: int):
    """Standard decompressed attention; caches (ckv, k_rope)."""
    m = cfg.mla
    b, s, _ = x.shape
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    ckv, k_rope = _project_kv_latent(p, cfg, x, positions)
    kv = jnp.einsum("bsr,rhk->bshk", ckv, p["wkv_b"])
    k_nope = kv[..., :m.qk_nope_head_dim]
    v = kv[..., m.qk_nope_head_dim:]
    scale = 1.0 / jnp.sqrt(float(m.qk_nope_head_dim + m.qk_rope_head_dim))
    kn_f = k_nope.astype(jnp.float32)
    kr_f = k_rope.astype(jnp.float32)
    v_f = v.astype(jnp.float32)

    def attend(qn_blk, qr_blk, offset):
        sc = (jnp.einsum("bqhk,bshk->bhqs", qn_blk.astype(jnp.float32),
                         kn_f)
              + jnp.einsum("bqhk,bsk->bhqs", qr_blk.astype(jnp.float32),
                           kr_f)) * scale
        msk = ops.causal_mask(qn_blk.shape[1], s, offset)[None, None]
        sc = jnp.where(msk, sc, -1e30)
        w = jax.nn.softmax(sc, axis=-1)
        return jnp.einsum("bhqs,bshv->bqhv", w, v_f).astype(x.dtype)

    if s > 1024:
        # blocked over q so scores never exceed [B,H,bq,S] (32k cells)
        bq = 512
        n_blk = s // bq
        qn = q_nope.reshape(b, n_blk, bq, *q_nope.shape[2:]).transpose(
            1, 0, 2, 3, 4)
        qr = q_rope.reshape(b, n_blk, bq, *q_rope.shape[2:]).transpose(
            1, 0, 2, 3, 4)

        @jax.checkpoint
        def body(_, inp):
            qn_b, qr_b, i = inp
            from repro.distributed import context as dist_ctx
            return None, dist_ctx.constrain_batch(
                attend(qn_b, qr_b, i * bq))

        _, outs = jax.lax.scan(body, None, (qn, qr, jnp.arange(n_blk)),
                               unroll=True if cfg.scan_unroll else 1)
        o = outs.transpose(1, 0, 2, 3, 4).reshape(
            b, s, cfg.n_heads, m.v_head_dim)
    else:
        o = attend(q_nope, q_rope, 0)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    pad = cache_len - s
    if pad > 0:
        ckv = jnp.pad(ckv, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return out, {"ckv": ckv, "kr": k_rope}


def mla_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
               position: jax.Array, cache: Dict):
    """Absorbed decode: score/value computed in the latent space."""
    m = cfg.mla
    ckv_cache, kr_cache = cache["ckv"], cache["kr"]  # [B,S,r], [B,S,rope]
    b, s_max, r = ckv_cache.shape
    pos = position[:, None]
    q_nope, q_rope = _project_q(p, cfg, x, pos)     # [B,1,H,*]
    ckv_new, kr_new = _project_kv_latent(p, cfg, x, pos)
    onehot = jax.nn.one_hot(position, s_max, dtype=ckv_cache.dtype)
    ckv_cache = ckv_cache * (1 - onehot[..., None]) + \
        onehot[..., None] * ckv_new.astype(ckv_cache.dtype)
    kr_cache = kr_cache * (1 - onehot[..., None]) + \
        onehot[..., None] * kr_new.astype(kr_cache.dtype)
    # absorb W_kv_b(k-part) into q:  q_lat [B,1,H,r]
    wkb_k = p["wkv_b"][..., :m.qk_nope_head_dim]    # [r,H,nope]
    q_lat = jnp.einsum("bqhk,rhk->bqhr", q_nope, wkb_k)
    scale = 1.0 / jnp.sqrt(float(m.qk_nope_head_dim + m.qk_rope_head_dim))
    scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bqhk,bsk->bhqs", q_rope.astype(jnp.float32),
                           kr_cache.astype(jnp.float32))) * scale
    kv_pos = jnp.arange(s_max)[None, None, None, :]
    mask = kv_pos <= position[:, None, None, None]
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    o_lat = jnp.einsum("bhqs,bsr->bqhr", w,
                       ckv_cache.astype(jnp.float32))  # [B,1,H,r]
    wkb_v = p["wkv_b"][..., m.qk_nope_head_dim:]       # [r,H,v]
    o = jnp.einsum("bqhr,rhv->bqhv", o_lat.astype(x.dtype), wkb_v)
    out = jnp.einsum("bshv,hvd->bsd", o, p["wo"])
    return out, {"ckv": ckv_cache, "kr": kr_cache}
