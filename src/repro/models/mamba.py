"""Mamba-1 selective SSM block (falcon-mamba, jamba).

Sequence processing uses a chunked scan: within a VMEM-sized chunk the
diagonal recurrence h_t = a_t * h_{t-1} + b_t is evaluated with an
associative scan; chunks are chained with lax.scan so the [B,S,di,ds]
state tensor is never materialized for the full sequence.  The Pallas
kernel in repro.kernels.mamba_scan implements the same chunking for TPU.

Decode keeps O(1) state: a (d_conv-1)-deep conv window and the [di,ds] SSM
state -- this is what makes the long_500k cell feasible for SSM/hybrid archs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig


def _ssm_inputs(p: Dict, cfg: ModelConfig, xc: jax.Array):
    """xc [B,S,di] (post-conv, post-silu) -> dt, B, C."""
    m, dtr = cfg.mamba, cfg.dt_rank
    dbc = xc @ p["x_proj"]                              # [B,S,dtr+2ds]
    dt = jax.nn.softplus(dbc[..., :dtr] @ p["dt_proj"]
                         + p["dt_bias"].astype(jnp.float32))
    b = dbc[..., dtr:dtr + m.d_state]
    c = dbc[..., dtr + m.d_state:]
    return dt, b, c


def selective_scan(xc: jax.Array, dt: jax.Array, b: jax.Array, c: jax.Array,
                   a_log: jax.Array, d: jax.Array, h0: jax.Array,
                   chunk: int, unroll: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Reference selective scan.  xc [B,S,di]; dt [B,S,di]; b,c [B,S,ds];
    a_log [di,ds]; d [di]; h0 [B,di,ds] -> (y [B,S,di], h_final).

    Everything (decay, drive, in-chunk associative scan, output readout)
    is computed PER CHUNK inside the scan body -- the [B,S,di,ds] state
    tensor never materializes for the full sequence (same structure as the
    Pallas kernel; without this a 4k x 8192 x 16 train step allocates
    ~100 GiB/device in f32)."""
    from repro.distributed import context as dist_ctx
    bsz, s, di = xc.shape
    ds = b.shape[-1]
    assert s % chunk == 0, (s, chunk)
    n_chunks = s // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))            # [di,ds]

    def chunked(t):
        return t.reshape(bsz, n_chunks, chunk, *t.shape[2:]).transpose(
            1, 0, 2, *range(3, t.ndim + 1))

    xs = (chunked(xc), chunked(dt), chunked(b), chunked(c))

    def combine(x, y):
        ax, bx = x
        ay, by = y
        return ax * ay, ay * bx + by

    @jax.checkpoint
    def body(h, inp):
        x_c, dt_c, b_c, c_c = inp                      # [B,chunk,...]
        dt_f = dt_c.astype(jnp.float32)
        decay = jnp.exp(dt_f[..., None] * a)           # [B,chunk,di,ds]
        decay = dist_ctx.constrain_heads(decay, head_dim=2)
        drive = (dt_f * x_c.astype(jnp.float32))[..., None] * \
            b_c.astype(jnp.float32)[:, :, None, :]
        drive = dist_ctx.constrain_heads(drive, head_dim=2)
        a_cum, b_cum = jax.lax.associative_scan(combine, (decay, drive),
                                                axis=1)
        h_all = a_cum * h[:, None] + b_cum
        h_all = dist_ctx.constrain_heads(h_all, head_dim=2)
        y_c = jnp.sum(h_all * c_c.astype(jnp.float32)[:, :, None, :],
                      axis=-1)
        y_c = y_c + x_c.astype(jnp.float32) * d
        return h_all[:, -1], y_c.astype(xc.dtype)

    h_final, y_chunks = jax.lax.scan(body, h0.astype(jnp.float32), xs,
                                     unroll=True if unroll else 1)
    y = y_chunks.transpose(1, 0, 2, 3).reshape(bsz, s, di)
    return y, h_final


def _causal_conv(p: Dict, x: jax.Array, state: jax.Array | None):
    """Depthwise causal conv1d.  x [B,S,di]; state [B,dc-1,di] or None."""
    w = p["conv_w"].astype(jnp.float32)                # [dc,di]
    dc = w.shape[0]
    xf = x.astype(jnp.float32)
    if state is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), jnp.float32)
    else:
        pad = state.astype(jnp.float32)
    xp = jnp.concatenate([pad, xf], axis=1)            # [B,S+dc-1,di]
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(dc))
    out = out + p["conv_b"].astype(jnp.float32)
    new_state = xp[:, -(dc - 1):] if dc > 1 else pad[:, :0]
    return out.astype(x.dtype), new_state.astype(x.dtype)


def mamba_seq(p: Dict, cfg: ModelConfig, x: jax.Array,
              use_pallas: bool = False):
    """Full-sequence mamba block.  x [B,S,d] -> (y [B,S,d], (conv_state,
    ssm_state)) final states for cache handoff."""
    m = cfg.mamba
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(p, xi, None)
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _ssm_inputs(p, cfg, xc)
    h0 = jnp.zeros((x.shape[0], m.d_inner, m.d_state), jnp.float32)
    if use_pallas or cfg.use_pallas:
        from repro.kernels.mamba_scan import ops as ms_ops
        y, h_final = ms_ops.mamba_scan(xc, dt, bmat, cmat, p["A_log"],
                                       p["D"], h0, chunk=m.chunk)
    else:
        chunk = min(m.chunk, x.shape[1])
        y, h_final = selective_scan(xc, dt, bmat, cmat, p["A_log"], p["D"],
                                    h0, chunk, unroll=cfg.scan_unroll)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"], {"conv": conv_state,
                               "ssm": h_final.astype(x.dtype)}


def mamba_decode(p: Dict, cfg: ModelConfig, x: jax.Array, cache: Dict):
    """Single-token step.  x [B,1,d]; cache = {conv [B,dc-1,di],
    ssm [B,di,ds]}."""
    m = cfg.mamba
    conv_state, h = cache["conv"], cache["ssm"]
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                  # [B,1,di]
    xc, conv_state = _causal_conv(p, xi, conv_state)
    xc = jax.nn.silu(xc)
    dt, bmat, cmat = _ssm_inputs(p, cfg, xc)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    dtf = dt[:, 0].astype(jnp.float32)                 # [B,di]
    decay = jnp.exp(dtf[..., None] * a)                # [B,di,ds]
    drive = (dtf * xc[:, 0].astype(jnp.float32))[..., None] * \
        bmat[:, 0].astype(jnp.float32)[:, None, :]
    h = decay * h.astype(jnp.float32) + drive
    y = jnp.sum(h * cmat[:, 0].astype(jnp.float32)[:, None, :], axis=-1)
    y = y + xc[:, 0].astype(jnp.float32) * p["D"]
    y = (y.astype(x.dtype) * jax.nn.silu(z[:, 0]))[:, None]
    return y @ p["out_proj"], {"conv": conv_state, "ssm": h.astype(x.dtype)}
