"""GQA/MHA/MQA self-attention and VLM cross-attention (pure jnp core).

The Pallas kernels in ``repro.kernels`` implement the same math for TPU; the
model switches via ``cfg.use_pallas``.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import ops


def gqa_blocked(q: jax.Array, k: jax.Array, v: jax.Array, *,
                causal: bool, q_offset=0, block_q: int = 512,
                kv_valid: Optional[jax.Array] = None,
                unroll: bool = False) -> jax.Array:
    """Memory-blocked attention (jnp flash-style): scans q blocks so the
    score matrix never materializes beyond [B, KV, G, block_q, Skv].
    Required for the 32k/500k cells where full S^2 scores would OOM."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_q = min(block_q, sq)
    assert sq % block_q == 0
    n_blocks = sq // block_q
    qg = q.reshape(b, n_blocks, block_q, kvh, g, hd).transpose(
        1, 0, 2, 3, 4, 5)                       # [n, B, bq, KV, G, hd]
    kf = k       # bf16 operands; f32 accumulation via preferred dtype
    vf = v
    kv_pos = jnp.arange(skv)[None, :]

    from repro.distributed import context as dist_ctx

    @jax.checkpoint
    def body(_, inp):
        # rematted: without this, the scan transpose saves every block's
        # [B,KV,G,bq,Skv] scores -- the full S^2 matrix in aggregate.
        q_blk, idx = inp
        q_blk = dist_ctx.constrain_batch(q_blk)
        scores = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, kf,
                            preferred_element_type=jnp.float32) \
            / jnp.sqrt(float(hd))
        if causal:
            q_pos = idx * block_q + jnp.arange(block_q)[:, None] + q_offset
            scores = jnp.where((kv_pos <= q_pos)[None, None, None],
                               scores, -1e30)
        if kv_valid is not None:
            scores = jnp.where(kv_valid[:, None, None, None, :],
                               scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1).astype(vf.dtype)
        out = jnp.einsum("bkgqs,bskd->bqkgd", w, vf,
                         preferred_element_type=jnp.float32)
        return None, dist_ctx.constrain_batch(out.astype(q.dtype))

    _, outs = jax.lax.scan(body, None, (qg, jnp.arange(n_blocks)),
                           unroll=True if unroll else 1)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


def quantize_kv(x: jax.Array):
    """[B,S,KV,hd] -> (int8 values, bf16 scales [B,S,KV]) per token+head."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.bfloat16) * scale[..., None].astype(jnp.bfloat16)


def gqa_core(q: jax.Array, k: jax.Array, v: jax.Array,
             mask: Optional[jax.Array]) -> jax.Array:
    """Grouped-query attention.

    q: [B, Sq, H, hd];  k/v: [B, Skv, KV, hd];  mask: [B, Sq, Skv] or None
    returns [B, Sq, H, hd].
    """
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def _maybe_pallas_prefill(cfg, q, k, v, q_offset):
    if not cfg.use_pallas:
        return None
    from repro.kernels.flash_attention import ops as fa_ops
    return fa_ops.flash_attention(q, k, v, causal=True, q_offset=q_offset)


def _maybe_pallas_decode(cfg, q, k, v, kv_len):
    if not cfg.use_pallas:
        return None
    from repro.kernels.decode_attention import ops as da_ops
    return da_ops.decode_attention(q, k, v, kv_len=kv_len)


def _expand_kv_for_tp(cfg: ModelConfig, k: jax.Array, v: jax.Array):
    """When KV heads don't divide the TP axis but H does, broadcast K/V to
    full H so every attention tensor shards cleanly over "model".  The
    per-device expanded slice (H/tp heads) is SMALLER than a replicated
    un-expanded K/V, and compute stops being replicated across the axis."""
    from repro.distributed import context as dist_ctx
    tp = dist_ctx.tp_size()
    kvh = k.shape[2]
    if tp == 1 or kvh % tp == 0 or cfg.n_heads % tp != 0:
        return k, v
    g = cfg.n_heads // kvh
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    return (dist_ctx.constrain_heads(k), dist_ctx.constrain_heads(v))


def project_qkv(p: Dict, cfg: ModelConfig, x: jax.Array,
                positions: jax.Array) -> Tuple[jax.Array, jax.Array,
                                               jax.Array]:
    """x [B,S,d] -> q [B,S,H,hd], k/v [B,S,KV,hd] (RoPE + optional qk-norm)."""
    from repro.distributed import context as dist_ctx
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = ops.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = ops.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = ops.apply_rope(q, positions, cfg.rope_theta)
    k = ops.apply_rope(k, positions, cfg.rope_theta)
    q = dist_ctx.constrain_heads(q)
    return q, k, v


_BLOCKED_THRESHOLD = 1024


def _causal_attn(cfg: ModelConfig, q, k, v):
    out = _maybe_pallas_prefill(cfg, q, k, v, 0)
    if out is not None:
        return out
    k, v = _expand_kv_for_tp(cfg, k, v)
    if q.shape[1] > _BLOCKED_THRESHOLD:
        return gqa_blocked(q, k, v, causal=True, unroll=cfg.scan_unroll)
    mask = ops.causal_mask(q.shape[1], k.shape[1], 0)[None]
    return gqa_core(q, k, v, mask)


def self_attention_train(p: Dict, cfg: ModelConfig, x: jax.Array,
                         positions: jax.Array) -> jax.Array:
    """Full-sequence causal attention (training / no cache)."""
    q, k, v = project_qkv(p, cfg, x, positions)
    out = _causal_attn(cfg, q, k, v)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def self_attention_prefill(p: Dict, cfg: ModelConfig, x: jax.Array,
                           positions: jax.Array, cache_len: int):
    """Prefill: returns (out, (k_cache_entry, v_cache_entry)) padded to
    cache_len along the sequence axis."""
    q, k, v = project_qkv(p, cfg, x, positions)
    out = _causal_attn(cfg, q, k, v)
    from repro.models.model import cache_kv_heads
    if cache_kv_heads(cfg) != k.shape[2]:
        k, v = _expand_kv_for_tp(cfg, k, v)
    pad = cache_len - k.shape[1]
    if pad > 0:
        pads = ((0, 0), (0, pad), (0, 0), (0, 0))
        k = jnp.pad(k, pads)
        v = jnp.pad(v, pads)
    proj = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if cfg.kv_cache_dtype == "int8":
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        return proj, {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
    return proj, {"k": k, "v": v}


def self_attention_decode(p: Dict, cfg: ModelConfig, x: jax.Array,
                          position: jax.Array, cache: Dict):
    """Single-token decode.  x [B,1,d]; position [B] absolute position of the
    new token; cache = {k [B,S,KV,hd], v [B,S,KV,hd]} with S = max len."""
    k_cache, v_cache = cache["k"], cache["v"]
    b, s_max = k_cache.shape[0], k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = ops.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k_new = ops.rmsnorm(k_new, p["k_norm"], cfg.norm_eps)
    pos = position[:, None]                     # [B,1]
    q = ops.apply_rope(q, pos, cfg.rope_theta)
    k_new = ops.apply_rope(k_new, pos, cfg.rope_theta)
    if k_cache.shape[2] != k_new.shape[2]:      # expanded cache layout
        k_new, v_new = _expand_kv_for_tp(cfg, k_new, v_new)
    from repro.distributed import context as dist_ctx
    q = dist_ctx.constrain_heads(q)
    int8_cache = cfg.kv_cache_dtype == "int8"
    if int8_cache:
        k_new_q, k_new_s = quantize_kv(k_new)
        v_new_q, v_new_s = quantize_kv(v_new)
    # scatter the new K/V at `position`
    onehot = jax.nn.one_hot(position, s_max, dtype=jnp.float32)  # [B,S]
    oh = onehot[:, :, None, None]

    def scatter(cache, new):
        compute_dt = jnp.float32 if cache.dtype == jnp.int8 \
            else cache.dtype
        return (cache.astype(compute_dt) * (1 - oh).astype(compute_dt)
                + oh.astype(compute_dt) * new.astype(compute_dt)
                ).astype(cache.dtype)

    if int8_cache:
        k_cache = scatter(k_cache, k_new_q)
        v_cache = scatter(v_cache, v_new_q)
        oh2 = onehot[:, :, None]
        ks = (cache["k_scale"] * (1 - oh2) + oh2 * k_new_s
              ).astype(cache["k_scale"].dtype)
        vs = (cache["v_scale"] * (1 - oh2) + oh2 * v_new_s
              ).astype(cache["v_scale"].dtype)
        new_cache = {"k": k_cache, "v": v_cache, "k_scale": ks,
                     "v_scale": vs}
        k_read = dequantize_kv(k_cache, ks)
        v_read = dequantize_kv(v_cache, vs)
    else:
        k_cache = scatter(k_cache, k_new)
        v_cache = scatter(v_cache, v_new)
        new_cache = {"k": k_cache, "v": v_cache}
        k_read, v_read = k_cache, v_cache
    out = _maybe_pallas_decode(cfg, q, k_read, v_read, position + 1)
    if out is None:
        kv_pos = jnp.arange(s_max)[None, None, :]          # [1,1,S]
        mask = kv_pos <= position[:, None, None]           # [B,1,S]
        out = gqa_core(q, k_read, v_read, mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


# ---------------------------------------------------------------------------
# Cross attention (llama-3.2-vision style gated cross-attn layers)
# ---------------------------------------------------------------------------

def cross_attention(p: Dict, cfg: ModelConfig, x: jax.Array,
                    vis_kv: Dict) -> jax.Array:
    """x [B,S,d] attends over fixed vision K/V [B,Tv,KV,hd]."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if q.shape[1] > _BLOCKED_THRESHOLD:
        out = gqa_blocked(q, vis_kv["k"], vis_kv["v"], causal=False,
                          unroll=cfg.scan_unroll)
    else:
        out = gqa_core(q, vis_kv["k"], vis_kv["v"], None)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def vision_kv(p: Dict, cfg: ModelConfig, vis: jax.Array) -> Dict:
    """Project (stub) vision embeddings [B,Tv,d] to cross-attn K/V once."""
    k = jnp.einsum("btd,dhk->bthk", vis, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", vis, p["wv"])
    return {"k": k, "v": v}
