"""Primitive neural ops shared across the model zoo (pure jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
    }[name]


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings (half the head dim)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: [..., seq, n_heads, head_dim]; positions: [..., seq] (int32).
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    # broadcast over heads: [..., S, 1, hd/2]
    angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return x
    return cap * jnp.tanh(x / cap)


def causal_mask(q_len: int, kv_len: int, q_offset) -> jax.Array:
    """Boolean mask [q_len, kv_len]; True where attention is allowed.

    q_offset: absolute position of the first query (array or int).
    """
    q_pos = jnp.arange(q_len)[:, None] + q_offset
    kv_pos = jnp.arange(kv_len)[None, :]
    return kv_pos <= q_pos


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross entropy.  logits [..., V], labels [...].

    The gold logit is picked with a one-hot contraction (NOT
    take_along_axis): gathering along a "model"-sharded vocab axis forces
    SPMD to replicate the full logits tensor."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = logz - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def chunked_cross_entropy(hidden: jax.Array, w_head: jax.Array,
                          labels: jax.Array, softcap_val: float = 0.0,
                          block: int = 512,
                          unroll: bool = False) -> jax.Array:
    """Sequence-chunked CE: logits are materialized one [B, block, V]
    slab at a time (scanned), so the full [B, S, V] f32 logits tensor --
    tens of GB per device for 150k vocabularies -- never exists.

    hidden [B,S,d] (already final-normed); w_head [d,V]; labels [B,S].
    """
    b, s, d = hidden.shape
    block = min(block, s)
    assert s % block == 0
    n_blocks = s // block
    h = hidden.reshape(b, n_blocks, block, d).transpose(1, 0, 2, 3)
    y = labels.reshape(b, n_blocks, block).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        h_blk, y_blk = inp
        logits = (h_blk @ w_head).astype(jnp.float32)
        logits = softcap(logits, softcap_val)
        logz = jax.nn.logsumexp(logits, axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                        logits.ndim - 1)
        gold = jnp.sum(jnp.where(iota == y_blk[..., None], logits, 0.0),
                       axis=-1)
        return acc + jnp.sum(logz - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (h, y),
                            unroll=True if unroll else 1)
    return total / (b * s)
