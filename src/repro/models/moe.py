"""Mixture-of-Experts layer: routed top-k experts + optional shared experts.

Three interchangeable implementations (cfg.moe.impl):

  dense   -- every expert on every token, gate-combined.  O(E/k) FLOP
             overhead; used as the correctness oracle and for tiny configs.
  ragged  -- tokens sorted by expert id, grouped GEMM via jax.lax.ragged_dot.
             Exact FLOPs; the single-device / auto-sharded path.
  ep      -- expert parallelism: shard_map over the ("pod","data") mesh axes
             with capacity-bounded all_to_all dispatch/combine, local experts
             computed with ragged_dot, TP (f over "model") with a single psum
             per layer.  Experts with E < n_shards are replicated R = shards/E
             times (grok: 8 experts over 16 shards -> R=2); replica gradients
             are symmetrized in the train step.

The routed output is combined with the shared-expert output (computed by the
caller as a dense FFN under auto sharding) and carries a load-balance aux
loss (switch-transformer style).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax

from repro.common import compat
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.distributed import context as dist_ctx
from repro.models import ops


def _router(p: Dict, x: jax.Array, cfg: ModelConfig):
    """x [T,d] -> (probs [T,E] f32, topk_idx [T,k], topk_w [T,k] f32)."""
    m = cfg.moe
    logits = (x.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, m.top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, -1, keepdims=True), 1e-9)
    return probs, topk_idx, topk_w


def aux_loss(probs: jax.Array, topk_idx: jax.Array, n_experts: int):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    t = probs.shape[0]
    sel = jax.nn.one_hot(topk_idx, n_experts, dtype=jnp.float32)  # [T,k,E]
    f = jnp.mean(jnp.sum(sel, axis=1), axis=0)      # fraction routed to e * k
    p_mean = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * p_mean) / topk_idx.shape[1]


def _unique_experts(w: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Strip EP replication from stored expert weights (for non-EP math)."""
    e = cfg.moe.n_experts
    if w.shape[0] == e:
        return w
    r = w.shape[0] // e
    return w[::r]


def _expert_ffn_dense(p: Dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """All experts on all tokens: x [T,d] -> [T,E,d]."""
    act = ops.activation(cfg.activation)
    w_up = _unique_experts(p["w_up"], cfg)
    w_down = _unique_experts(p["w_down"], cfg)
    h = jnp.einsum("td,edf->tef", x, w_up)
    if cfg.gated_mlp:
        g = jnp.einsum("td,edf->tef", x, _unique_experts(p["w_gate"], cfg))
        h = act(g) * h
    else:
        h = act(h)
    return jnp.einsum("tef,efd->ted", h, w_down)


def moe_dense(p: Dict, cfg: ModelConfig, x_flat: jax.Array):
    probs, topk_idx, topk_w = _router(p, x_flat, cfg)
    y_all = _expert_ffn_dense(p["routed"], cfg, x_flat)     # [T,E,d]
    combine = jnp.zeros(probs.shape, x_flat.dtype)
    combine = jnp.take_along_axis(
        combine, topk_idx, axis=1)  # placeholder shape [T,k]
    # scatter topk weights into [T,E]
    comb = jnp.zeros(probs.shape, jnp.float32)
    comb = comb.at[jnp.arange(x_flat.shape[0])[:, None],
                   topk_idx].set(topk_w)
    y = jnp.einsum("te,ted->td", comb.astype(x_flat.dtype), y_all)
    return y, aux_loss(probs, topk_idx, cfg.moe.n_experts)


def _grouped_ffn(p: Dict, cfg: ModelConfig, x_sorted: jax.Array,
                 group_sizes: jax.Array) -> jax.Array:
    """Grouped GEMM over experts: x_sorted [T,d] grouped by expert."""
    act = ops.activation(cfg.activation)
    w_up = _unique_experts(p["w_up"], cfg)
    w_down = _unique_experts(p["w_down"], cfg)
    h = jax.lax.ragged_dot(x_sorted, w_up, group_sizes)
    if cfg.gated_mlp:
        g = jax.lax.ragged_dot(x_sorted,
                               _unique_experts(p["w_gate"], cfg), group_sizes)
        h = act(g) * h
    else:
        h = act(h)
    return jax.lax.ragged_dot(h, w_down, group_sizes)


def moe_ragged(p: Dict, cfg: ModelConfig, x_flat: jax.Array):
    """Sort-by-expert + ragged_dot grouped GEMM (exact FLOPs)."""
    m = cfg.moe
    t = x_flat.shape[0]
    probs, topk_idx, topk_w = _router(p, x_flat, cfg)
    flat_expert = topk_idx.reshape(-1)                      # [T*k]
    order = jnp.argsort(flat_expert)
    token_of_pair = jnp.arange(t * m.top_k) // m.top_k
    x_sorted = x_flat[token_of_pair[order]]
    group_sizes = jnp.bincount(flat_expert, length=m.n_experts)
    y_sorted = _grouped_ffn(p["routed"], cfg, x_sorted, group_sizes)
    # unsort and weighted-combine the k copies
    inv = jnp.argsort(order)
    y_pairs = y_sorted[inv].reshape(t, m.top_k, -1)
    y = jnp.sum(y_pairs * topk_w[..., None].astype(y_pairs.dtype), axis=1)
    return y.astype(x_flat.dtype), aux_loss(probs, topk_idx, m.n_experts)


# ---------------------------------------------------------------------------
# Expert-parallel path (shard_map + all_to_all)
# ---------------------------------------------------------------------------

def _ep_local(x_local, router_w, w_gate, w_up, w_down, *, cfg: ModelConfig,
              n_shards: int, ep_axes, tp_axis: str, aux_axes=None):
    """Body run per device group.  x_local [T_loc, d]; expert weights are the
    local slices [e_loc, d, f_loc] / [e_loc, f_loc, d]."""
    m = cfg.moe
    e, k = m.n_experts, m.top_k
    t_loc, d = x_local.shape
    r = max(1, n_shards // e)               # replication factor
    e_loc = max(1, e // n_shards)           # experts per device

    probs, topk_idx, topk_w = _router({"router": router_w}, x_local, cfg)
    pair_token = jnp.arange(t_loc * k) // k
    pair_expert = topk_idx.reshape(-1)
    pair_w = topk_w.reshape(-1)
    # destination device: spread across the R replicas of the expert
    if r > 1:
        dest = pair_expert * r + (pair_token % r)
    else:
        dest = pair_expert // e_loc
    # capacity per destination
    cap = int(-(-t_loc * k // n_shards) * m.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)
    onehot = jax.nn.one_hot(dest, n_shards, dtype=jnp.int32)     # [P,S]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_dest = jnp.sum(pos * onehot, axis=1)                  # [P]
    keep = pos_in_dest < cap
    # send buffers
    send_x = jnp.zeros((n_shards, cap, d), x_local.dtype)
    send_eid = jnp.zeros((n_shards, cap), jnp.int32)
    di, pi = dest, jnp.where(keep, pos_in_dest, cap)  # cap row -> dropped
    send_x = send_x.at[di, pi].set(x_local[pair_token], mode="drop")
    send_eid = send_eid.at[di, pi].set(pair_expert % e_loc if e_loc > 1
                                       else 0, mode="drop")
    recv_x = jax.lax.all_to_all(send_x, ep_axes, 0, 0, tiled=True)
    recv_eid = jax.lax.all_to_all(send_eid, ep_axes, 0, 0, tiled=True)
    rx = recv_x.reshape(n_shards * cap, d)
    reid = recv_eid.reshape(-1)
    if e_loc > 1:
        order = jnp.argsort(reid)
        rx_sorted = rx[order]
        group_sizes = jnp.bincount(reid, length=e_loc)
        inv = jnp.argsort(order)
    else:
        rx_sorted = rx
        group_sizes = jnp.array([n_shards * cap], jnp.int32)
        inv = None
    act = ops.activation(cfg.activation)
    h = jax.lax.ragged_dot(rx_sorted, w_up, group_sizes)
    if cfg.gated_mlp:
        h = act(jax.lax.ragged_dot(rx_sorted, w_gate, group_sizes)) * h
    else:
        h = act(h)
    y_sorted = jax.lax.ragged_dot(h, w_down, group_sizes)
    y_sorted = jax.lax.psum(y_sorted, tp_axis)       # TP reduce over f
    y_loc = y_sorted if inv is None else y_sorted[inv]
    y_back = jax.lax.all_to_all(y_loc.reshape(n_shards, cap, d),
                                ep_axes, 0, 0, tiled=True)
    # gather each pair's result and combine
    y_pairs = y_back[di, pi] * keep[:, None].astype(y_back.dtype)
    y = jnp.zeros((t_loc, d), jnp.float32)
    y = y.at[pair_token].add(
        (y_pairs * pair_w[:, None].astype(y_pairs.dtype)).astype(jnp.float32))
    aux_axes = aux_axes or ep_axes
    aux = jax.lax.psum(aux_loss(probs, topk_idx, e), aux_axes)
    aux = aux / jax.lax.psum(jnp.ones(()), aux_axes)
    return y.astype(x_local.dtype), aux


def moe_ep(p: Dict, cfg: ModelConfig, x_flat: jax.Array):
    """Expert-parallel MoE via shard_map over the ambient mesh.

    Dispatch (all_to_all) runs over ``ctx.ep_axes`` (the within-pod "data"
    axis); tokens arrive sharded over ``ctx.batch_axes`` (which may include
    "pod": each pod then runs EP independently on replicated experts); the
    expert FFN is TP-sharded over "model" with one psum per layer.
    """
    ctx = dist_ctx.get()
    mesh = ctx.mesh
    assert mesh is not None, "EP MoE requires a parallel context mesh"
    ep_axes = ctx.ep_axes or ("data",)
    batch_axes = ctx.batch_axes or ep_axes
    tp_axis = ctx.model_axis
    n_shards = 1
    for a in ep_axes:
        n_shards *= mesh.shape[a]
    routed = p["routed"]
    e_store = routed["w_up"].shape[0]
    assert e_store % n_shards == 0, (e_store, n_shards)
    pspec = jax.sharding.PartitionSpec
    x_spec = pspec(batch_axes, None)
    w3 = pspec(ep_axes, None, tp_axis)
    w3d = pspec(ep_axes, tp_axis, None)
    fn = functools.partial(_ep_local, cfg=cfg, n_shards=n_shards,
                           ep_axes=ep_axes, tp_axis=tp_axis,
                           aux_axes=batch_axes)
    y, aux = compat.shard_map(
        fn, mesh=mesh,
        in_specs=(x_spec, pspec(None, None), w3, w3, w3d),
        out_specs=(x_spec, pspec()),
        check_vma=False,
    )(x_flat, p["router"],
      routed.get("w_gate", routed["w_up"]), routed["w_up"], routed["w_down"])
    return y, aux


def moe_gather(p: Dict, cfg: ModelConfig, x_flat: jax.Array):
    """Tiny-batch path (e.g. batch-1 long-context decode): dynamically
    gather only the top-k experts' weights instead of computing or
    gathering all E experts."""
    m = cfg.moe
    t = x_flat.shape[0]
    probs, topk_idx, topk_w = _router(p, x_flat, cfg)
    act = ops.activation(cfg.activation)
    w_up = _unique_experts(p["routed"]["w_up"], cfg)
    w_down = _unique_experts(p["routed"]["w_down"], cfg)
    w_gate = _unique_experts(p["routed"].get("w_gate",
                                             p["routed"]["w_up"]), cfg)
    wu = jnp.take(w_up, topk_idx, axis=0)        # [T,k,d,f]
    wd = jnp.take(w_down, topk_idx, axis=0)      # [T,k,f,d]
    h = jnp.einsum("td,tkdf->tkf", x_flat, wu)
    if cfg.gated_mlp:
        wg = jnp.take(w_gate, topk_idx, axis=0)
        h = act(jnp.einsum("td,tkdf->tkf", x_flat, wg)) * h
    else:
        h = act(h)
    y = jnp.einsum("tkf,tkfd->tkd", h, wd)
    y = jnp.sum(y * topk_w[..., None].astype(y.dtype), axis=1)
    return y.astype(x_flat.dtype), aux_loss(probs, topk_idx, m.n_experts)


def moe_layer(p: Dict, cfg: ModelConfig, x: jax.Array) -> Tuple[jax.Array,
                                                                jax.Array]:
    """Full MoE block.  x [B,S,d] -> (y [B,S,d], aux_loss scalar)."""
    m = cfg.moe
    shape = x.shape
    x_flat = x.reshape(-1, shape[-1])
    impl = m.impl
    if impl == "ep":
        ctx = dist_ctx.get()
        if ctx.mesh is None:
            impl = "ragged"
        else:
            shards = 1
            for a in (ctx.batch_axes or ctx.ep_axes):
                shards *= ctx.mesh.shape[a]
            if x_flat.shape[0] % shards != 0 or x_flat.shape[0] < shards:
                impl = "gather"     # e.g. single-token long-context decode
    if x_flat.shape[0] <= 8 and impl != "ep":
        impl = "gather"
    if impl == "dense":
        y, aux = moe_dense(p, cfg, x_flat)
    elif impl == "ragged":
        y, aux = moe_ragged(p, cfg, x_flat)
    elif impl == "gather":
        y, aux = moe_gather(p, cfg, x_flat)
    elif impl == "ep":
        y, aux = moe_ep(p, cfg, x_flat)
    else:
        raise ValueError(impl)
    if m.n_shared:
        from repro.models.model import ffn_forward
        y = y + ffn_forward(p["shared"], cfg, x_flat)
    return y.reshape(shape), aux
