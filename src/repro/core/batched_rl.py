"""Batched multi-episode RL training (the router-training scale-up).

The sequential trainer (`rl_router.train`, the paper-faithful loop)
interleaves one Python-simulator episode with one jitted Q dispatch per
decision and one synchronous gradient step every few decisions -- the
DQN learner is starved and the accelerator dispatch overhead is paid
per request.  This module runs N independent episodes in lockstep
"rounds" instead:

  * one `DQNAgent.act_batch` call selects actions for all N episodes
    (one jitted dispatch per round instead of per decision);
  * every transition feeds ONE shared replay buffer, so the learner
    sees N-fold experience throughput;
  * learn steps are dispatched asynchronously (`learn(sync=False)`)
    once per `learn_every_rounds` rounds -- on CPU the XLA gradient
    step runs on a worker thread while Python steps the simulators of
    the next round, taking the learner off the critical path;
  * episodes draw from a *scenario stream* (`workload.make_scenario`):
    heterogeneous hardware mixes, bursty/diurnal arrivals, and varying
    cluster widths.  States, masks, and guidance priors are padded to
    the widest cluster `m_max` (padding encodes exactly like a failed
    instance, and the defer action moves to the last slot), so all
    episodes share one Q network and one buffer.

A 1-episode batched run reproduces the sequential path decision for
decision (see tests/test_batched_rl.py); at 8 parallel episodes the
runner trains >3x faster on 2 CPU cores (benchmarks/bench_batched_rl).
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import rl_router as rl
from repro.core import state as state_lib
from repro.core.workload import Scenario
from repro.serving.request import summarize


@dataclass
class BatchedRLConfig:
    n_envs: int = 8
    # padded instance width shared by every episode; None = max of
    # cfg.n_instances and the widest scenario seen at start time is NOT
    # knowable, so scenarios wider than m_max raise.
    m_max: Optional[int] = None
    # learn cadence in rounds (a round = one decision on each of n_envs
    # episodes).  Every 2 rounds x 256-sample batches keeps the async
    # XLA step fully hidden behind simulator Python on a 2-core CPU and
    # still trains to parity with the sequential loop (validated in
    # benchmarks/bench_batched_rl.py).
    learn_every_rounds: int = 2
    updates_per_learn: int = 1
    # gradient-batch size for the shared learner.  Smaller than the
    # sequential default (512) on purpose: the async-dispatched XLA step
    # must fit inside one round's Python simulator work to stay off the
    # critical path (at 256 it does on a 2-core CPU; the higher update
    # frequency compensates the smaller batch).
    learn_batch_size: int = 256
    sync_learn: bool = False         # True: block on each gradient step
    valid_every: int = 4             # validate every k completed episodes
    # prioritized replay over the SHARED buffer: |TD| priorities with
    # IS-weight correction (the packed-row weight column).  Uniform
    # sampling (False) remains the validated default.
    prioritized: bool = False
    # simulator backend, resolved through the ``core.backends``
    # registry: "py" steps each episode's SimInstances in Python;
    # "vec" packs ALL episodes' instances into one shared vecsim pool
    # and advances every instance of every episode in fused vector
    # rounds (decision-for-decision identical; see core.vecsim);
    # "jax" runs the same pool's round loop as one jitted device
    # program (core.jaxsim; bit-parity contract in docs/BACKENDS.md).
    # benchmarks/bench_batched_rl.py and bench_jaxsim.py gate the
    # speedups.
    backend: str = "py"
    # extra kwargs for the backend's ``make_pool`` (e.g. the jax
    # pool's hybrid threshold: {"min_span_ticks": 32} keeps short
    # spans on the numpy fast path and sends only long drain spans to
    # the jitted kernel)
    pool_kwargs: Optional[Dict] = None
    # DEPRECATED alias for ``backend`` (pre-registry spelling); when
    # set it wins, with a DeprecationWarning.
    sim_backend: Optional[str] = None

    def __post_init__(self):
        if self.sim_backend is not None:
            warnings.warn(
                "BatchedRLConfig.sim_backend is deprecated; use "
                "BatchedRLConfig(backend=...) — backends now resolve "
                "through the core.backends registry",
                DeprecationWarning, stacklevel=3)
            self.backend = self.sim_backend


class _Slot:
    """One concurrent episode: env + its schedule point and bookkeeping."""

    __slots__ = ("env", "ep", "scenario", "w_k", "w_sel", "eps", "window",
                 "rew", "s", "s_pad", "mask_pad", "reward", "ticks",
                 "done", "pool_ep")

    def __init__(self, cfg: rl.RouterConfig, scenario: Scenario, ep: int,
                 m_max: int, predict_decode, explore: bool,
                 pool=None, pool_ep: int = 0):
        if scenario.m > m_max:
            raise ValueError(
                f"scenario {scenario.name} has m={scenario.m} > "
                f"m_max={m_max}; raise BatchedRLConfig.m_max")
        self.pool_ep = pool_ep
        self.env = rl.RoutingEnv(cfg, scenario.profiles, predict_decode,
                                 pool=pool, pool_ep=pool_ep)
        self.ep = ep
        self.scenario = scenario
        self.w_k = rl.guidance_weight(cfg, ep)
        self.w_sel = (max(self.w_k, cfg.guidance_floor)
                      if cfg.variant == "guided" else 0.0)
        if explore:
            frac = min(ep / max(cfg.explore_episodes, 1), 1.0)
            eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
            self.eps = 0.0 if ep >= cfg.explore_episodes else eps
        else:
            self.eps = 0.0
        self.window: deque = deque()   # (s_pad, a_pad, index into rew)
        self.rew = []                  # scaled per-decision rewards
        self.reward = 0.0
        self.ticks = 0
        self.done = False
        s = self.env.reset(scenario.requests)
        self._set_state(s, m_max, cfg)

    def _set_state(self, s: np.ndarray, m_max: int,
                   cfg: rl.RouterConfig):
        self.s = s
        m = self.env.m
        self.s_pad = state_lib.pad_state(
            s, m, m_max, cfg.include_impact_features,
            cfg.include_hardware_features, cfg.include_cache_features,
            cfg.include_health_features)
        self.mask_pad = state_lib.pad_mask(self.env.mask(), m, m_max)

    def prior_pad(self, m_max: int) -> Optional[np.ndarray]:
        if not self.w_sel:
            return None
        bonus = self.env.guidance_bonus()
        m = self.env.m
        if m == m_max:
            return self.w_sel * bonus
        out = np.zeros(m_max + 1, np.float32)
        out[:m] = bonus[:m]
        out[m_max] = bonus[m]
        return self.w_sel * out

    def unpad_action(self, a: int, m_max: int) -> int:
        return self.env.m if a == m_max else a


def _act_padded(agent, cfg, slots, b_full: int, m_max: int,
                skip=None) -> np.ndarray:
    """One jitted Q dispatch for the live slots, batch-padded to
    ``b_full`` rows so XLA compiles exactly one shape per run (the slot
    pool shrinks in the drain phase; per-size retracing would pay a
    fresh compile each time).  Padding rows are all-masked and their
    argmax is discarded.  ``skip[i]`` rows (exploring slots) get no
    guidance prior."""
    b = len(slots)
    d = slots[0].s_pad.shape[0]
    states = np.zeros((b_full, d), np.float32)
    masks = np.zeros((b_full, m_max + 1), bool)
    for i, sl in enumerate(slots):
        states[i] = sl.s_pad
        masks[i] = sl.mask_pad
    priors = None
    if cfg.variant == "guided":
        priors = np.zeros((b_full, m_max + 1), np.float64)
        for i, sl in enumerate(slots):
            if skip is not None and skip[i]:
                continue
            p = sl.prior_pad(m_max)
            if p is not None:
                priors[i] = p
    acts = agent.act_batch(
        states, masks, epsilon=None, prior=priors,
        q_squash=cfg.q_squash if cfg.variant == "guided" else 0.0)
    return acts[:b]


def _flush_one(agent, slot: _Slot, gp: np.ndarray, nstep: int,
               out: Optional[list] = None):
    """Emit the oldest window entry's truncated n-step return.  Rewards
    live in one per-episode log (`slot.rew`) indexed by decision, so a
    decision costs one append instead of one append per window entry.
    With ``out`` the transition is collected for one batched insert at
    the end of the round (``_observe_packed``) instead of observed
    immediately; insertion order is preserved either way."""
    s0, a0, t0 = slot.window.popleft()
    rs = slot.rew[t0:t0 + nstep]
    ret = float(np.asarray(rs, np.float64) @ gp[:len(rs)])
    if out is None:
        agent.observe(s0, a0, ret, slot.s_pad, 1.0, slot.mask_pad)
    else:
        out.append((s0, a0, ret, slot.s_pad, 1.0, slot.mask_pad))


_PACK_ROWS = None


def _pack_rows_fn():
    """Jitted replay-row packer: one concatenate producing the exact
    ``ReplayBuffer`` row layout [s | s2 | a | r | done | mask2 | 1.0]
    for a whole round's transitions (device-resident when XLA has an
    accelerator; one fused op on CPU)."""
    global _PACK_ROWS
    if _PACK_ROWS is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def pack(s, a, r, s2, done, mask2):
            f32 = jnp.float32
            return jnp.concatenate(
                [s.astype(f32), s2.astype(f32),
                 a[:, None].astype(f32), r[:, None].astype(f32),
                 done[:, None].astype(f32), mask2.astype(f32),
                 jnp.ones((s.shape[0], 1), f32)], axis=1)
        _PACK_ROWS = pack
    return _PACK_ROWS


def _observe_packed(agent, trans: list):
    """Insert a round's transitions [(s, a, r, s2, done, mask2), ...]
    via the jitted packer + ``ReplayBuffer.add_rows`` -- bit-identical
    to per-transition ``agent.observe`` calls in the same order
    (asserted in tests/test_jaxsim.py).  Reward centering is an
    order-dependent EMA folded into ``r`` at observe time, so that
    configuration keeps the sequential path."""
    if not trans:
        return
    if agent.cfg.center_rewards:
        for t in trans:
            agent.observe(*t)
        return
    rows = _pack_rows_fn()(
        np.stack([t[0] for t in trans]),
        np.asarray([t[1] for t in trans], np.int32),
        np.asarray([t[2] for t in trans], np.float64),
        np.stack([t[3] for t in trans]),
        np.asarray([t[4] for t in trans], np.float64),
        np.stack([t[5] for t in trans]))
    agent.buffer.add_rows(np.asarray(rows))


def _step_fused(slots: List[_Slot], actions: List[int], pool,
                cfg: rl.RouterConfig):
    """One decision on every live episode with FUSED simulator
    stepping: apply each episode's action, then advance all episodes'
    instances together in shared vecsim rounds until every episode
    reaches its next decision point (non-empty router queue) or ends.
    Reward semantics are identical to per-slot ``RoutingEnv.step``
    (same ticks, same per-tick accrual); only the wall-clock cost
    changes -- O(rounds) instead of O(episodes x instances)."""
    n = len(slots)
    shaping = cfg.potential_shaping
    phi0 = ([sl.env._backlog_penalty() for sl in slots] if shaping
            else None)
    rewards = [sl.env._apply_action(actions[i], guide_w=sl.w_k)
               for i, sl in enumerate(slots)]
    dones = [False] * n
    pending = list(range(n))
    while pending:
        # each episode advances to its next possible decision point
        # (its next arrival) -- or a bounded drain window -- in ONE
        # pool call, so lanes at staggered iteration phases coincide
        # in the same fused rounds
        spans = {i: (slots[i].env.cluster.ep,
                     slots[i].env._span_bounds()) for i in pending}
        out = pool.advance_span(list(spans.values()))
        nxt = []
        for i in pending:
            env = slots[i].env
            ep, bounds = spans[i]
            gids, bk_rew = out[ep]
            done_now = env.cluster.collect_span(gids, len(bounds))
            delta, done = env._after_span(done_now, bk_rew)
            rewards[i] += delta
            if done:
                dones[i] = True
            elif not env.cluster.central:
                nxt.append(i)
        pending = nxt
    if shaping:
        for i, sl in enumerate(slots):
            rewards[i] += (cfg.gamma * sl.env._backlog_penalty()
                           - phi0[i])
    return rewards, dones


def train_batched(cfg: rl.RouterConfig,
                  scenario_fn: Callable[[int], Scenario],
                  n_episodes: int,
                  bcfg: Optional[BatchedRLConfig] = None,
                  agent=None,
                  predict_decode: Optional[Callable] = None,
                  valid_fn: Optional[Callable[[], Scenario]] = None,
                  verbose: bool = False,
                  registry=None) -> Dict:
    """Train the RL router over ``n_episodes`` scenarios, ``bcfg.n_envs``
    at a time; returns {agent, history} like `rl_router.train`.

    ``scenario_fn(ep)`` must return a FRESH Scenario per call (the
    simulation consumes its request objects).  ``valid_fn`` (optional)
    returns a validation Scenario; every ``bcfg.valid_every`` completed
    episodes the current greedy policy is scored on it and the best
    snapshot is restored at the end, as in the sequential trainer.

    ``registry`` (optional ``serving.obs.MetricsRegistry``) receives
    training telemetry after every finished episode: the episode's
    epsilon / reward / mean latencies under ``rl_episode_*`` and the
    agent's learner internals (loss, |TD|, replay priorities) from
    ``agent.telemetry()`` under ``rl_*`` -- the same scrape target the
    gateway publishes serving metrics to."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    bcfg = bcfg or BatchedRLConfig()
    m_max = bcfg.m_max or cfg.n_instances
    agent = agent or rl.make_agent(cfg, m=m_max)
    if bcfg.learn_batch_size and \
            agent.cfg.batch_size != bcfg.learn_batch_size:
        agent.cfg = dataclasses.replace(agent.cfg,
                                        batch_size=bcfg.learn_batch_size)
    if bcfg.prioritized and not agent.cfg.prioritized:
        agent.cfg = dataclasses.replace(agent.cfg, prioritized=True)
    scale = 1.0 if cfg.potential_shaping else cfg.reward_scale
    gp = cfg.nstep_gamma ** np.arange(max(cfg.nstep, 1), dtype=np.float64)
    history: List[Dict] = []
    best = None
    started = 0
    pool = None
    if bcfg.backend != "py":
        from repro.core.backends import make_backend
        pool = make_backend(bcfg.backend).make_pool(
            min(bcfg.n_envs, n_episodes), **(bcfg.pool_kwargs or {}))
    slots: List[_Slot] = []
    while started < min(bcfg.n_envs, n_episodes):
        slots.append(_Slot(cfg, scenario_fn(started), started, m_max,
                           predict_decode, explore=True,
                           pool=pool, pool_ep=started))
        started += 1
    round_i = 0
    since_valid = 0
    b_full = len(slots)      # the slot pool only ever shrinks
    while slots:
        b = len(slots)
        # exploration draws first: exploring slots need neither Q values
        # nor guidance priors (mirrors the sequential act() early-out),
        # and an all-exploring round skips the jitted dispatch entirely
        explore = agent.rng.random(b) < np.array([sl.eps for sl in slots])
        if explore.all():
            acts = np.array([agent.rng.choice(np.flatnonzero(sl.mask_pad))
                             for sl in slots], np.int64)
        else:
            acts = _act_padded(agent, cfg, slots, b_full, m_max,
                               skip=explore)
            for i in np.flatnonzero(explore):
                acts[i] = agent.rng.choice(
                    np.flatnonzero(slots[i].mask_pad))
        # dispatch the gradient step(s) NOW, right after the params were
        # consumed by act_batch: with sync_learn=False the XLA update
        # runs on a worker thread while the Python below steps the N
        # simulators, so the learner costs almost no wall time.  (The
        # next round's act_batch blocks until the new params are ready.)
        round_i += 1
        if round_i % bcfg.learn_every_rounds == 0:
            for _ in range(bcfg.updates_per_learn):
                agent.learn(sync=bcfg.sync_learn)
        finished: List[_Slot] = []
        if pool is not None:
            fused_r, fused_done = _step_fused(
                slots, [sl.unpad_action(int(acts[i]), m_max)
                        for i, sl in enumerate(slots)], pool, cfg)
            fused_s2 = state_lib.featurize_vec_many(
                [sl.env.cluster for sl in slots],
                [sl.env.profile for sl in slots],
                [sl.env.predict_decode for sl in slots],
                n_buckets=cfg.n_buckets,
                include_impact=cfg.include_impact_features,
                alpha=cfg.alpha,
                include_hardware=cfg.include_hardware_features,
                include_cache=cfg.include_cache_features,
                include_health=cfg.include_health_features)
        flush: List[tuple] = []
        for i, sl in enumerate(slots):
            a_pad = int(acts[i])
            s_prev_pad = sl.s_pad
            if pool is not None:
                r, done = fused_r[i], fused_done[i]
                s2 = fused_s2[i]
            else:
                s2, r, done, _ = sl.env.step(
                    sl.unpad_action(a_pad, m_max), guide_w=sl.w_k)
            sl._set_state(s2, m_max, cfg)
            if cfg.nstep > 0:
                sl.window.append((s_prev_pad, a_pad, len(sl.rew)))
                sl.rew.append(r / scale)
                if len(sl.window) > cfg.nstep:
                    _flush_one(agent, sl, gp, cfg.nstep, out=flush)
            else:
                flush.append((s_prev_pad, a_pad, r / scale, sl.s_pad,
                              float(done), sl.mask_pad))
            sl.reward += r
            sl.ticks += 1
            if done:
                sl.done = True
                finished.append(sl)
        for sl in finished:
            while sl.window:
                _flush_one(agent, sl, gp, cfg.nstep, out=flush)
        # one packed insert per round; the learner only reads the
        # buffer at the NEXT round's learn call, so deferring to here
        # is invisible to training (order within the round preserved)
        _observe_packed(agent, flush)
        for sl in finished:
            if pool is not None:
                sl.env.cluster.sync_all()     # max_time stragglers
            stats = summarize(sl.scenario.requests)
            stats.update({"episode": sl.ep, "reward": sl.reward,
                          "ticks": sl.ticks, "epsilon": sl.eps,
                          "guide_w": sl.w_k,
                          "scenario": sl.scenario.name,
                          "pattern": sl.scenario.pattern,
                          "m": sl.scenario.m})
            since_valid += 1
            if (valid_fn is not None and sl.eps <= 0.6
                    and since_valid >= bcfg.valid_every):
                since_valid = 0
                v = evaluate_scenarios(cfg, agent, [valid_fn()],
                                       predict_decode, m_max=m_max)[0]
                stats["valid_e2e"] = v["e2e_mean"]
                if best is None or v["e2e_mean"] < best[0]:
                    best = (v["e2e_mean"],
                            jax.tree.map(jnp.copy, agent.params))
            history.append(stats)
            if registry is not None:
                registry.ingest(
                    {"index": float(sl.ep), "epsilon": sl.eps,
                     "reward": sl.reward, "guide_w": sl.w_k,
                     "e2e_mean": stats.get("e2e_mean"),
                     "ttft_mean": stats.get("ttft_mean")},
                    prefix="rl_episode")
                registry.ingest_rl(agent.telemetry())
            if verbose:
                print(f"ep {sl.ep:3d} [{sl.scenario.name:>20s}] "
                      f"eps={sl.eps:.2f} reward={sl.reward:10.1f} "
                      f"e2e={stats.get('e2e_mean', float('nan')):.2f}")
            idx = slots.index(sl)
            if started < n_episodes:
                # a replacement episode reuses the finished slot's pool
                # episode (its lanes are reconfigured for the new shape)
                slots[idx] = _Slot(cfg, scenario_fn(started), started,
                                   m_max, predict_decode, explore=True,
                                   pool=pool, pool_ep=sl.pool_ep)
                started += 1
            else:
                slots.pop(idx)
    if best is not None:
        agent.params = best[1]
        agent.target = jax.tree.map(jnp.copy, best[1])
    history.sort(key=lambda h: h["episode"])
    return {"agent": agent, "history": history}


def evaluate_scenarios(cfg: rl.RouterConfig, agent,
                       scenarios: Sequence[Scenario],
                       predict_decode: Optional[Callable] = None,
                       m_max: Optional[int] = None,
                       backend: str = "py",
                       sim_backend: Optional[str] = None) -> List[Dict]:
    """Greedy (epsilon=0, no learning) batched evaluation; one stats dict
    per scenario, same fields as `rl_router.evaluate`.  With a single
    homogeneous scenario of width cfg.n_instances this reproduces the
    sequential evaluate decision for decision (on any registry
    backend).  ``sim_backend=`` is the deprecated alias of
    ``backend=``."""
    if sim_backend is not None:
        warnings.warn(
            "evaluate_scenarios(sim_backend=...) is deprecated; use "
            "backend=...", DeprecationWarning, stacklevel=2)
        backend = sim_backend
    m_max = m_max or max([cfg.n_instances] + [s.m for s in scenarios])
    pool = None
    if backend != "py":
        from repro.core.backends import make_backend
        pool = make_backend(backend).make_pool(len(scenarios))
    slots = [_Slot(cfg, s, ep=0, m_max=m_max,
                   predict_decode=predict_decode, explore=False,
                   pool=pool, pool_ep=i)
             for i, s in enumerate(scenarios)]
    for sl in slots:
        sl.w_sel = cfg.guidance_floor if cfg.variant == "guided" else 0.0
    live = [sl for sl in slots if not sl.done]
    b_full = max(len(live), 1)
    while live:
        acts = _act_padded(agent, cfg, live, b_full, m_max)
        for i, sl in enumerate(live):
            a = sl.unpad_action(int(acts[i]), m_max)
            s2, _, done, _ = sl.env.step(a)
            sl._set_state(s2, m_max, cfg)
            sl.done = done
        live = [sl for sl in live if not sl.done]
    out = []
    for sl in slots:
        if getattr(sl.env.cluster, "is_vec", False):
            sl.env.cluster.sync_all()     # truncated-run stragglers
        stats = summarize(sl.scenario.requests)
        stats["spikes"] = sum(len(i.spikes)
                              for i in sl.env.cluster.instances)
        routed = [r.routed_at - r.arrival for r in sl.scenario.requests
                  if r.routed_at is not None]
        stats["router_wait_mean"] = (float(np.mean(routed))
                                     if routed else 0.0)
        stats["scenario"] = sl.scenario.name
        out.append(stats)
    return out
