"""Discrete-event cluster simulator for routing experiments.

``SimInstance`` mirrors the real engine's continuous-batching semantics
(slot admission via an instance scheduler, one admission per iteration,
gang decode, capacity-budget preemption of the newest request) but costs
iterations with the calibrated HardwareProfile instead of running a model,
so thousand-request episodes run in milliseconds-per-simulated-second --
fast enough to train the RL router.

Sarathi-style chunked prefill (paper §6.3) is a timing-level instance
optimization: with ``chunked_prefill=C`` a prompt is processed C tokens per
iteration and decodes piggyback (no decode stall, smaller TBT spikes, TTFT
pays per-iteration overhead) -- exactly the trade-off Table 3 probes.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

from repro.core.prefix_cache import PrefixCache
from repro.core.profiles import HardwareProfile
from repro.serving import trace as _trace
from repro.serving.request import Phase, Request
from repro.serving.scheduler import InstanceScheduler, get_scheduler


class SimInstance:
    def __init__(self, profile: HardwareProfile,
                 scheduler: InstanceScheduler, instance_id: int = 0,
                 chunked_prefill: int = 0, n_slots: Optional[int] = None,
                 prefix_cache_tokens: int = 0, prefix_block: int = 32,
                 trace=None):
        self.profile = profile
        # lifecycle tracing (serving.trace); NULL keeps the hot path at
        # one attribute check per emission site
        self.trace = trace if trace is not None else _trace.NULL
        self.scheduler = scheduler
        self.instance_id = instance_id
        self.chunk = chunked_prefill
        self.n_slots = n_slots or profile.max_batch
        # prefix/KV cache model (0 tokens = disabled -- the paper's
        # baseline setup): admitted requests whose prompt hash-chain
        # shares a cached prefix start with ``prefilled = cached``
        self.prefix_cache = (PrefixCache(prefix_cache_tokens,
                                         prefix_block)
                             if prefix_cache_tokens > 0 else None)
        self.residents: List[Request] = []      # decoding or chunk-prefilling
        self.queue: deque = deque()
        self.clock = 0.0
        self.completed: List[Request] = []
        self.failed = False
        # straggler model: scales every iteration time (1.0 = nominal);
        # mirrored bit-exactly as the vecsim ``speed`` lane array
        self.speed_factor = 1.0
        self.spikes: List[float] = []           # iteration times > 2x base
        self._admit_seq = 0
        # observer hooks (the RL env maintains its backlog penalty
        # incrementally from these instead of rescanning every request
        # every tick): on_token(r) after each decoded token, on_preempt(r)
        # BEFORE a preemption resets r's progress.
        self.on_token = None
        self.on_preempt = None
        # incrementally-maintained token sums (every mutation site in
        # this class updates them; recomputing per query dominated the
        # simulator's profile).  Queue invariant: queued requests always
        # have zero progress (preemption resets before requeue), so the
        # queue's context sum equals its prompt sum.
        self._rts = 0.0                # sum of total_context, residents
        self._qps = 0.0                # sum of prompt_tokens, queue
        self._out = 0.0                # outstanding prompt+decode tokens

    # -- router-visible state ------------------------------------------------
    def resident_token_sum(self) -> float:
        return self._rts

    def queued_prompt_sum(self) -> float:
        return self._qps

    def outstanding_tokens(self) -> float:
        """Total tokens yet to be processed (for JSQ) -- O(1).

        Maintained incrementally like ``_rts``/``_qps`` (it used to
        rescan residents+queue on every JSQ route decision): submit
        adds prompt+decode, each prefill token and each decoded token
        subtracts one, preemption re-adds the lost progress.  Admission
        and completion are net zero (queued requests carry no progress;
        a completing request has none left)."""
        return self._out

    def free_tokens(self) -> float:
        return self.profile.capacity_tokens - self._rts - self._qps

    def earliest_completion(self) -> float:
        """(iterations left) x (average batch time) for the closest
        resident (paper §4.2)."""
        if not self.residents:
            return 0.0
        left = min(max(r.decode_tokens - r.decoded, 0)
                   for r in self.residents)
        return left * self.profile.t_decode_base

    def load_summary(self) -> Dict:
        return {
            "n_resident": len(self.residents),
            "n_queued": len(self.queue),
            "p_tokens": [r.prompt_tokens for r in self.residents],
            "d_tokens": [r.decoded for r in self.residents],
            "resident_tokens": self.resident_token_sum(),
            "free_tokens": self.free_tokens(),
            "earliest_completion": self.earliest_completion(),
            "clock": self.clock,
        }

    # -- intake ---------------------------------------------------------------
    def submit(self, req: Request):
        req.phase = Phase.INSTANCE_QUEUE
        req.instance = self.instance_id
        req.routed_at = self.clock
        self.queue.append(req)
        self._qps += req.prompt_tokens
        self._out += req.prompt_tokens + req.decode_tokens

    # -- iterate until the cluster time --------------------------------------
    def run_until(self, t: float) -> List[Request]:
        done: List[Request] = []
        if self.failed:
            self.clock = t
            return done
        while self.clock < t:
            if not self.residents and not self.queue:
                self.clock = t
                break
            done.extend(self._iteration())
        return done

    def _iteration(self) -> List[Request]:
        profile = self.profile
        tr = self.trace
        prefill_tokens = 0
        # resident context tokens before this iteration's prefill/decode
        rts = self._rts
        # admission: one request per iteration if a slot is free
        if len(self.residents) < self.n_slots and self.queue:
            budget = profile.capacity_tokens - rts
            pick = self.scheduler.pick(list(self.queue), budget, profile)
            if pick is not None:
                req = self.queue[pick]
                del self.queue[pick]
                self._qps -= req.prompt_tokens
                req.phase = Phase.PREFILL
                req.admitted_idx = self._admit_seq
                self._admit_seq += 1
                self.residents.append(req)
                if self.prefix_cache is not None and req.prefix_hashes:
                    # longest-prefix hit: the cached part of the prompt
                    # is already prefilled (counts as resident context
                    # but never enters the prefill loop); the prompt's
                    # own chain becomes resident for later arrivals
                    cached = self.prefix_cache.admit(req.prompt_tokens,
                                                     req.prefix_hashes)
                    req.prefilled = cached
                    req.cached_prefix = cached
                    self._out -= cached
                self._rts += req.prefilled + req.decoded
                rts = self._rts
                if tr.enabled:
                    tr.emit(self.clock, _trace.EV_INST_ADMIT, req.rid,
                            self.instance_id, req.tenant,
                            {"cached": int(req.cached_prefix)})
        # prefill progress (full, or one chunk per iteration)
        for r in self.residents:
            if r.phase is Phase.PREFILL:
                step = (r.prompt_tokens - r.prefilled) if not self.chunk \
                    else min(self.chunk, r.prompt_tokens - r.prefilled)
                r.prefilled += step
                prefill_tokens += step
                if tr.enabled and self.chunk and step > 0:
                    tr.emit(self.clock, _trace.EV_PREFILL_CHUNK, r.rid,
                            self.instance_id, r.tenant,
                            {"tokens": int(step)})
                if r.prefilled >= r.prompt_tokens:
                    r.phase = Phase.DECODE
                    r.prefill_done = self.clock
                    if tr.enabled:
                        tr.emit(self.clock, _trace.EV_PREFILL_DONE, r.rid,
                                self.instance_id, r.tenant)
                if not self.chunk:
                    break     # unchunked: only one prefill per iteration
        # decode every resident already in decode phase
        decoding = [r for r in self.residents if r.phase is Phase.DECODE]
        # iteration time (spikes when prefill mixes in -- Fig. 1a);
        # resident-other is the pre-prefill context sum
        it_time = profile.iteration_time(prefill_tokens, rts) \
            * self.speed_factor
        if it_time > 2.0 * profile.t_decode_base * self.speed_factor:
            self.spikes.append(it_time)
        self.clock += it_time
        rts += prefill_tokens
        self._out -= prefill_tokens + len(decoding)
        done: List[Request] = []
        on_token = self.on_token
        for r in decoding:
            r.decoded += 1
            rts += 1
            if r.first_token is None:
                r.first_token = self.clock
                if tr.enabled:
                    tr.emit(self.clock, _trace.EV_FIRST_TOKEN, r.rid,
                            self.instance_id, r.tenant)
            r.token_times.append(self.clock)
            if on_token is not None:
                on_token(r)
            if r.decoded >= r.decode_tokens:
                r.phase = Phase.DONE
                r.finished = self.clock
                self.completed.append(r)
                done.append(r)
                if tr.enabled:
                    tr.emit(self.clock, _trace.EV_COMPLETE, r.rid,
                            self.instance_id, r.tenant)
                rts -= r.prefilled + r.decoded
                if self.prefix_cache is not None and r.full_hashes:
                    # the finished conversation's KV (prompt + reply)
                    # stays cached: the follow-up turn extends it
                    self.prefix_cache.insert(r.full_hashes)
        if done:
            self.residents = [r for r in self.residents
                              if r.phase is not Phase.DONE]
        # capacity enforcement: evict newest-admitted until within budget.
        # The OLDEST resident is never evicted (liveness: it runs to
        # completion even if it alone overshoots -- swap-space grace),
        # matching vLLM's recompute-preemption order.
        while rts > profile.capacity_tokens and len(self.residents) > 1:
            victim = max(self.residents, key=lambda r: r.admitted_idx)
            self.residents.remove(victim)
            rts -= victim.prefilled + victim.decoded
            self._out += victim.prefilled + victim.decoded
            if self.on_preempt is not None:
                self.on_preempt(victim)
            if tr.enabled:
                tr.emit(self.clock, _trace.EV_PREEMPT, victim.rid,
                        self.instance_id, victim.tenant,
                        {"lost": int(victim.prefilled + victim.decoded)})
            victim.reset_progress()
            self.queue.appendleft(victim)
            self._qps += victim.prompt_tokens
        self._rts = rts
        return done

    # -- fault injection ------------------------------------------------------
    def fail(self) -> List[Request]:
        self.failed = True
        if self.trace.enabled:
            self.trace.emit(self.clock, _trace.EV_FAIL, -1,
                            self.instance_id)
        orphans = list(self.residents) + list(self.queue)
        self.residents, self.queue = [], deque()
        if self.prefix_cache is not None:
            self.prefix_cache.clear()   # the KV pool dies with the node
        self._rts = 0.0
        self._qps = 0.0
        self._out = 0.0
        for r in orphans:
            if self.on_preempt is not None:
                self.on_preempt(r)
            r.reset_progress()
            r.phase = Phase.QUEUED
            r.instance = None
            # the attempt died: clear its timing stamps so TTFT/TBT/E2E
            # measure the attempt that actually serves the request (a
            # stale first_token would anchor TTFT at the dead node)
            r.first_token = None
            r.token_times = []
            r.prefill_done = None
        return orphans

    def recover(self):
        """Undo :meth:`fail`: the node comes back *empty* (no residents,
        cold prefix cache) at its current clock and resumes accepting
        work.  Emits ``recover`` so traces show the outage window."""
        self.failed = False
        if self.trace.enabled:
            self.trace.emit(self.clock, _trace.EV_RECOVER, -1,
                            self.instance_id)

    def restore(self):
        self.failed = False

    def steal(self, req: Request) -> bool:
        """Withdraw a routed request (hedged re-dispatch): remove it
        from this instance's queue or residents, reset its progress and
        timing stamps, and hand it back to the caller.  Returns False if
        the request is no longer here (completed this tick)."""
        if req in self.residents:
            self.residents.remove(req)
            self._rts -= req.prefilled + req.decoded
            self._out -= ((req.prompt_tokens - req.prefilled)
                          + (req.decode_tokens - req.decoded))
            if self.on_preempt is not None:
                self.on_preempt(req)
        else:
            try:
                self.queue.remove(req)
            except ValueError:
                return False
            self._qps -= req.prompt_tokens
            self._out -= req.prompt_tokens + req.decode_tokens
        req.reset_progress()
        req.phase = Phase.QUEUED
        req.instance = None
        req.first_token = None
        req.token_times = []
        req.prefill_done = None
        return True


class Cluster:
    """m instances + the central router queue, stepped at dt (= the paper's
    0.02 s action interval).

    ``profile`` may be a single HardwareProfile (homogeneous cluster, the
    paper's setup) or a sequence of per-instance profiles (heterogeneous
    cluster -- mixed GPU generations behind one router); in the latter
    case ``n_instances`` must match and ``cluster.profile`` is the first
    entry (the router-level reference profile).

    ``backend`` resolves through the ``core.backends`` registry:
    ``"vec"`` returns the vectorized structure-of-arrays implementation
    (`core.vecsim.VecCluster`, decision-for-decision identical;
    O(rounds) stepping instead of O(requests x instances)), ``"jax"``
    its device-resident jitted subclass (`core.jaxsim`) -- the Python
    stepper remains the reference oracle."""

    def __new__(cls, profile=None, n_instances: int = 0,
                scheduler: str = "fcfs", dt: float = 0.02,
                chunked_prefill: int = 0,
                n_slots: Optional[int] = None, backend: str = "py",
                prefix_cache_tokens: int = 0, prefix_block: int = 32,
                trace=None):
        if cls is Cluster and backend != "py":
            from repro.core.backends import make_backend
            # registry backends are not Cluster subclasses, so
            # __init__ below is not re-run on the returned object
            return make_backend(backend).make_cluster(
                profile, n_instances, scheduler=scheduler, dt=dt,
                chunked_prefill=chunked_prefill, n_slots=n_slots,
                prefix_cache_tokens=prefix_cache_tokens,
                prefix_block=prefix_block, trace=trace)
        return super().__new__(cls)

    def __init__(self, profile, n_instances: int,
                 scheduler: str = "fcfs", dt: float = 0.02,
                 chunked_prefill: int = 0,
                 n_slots: Optional[int] = None, backend: str = "py",
                 prefix_cache_tokens: int = 0, prefix_block: int = 32,
                 trace=None):
        if isinstance(profile, HardwareProfile):
            profiles = [profile] * n_instances
        else:
            profiles = list(profile)
            if len(profiles) != n_instances:
                raise ValueError(
                    f"{len(profiles)} profiles for {n_instances} instances")
        self.profile = profiles[0]
        self.profiles = tuple(profiles)
        self.dt = dt
        self._prefix_cache_tokens = prefix_cache_tokens
        self._prefix_block = prefix_block
        self._trace = trace if trace is not None else _trace.NULL
        self.instances = [
            SimInstance(profiles[i], get_scheduler(scheduler), i,
                        chunked_prefill, n_slots,
                        prefix_cache_tokens=prefix_cache_tokens,
                        prefix_block=prefix_block, trace=self._trace)
            for i in range(n_instances)]
        self.central: deque = deque()
        self.t = 0.0
        self.completed: List[Request] = []
        self.queue_len_trace: List[int] = []

    @property
    def m(self) -> int:
        return len(self.instances)

    def set_trace(self, trace):
        """Attach a TraceRecorder after construction (gateway over a
        pre-built cluster)."""
        self._trace = trace
        for inst in self.instances:
            inst.trace = trace

    def alive(self) -> List[int]:
        return [i for i, inst in enumerate(self.instances)
                if not inst.failed]

    def enqueue(self, req: Request):
        req.phase = Phase.QUEUED
        self.central.append(req)

    def route(self, idx: int) -> Request:
        req = self.central.popleft()
        self.instances[idx].submit(req)
        return req

    def advance(self) -> List[Request]:
        """Advance the cluster clock by dt; returns completions."""
        self.t += self.dt
        done: List[Request] = []
        for inst in self.instances:
            done.extend(inst.run_until(self.t))
        self.completed.extend(done)
        self.queue_len_trace.append(len(self.central))
        return done

    def add_instance(self, scheduler: str = "fcfs",
                     chunked_prefill: int = 0,
                     profile: Optional[HardwareProfile] = None) -> int:
        """Elastic scale-out (optionally with a different hardware tier)."""
        inst = SimInstance(profile or self.profile, get_scheduler(scheduler),
                           len(self.instances), chunked_prefill,
                           prefix_cache_tokens=self._prefix_cache_tokens,
                           prefix_block=self._prefix_block,
                           trace=self._trace)
        inst.clock = self.t
        # inherit cluster-level observer hooks (the RL env's incremental
        # backlog accounting must see the new instance's decode events)
        if self.instances:
            inst.on_token = self.instances[0].on_token
            inst.on_preempt = self.instances[0].on_preempt
        self.instances.append(inst)
        self.profiles = self.profiles + (inst.profile,)
        return inst.instance_id

    def fail_instance(self, idx: int, requeue: bool = True) -> List[Request]:
        """Node failure: orphaned requests are requeued centrally
        (default; idempotent request ids, progress restarts) or -- with
        ``requeue=False`` -- returned for the caller's failover machinery
        (the gateway's bounded-retry path) to take ownership of."""
        orphans = self.instances[idx].fail()
        if requeue:
            for r in orphans:
                self.central.appendleft(r)
        return orphans

    def recover_instance(self, idx: int):
        """Bring a failed instance back into service at the cluster
        clock; policies see it in ``alive()`` from the next decision."""
        inst = self.instances[idx]
        inst.clock = max(inst.clock, self.t)
        inst.recover()

    def set_speed_factor(self, idx: int, factor: float):
        """Straggler injection: scale instance ``idx``'s iteration times
        (1.0 = nominal, 2.0 = half speed)."""
        self.instances[idx].speed_factor = float(factor)

    def steal(self, req: Request) -> bool:
        """Withdraw a routed-but-tokenless request for hedged
        re-dispatch (see SimInstance.steal)."""
        if req.instance is None:
            return False
        return self.instances[req.instance].steal(req)


def run_heuristic(cluster: Cluster, requests: Sequence[Request], policy,
                  max_time: float = 36000.0,
                  routes_per_tick: int = 64) -> Dict:
    """Drive a (non-RL) routing policy over an episode."""
    pending = sorted(requests, key=lambda r: r.arrival)
    i = 0
    n = len(pending)
    while len(cluster.completed) < n and cluster.t < max_time:
        while i < n and pending[i].arrival <= cluster.t:
            cluster.enqueue(pending[i])
            i += 1
        for _ in range(routes_per_tick):
            if not cluster.central:
                break
            act = policy.act(cluster)
            if act is None or act >= cluster.m:
                break               # defer
            cluster.route(act)
        cluster.advance()
    if getattr(cluster, "is_vec", False):
        cluster.sync_all()       # in-flight requests on truncated runs
    from repro.serving.request import summarize
    stats = summarize(requests)
    stats["spikes"] = sum(len(inst.spikes) for inst in cluster.instances)
    return stats
