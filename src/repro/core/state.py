"""MDP state featurization (paper §5.3 State Space + §A.9 bounds).

Per instance (8 dims):
  P_t histogram: resident prompt tokens in 3 buckets (0-256, 256-2048, >2048)
  D_t histogram: resident decoded tokens in the same 3 buckets
  C_t: free capacity fraction
  T_c: estimated earliest completion (clipped, normalized)
Router (4 dims):
  queue length (bounded by 4 x max_batch = 512, as §A.9),
  next request prompt tokens (normalized),
  next request predicted decode bucket,
  head-of-queue waiting time (clipped).

Heterogeneous clusters: every per-instance feature is computed against
that instance's own ``HardwareProfile`` (capacity fraction, earliest
completion, impact score), so mixed-hardware episodes featurize
correctly; the ``profile`` argument is the router-level reference used
only for the head request's decode bucket.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core import impact
from repro.core.profiles import HardwareProfile
from repro.core.simulator import Cluster

BUCKET_EDGES = (256, 2048)          # paper A.9 DQN buckets
N_BUCKETS = len(BUCKET_EDGES) + 1
INSTANCE_DIMS = 2 * N_BUCKETS + 2
ROUTER_DIMS = 4

_E0, _E1 = BUCKET_EDGES


def state_dim(m: int, include_impact: bool = True) -> int:
    return (INSTANCE_DIMS + (1 if include_impact else 0)) * m + ROUTER_DIMS


def featurize(cluster: Cluster, profile: HardwareProfile,
              predict_bucket: Optional[Callable] = None,
              n_buckets: int = 8, include_impact: bool = True,
              predict_decode: Optional[Callable] = None,
              alpha: float = 0.5) -> np.ndarray:
    # Featurization runs once per router decision; it is written as a
    # single pass of scalar Python per instance because numpy call
    # overhead dominates at these sizes (a handful of residents).
    head = cluster.central[0] if cluster.central else None
    dims = INSTANCE_DIMS + (1 if include_impact else 0)
    feats = [0.0] * (dims * cluster.m + ROUTER_DIMS)
    if include_impact and head is not None:
        d_hat = (predict_decode(head) if predict_decode
                 else head.decode_tokens)
    for k, inst in enumerate(cluster.instances):
        if inst.failed:
            continue         # failed instance: all-zero block
        prof = inst.profile
        base = k * dims
        scale = inst.n_slots
        p0 = p1 = p2 = d0 = d1 = d2 = 0
        ctx = 0
        min_left = None
        for r in inst.residents:
            p = r.prompt_tokens
            if p < _E0:
                p0 += 1
            elif p < _E1:
                p1 += 1
            else:
                p2 += 1
            d = r.decoded
            if d < _E0:
                d0 += 1
            elif d < _E1:
                d1 += 1
            else:
                d2 += 1
            ctx += r.prefilled + d
            left = r.decode_tokens - d
            if min_left is None or left < min_left:
                min_left = left
        # queued requests carry zero progress: queue context == prompts
        q_prompt = q_ctx = inst.queued_prompt_sum()
        feats[base] = p0 / scale
        feats[base + 1] = p1 / scale
        feats[base + 2] = p2 / scale
        feats[base + 3] = d0 / scale
        feats[base + 4] = d1 / scale
        feats[base + 5] = d2 / scale
        free = (prof.capacity_tokens - ctx - q_prompt) / prof.capacity_tokens
        feats[base + 6] = -1.0 if free < -1.0 else (1.0 if free > 1.0
                                                    else free)
        t_c = (max(min_left, 0) * prof.t_decode_base / 10.0
               if min_left is not None else 0.0)
        feats[base + 7] = 1.0 if t_c > 1.0 else t_c
        if include_impact and head is not None:
            # the workload impact estimator is a router module (§5.2); its
            # per-instance score for the head request is part of the
            # router's observable state.
            score = impact.r_mixing(prof, head.prompt_tokens, d_hat,
                                    ctx + q_ctx, alpha)
            feats[base + 8] = -5.0 if score < -5.0 else (
                1.0 if score > 1.0 else score)
    feats[dims * cluster.m] = min(len(cluster.central), 512) / 512.0
    if head is not None:
        if head.predicted_bucket is not None:
            bucket = head.predicted_bucket
        elif predict_bucket is not None:
            bucket = predict_bucket(head)
        else:
            bucket = profile.bucketize(head.decode_tokens, n_buckets)
        feats[dims * cluster.m + 1] = min(head.prompt_tokens, 2048) / 2048.0
        feats[dims * cluster.m + 2] = bucket / max(n_buckets - 1, 1)
        wait = (cluster.t - head.arrival) / 10.0
        feats[dims * cluster.m + 3] = 1.0 if wait > 1.0 else (
            0.0 if wait < 0.0 else wait)
    return np.asarray(feats, np.float32)


def pad_state(s: np.ndarray, m: int, m_max: int,
              include_impact: bool = True) -> np.ndarray:
    """Pad an m-instance state vector to m_max instance slots (zeros --
    the same encoding as a failed instance) so episodes with different
    cluster shapes share one replay buffer / Q network."""
    if m == m_max:
        return s
    dims = INSTANCE_DIMS + (1 if include_impact else 0)
    out = np.zeros(dims * m_max + ROUTER_DIMS, np.float32)
    out[:dims * m] = s[:dims * m]
    out[dims * m_max:] = s[dims * m:]
    return out


def action_mask(cluster: Cluster) -> np.ndarray:
    """[m+1] bool: failed instances masked out; defer always allowed."""
    m = cluster.m
    mask = np.zeros(m + 1, bool)
    for i, inst in enumerate(cluster.instances):
        mask[i] = not inst.failed
    mask[m] = True
    if not cluster.central:          # nothing to route: only defer is valid
        mask[:m] = False
    return mask


def pad_mask(mask: np.ndarray, m: int, m_max: int) -> np.ndarray:
    """Pad an [m+1] action mask to [m_max+1]: padded instance slots are
    invalid; defer moves to the last position."""
    if m == m_max:
        return mask
    out = np.zeros(m_max + 1, bool)
    out[:m] = mask[:m]
    out[m_max] = mask[m]
    return out
