"""MDP state featurization (paper §5.3 State Space + §A.9 bounds).

Per instance (8 dims):
  P_t histogram: resident prompt tokens in 3 buckets (0-256, 256-2048, >2048)
  D_t histogram: resident decoded tokens in the same 3 buckets
  C_t: free capacity fraction
  T_c: estimated earliest completion (clipped, normalized)
Router (4 dims):
  queue length (bounded by 4 x max_batch = 512, as §A.9),
  next request prompt tokens (normalized),
  next request predicted decode bucket,
  head-of-queue waiting time (clipped).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.profiles import HardwareProfile
from repro.core.simulator import Cluster

BUCKET_EDGES = (256, 2048)          # paper A.9 DQN buckets
N_BUCKETS = len(BUCKET_EDGES) + 1
INSTANCE_DIMS = 2 * N_BUCKETS + 2
ROUTER_DIMS = 4


def state_dim(m: int, include_impact: bool = True) -> int:
    return (INSTANCE_DIMS + (1 if include_impact else 0)) * m + ROUTER_DIMS


def _hist(tokens, scale: float) -> np.ndarray:
    h = np.zeros(N_BUCKETS, np.float32)
    for t in tokens:
        h[int(np.searchsorted(BUCKET_EDGES, t, side="right"))] += 1
    return h / scale


def featurize(cluster: Cluster, profile: HardwareProfile,
              predict_bucket: Optional[Callable] = None,
              n_buckets: int = 8, include_impact: bool = True,
              predict_decode: Optional[Callable] = None,
              alpha: float = 0.5) -> np.ndarray:
    feats = []
    head = cluster.central[0] if cluster.central else None
    for inst in cluster.instances:
        dims = INSTANCE_DIMS + (1 if include_impact else 0)
        if inst.failed:
            feats.extend([0.0] * dims)
            continue
        s = inst.load_summary()
        scale = float(inst.n_slots)
        feats.extend(_hist(s["p_tokens"], scale))
        feats.extend(_hist(s["d_tokens"], scale))
        feats.append(np.clip(s["free_tokens"]
                             / profile.capacity_tokens, -1.0, 1.0))
        feats.append(np.clip(s["earliest_completion"] / 10.0, 0.0, 1.0))
        if include_impact:
            # the workload impact estimator is a router module (§5.2); its
            # per-instance score for the head request is part of the
            # router's observable state.
            if head is not None:
                from repro.core import impact
                d_hat = (predict_decode(head) if predict_decode
                         else head.decode_tokens)
                resident = s["resident_tokens"] + sum(
                    r.prompt_tokens + r.decoded for r in inst.queue)
                score = impact.r_mixing(profile, head.prompt_tokens,
                                        d_hat, resident, alpha)
                feats.append(float(np.clip(score, -5.0, 1.0)))
            else:
                feats.append(0.0)
    qlen = min(len(cluster.central), 512) / 512.0
    if head is not None:
        if head.predicted_bucket is not None:
            bucket = head.predicted_bucket
        elif predict_bucket is not None:
            bucket = predict_bucket(head)
        else:
            bucket = profile.bucketize(head.decode_tokens, n_buckets)
        p_norm = min(head.prompt_tokens, 2048) / 2048.0
        b_norm = bucket / max(n_buckets - 1, 1)
        wait = np.clip((cluster.t - head.arrival) / 10.0, 0.0, 1.0)
    else:
        p_norm = b_norm = wait = 0.0
    feats.extend([qlen, p_norm, b_norm, wait])
    return np.asarray(feats, np.float32)


def action_mask(cluster: Cluster) -> np.ndarray:
    """[m+1] bool: failed instances masked out; defer always allowed."""
    m = cluster.m
    mask = np.zeros(m + 1, bool)
    for i, inst in enumerate(cluster.instances):
        mask[i] = not inst.failed
    mask[m] = True
    if not cluster.central:          # nothing to route: only defer is valid
        mask[:m] = False
    return mask
