"""MDP state featurization (paper §5.3 State Space + §A.9 bounds).

Per instance (8 dims):
  P_t histogram: resident prompt tokens in 3 buckets (0-256, 256-2048, >2048)
  D_t histogram: resident decoded tokens in the same 3 buckets
  C_t: free capacity fraction
  T_c: estimated earliest completion (clipped, normalized)
Router (4 dims):
  queue length (bounded by 4 x max_batch = 512, as §A.9),
  next request prompt tokens (normalized),
  next request predicted decode bucket,
  head-of-queue waiting time (clipped).

Heterogeneous clusters: every per-instance feature is computed against
that instance's own ``HardwareProfile`` (capacity fraction, earliest
completion, impact score), so mixed-hardware episodes featurize
correctly; the ``profile`` argument is the router-level reference used
only for the head request's decode bucket.

``include_hardware`` appends the instance's calibration constants
(grad1 / grad2 / KV capacity, normalized and clipped to [0, 1]) to each
instance block: with them an agent trained on a MIX of calibrated and
synthetic profiles can condition placement on what the hardware *is*
instead of inferring speed from load dynamics (off by default --
existing checkpoints keep their state shape).

``include_health`` appends the gateway HealthTracker's degradation
score and the instance's slowdown ``1 - 1/speed_factor`` (both in
[0, 1]) so an agent can learn to route around stragglers before the
circuit breaker trips (off by default, same shape-compat reasoning).
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core import impact
from repro.core.profiles import HardwareProfile
from repro.core.simulator import Cluster

BUCKET_EDGES = (256, 2048)          # paper A.9 DQN buckets
N_BUCKETS = len(BUCKET_EDGES) + 1
INSTANCE_DIMS = 2 * N_BUCKETS + 2
ROUTER_DIMS = 4

# per-instance hardware block (optional): grad1 / grad2 / kv-capacity,
# scaled so the paper's V100 and A100 calibrations land mid-range and
# clipped to [0, 1]
HW_DIMS = 3
HW_G1_SCALE = 1e3       # grad1 ~3.2e-4 (V100) -> 0.32
HW_G2_SCALE = 1e4       # grad2 ~3.3e-5 (V100) -> 0.33
HW_CAP_SCALE = 1e-5     # capacity 60k (A100)  -> 0.60

# per-instance prefix-cache block (optional): the head request's
# prospective hit fraction on this instance -- already in [0, 1]
CACHE_DIMS = 1

# per-instance health block (optional): the gateway HealthTracker's
# degradation score (0 = at fleet median, 1 = breaker threshold) and the
# instance's observable slowdown 1 - 1/speed_factor (0 = nominal) --
# both already in [0, 1]
HEALTH_DIMS = 2

_E0, _E1 = BUCKET_EDGES


def instance_dims(include_impact: bool = True,
                  include_hardware: bool = False,
                  include_cache: bool = False,
                  include_health: bool = False) -> int:
    return (INSTANCE_DIMS + (1 if include_impact else 0)
            + (HW_DIMS if include_hardware else 0)
            + (CACHE_DIMS if include_cache else 0)
            + (HEALTH_DIMS if include_health else 0))


def state_dim(m: int, include_impact: bool = True,
              include_hardware: bool = False,
              include_cache: bool = False,
              include_health: bool = False) -> int:
    return instance_dims(include_impact, include_hardware,
                         include_cache, include_health) * m + ROUTER_DIMS


def featurize(cluster: Cluster, profile: HardwareProfile,
              predict_bucket: Optional[Callable] = None,
              n_buckets: int = 8, include_impact: bool = True,
              predict_decode: Optional[Callable] = None,
              alpha: float = 0.5,
              include_hardware: bool = False,
              include_cache: bool = False,
              include_health: bool = False) -> np.ndarray:
    if getattr(cluster, "is_vec", False):
        # vecsim backend: read the packed per-slot arrays directly
        # (bit-identical features, no Python object scans)
        return _featurize_vec(cluster, profile, predict_bucket,
                              n_buckets, include_impact,
                              predict_decode, alpha, include_hardware,
                              include_cache, include_health)
    # Featurization runs once per router decision; it is written as a
    # single pass of scalar Python per instance because numpy call
    # overhead dominates at these sizes (a handful of residents).
    head = cluster.central[0] if cluster.central else None
    dims = instance_dims(include_impact, include_hardware,
                         include_cache, include_health)
    health_scores = (getattr(cluster, "health_scores", None)
                     if include_health else None)
    feats = [0.0] * (dims * cluster.m + ROUTER_DIMS)
    if include_impact and head is not None:
        d_hat = (predict_decode(head) if predict_decode
                 else head.decode_tokens)
    for k, inst in enumerate(cluster.instances):
        if inst.failed:
            continue         # failed instance: all-zero block
        prof = inst.profile
        base = k * dims
        scale = inst.n_slots
        p0 = p1 = p2 = d0 = d1 = d2 = 0
        ctx = 0
        min_left = None
        for r in inst.residents:
            p = r.prompt_tokens
            if p < _E0:
                p0 += 1
            elif p < _E1:
                p1 += 1
            else:
                p2 += 1
            d = r.decoded
            if d < _E0:
                d0 += 1
            elif d < _E1:
                d1 += 1
            else:
                d2 += 1
            ctx += r.prefilled + d
            left = r.decode_tokens - d
            if min_left is None or left < min_left:
                min_left = left
        # queued requests carry zero progress: queue context == prompts
        q_prompt = q_ctx = inst.queued_prompt_sum()
        feats[base] = p0 / scale
        feats[base + 1] = p1 / scale
        feats[base + 2] = p2 / scale
        feats[base + 3] = d0 / scale
        feats[base + 4] = d1 / scale
        feats[base + 5] = d2 / scale
        free = (prof.capacity_tokens - ctx - q_prompt) / prof.capacity_tokens
        feats[base + 6] = -1.0 if free < -1.0 else (1.0 if free > 1.0
                                                    else free)
        t_c = (max(min_left, 0) * prof.t_decode_base / 10.0
               if min_left is not None else 0.0)
        feats[base + 7] = 1.0 if t_c > 1.0 else t_c
        if include_impact and head is not None:
            # the workload impact estimator is a router module (§5.2); its
            # per-instance score for the head request is part of the
            # router's observable state.
            score = impact.r_mixing(prof, head.prompt_tokens, d_hat,
                                    ctx + q_ctx, alpha)
            feats[base + 8] = -5.0 if score < -5.0 else (
                1.0 if score > 1.0 else score)
        if include_hardware:
            hb = base + INSTANCE_DIMS + (1 if include_impact else 0)
            g1 = prof.grad1 * HW_G1_SCALE
            feats[hb] = 1.0 if g1 > 1.0 else g1
            g2 = prof.grad2 * HW_G2_SCALE
            feats[hb + 1] = 1.0 if g2 > 1.0 else g2
            cp = prof.capacity_tokens * HW_CAP_SCALE
            feats[hb + 2] = 1.0 if cp > 1.0 else cp
        if include_cache and head is not None \
                and getattr(head, "prefix_hashes", None):
            # prospective hit fraction of the head request on this
            # instance (read-only query; 0 when the cache model is off)
            pc = getattr(inst, "prefix_cache", None)
            if pc is not None:
                cb = base + INSTANCE_DIMS + (1 if include_impact else 0) \
                    + (HW_DIMS if include_hardware else 0)
                feats[cb] = pc.hit_fraction(head.prompt_tokens,
                                            head.prefix_hashes)
        if include_health:
            hlb = base + INSTANCE_DIMS + (1 if include_impact else 0) \
                + (HW_DIMS if include_hardware else 0) \
                + (CACHE_DIMS if include_cache else 0)
            if health_scores is not None and k < len(health_scores):
                feats[hlb] = float(health_scores[k])
            # slowdown 1 - 1/speed: same expression as the vec path
            feats[hlb + 1] = 1.0 - 1.0 / getattr(inst, "speed_factor",
                                                 1.0)
    feats[dims * cluster.m] = min(len(cluster.central), 512) / 512.0
    if head is not None:
        if head.predicted_bucket is not None:
            bucket = head.predicted_bucket
        elif predict_bucket is not None:
            bucket = predict_bucket(head)
        else:
            bucket = profile.bucketize(head.decode_tokens, n_buckets)
        feats[dims * cluster.m + 1] = min(head.prompt_tokens, 2048) / 2048.0
        feats[dims * cluster.m + 2] = bucket / max(n_buckets - 1, 1)
        wait = (cluster.t - head.arrival) / 10.0
        feats[dims * cluster.m + 3] = 1.0 if wait > 1.0 else (
            0.0 if wait < 0.0 else wait)
    return np.asarray(feats, np.float32)


def _featurize_vec(cluster, profile: HardwareProfile,
                   predict_bucket, n_buckets: int, include_impact: bool,
                   predict_decode, alpha: float,
                   include_hardware: bool = False,
                   include_cache: bool = False,
                   include_health: bool = False) -> np.ndarray:
    """Featurize straight from a VecCluster's packed structure-of-arrays
    state -- the single-cluster view of :func:`featurize_vec_many`."""
    return featurize_vec_many(
        [cluster], [profile], [predict_decode], n_buckets=n_buckets,
        include_impact=include_impact, alpha=alpha,
        predict_buckets=[predict_bucket],
        include_hardware=include_hardware,
        include_cache=include_cache, include_health=include_health)[0]


def featurize_vec_many(clusters, profiles, predict_decodes,
                       n_buckets: int = 8, include_impact: bool = True,
                       alpha: float = 0.5, predict_buckets=None,
                       include_hardware: bool = False,
                       include_cache: bool = False,
                       include_health: bool = False):
    """Featurize MANY VecClusters sharing one pool in a single
    vectorized pass over the concatenated lane set (the batched
    trainer's per-round state build: one set of matrix ops instead of
    one per episode).  Every expression mirrors the scalar path's
    association order on exact-integer values, so the produced float32
    vectors are bit-identical to ``featurize`` on the Python stepper
    (asserted by tests/test_vecsim.py)."""
    pool = clusters[0].pool
    lanes_cat = np.concatenate([c.lane_ids for c in clusters])
    n = lanes_cat.size
    hw = pool._hw
    heads = [c.central[0] if c.central else None for c in clusters]
    dims = instance_dims(include_impact, include_hardware,
                         include_cache, include_health)
    occ = pool.s_state[:, :hw][lanes_cat] != 0
    p = pool.s_prompt[:, :hw][lanes_cat]
    d = pool.s_decoded[:, :hw][lanes_cat]
    ctx = ((pool.s_prefilled[:, :hw][lanes_cat] + d) * occ).sum(1)
    left = (pool.s_dtotal[:, :hw][lanes_cat] - d) + ~occ * (1 << 62)
    min_left = left.min(1) if hw else np.zeros(n, np.int64)
    has_res = occ.any(1) if hw else np.zeros(n, bool)
    lo_p, hi_p = (p < _E0) & occ, (p >= _E1) & occ
    lo_d, hi_d = (d < _E0) & occ, (d >= _E1) & occ
    scale = pool.nslots[lanes_cat]
    block = np.zeros((n, dims))
    block[:, 0] = lo_p.sum(1) / scale
    block[:, 1] = (occ & ~lo_p & ~hi_p).sum(1) / scale
    block[:, 2] = hi_p.sum(1) / scale
    block[:, 3] = lo_d.sum(1) / scale
    block[:, 4] = (occ & ~lo_d & ~hi_d).sum(1) / scale
    block[:, 5] = hi_d.sum(1) / scale
    q_prompt = pool.qps[lanes_cat]
    cap = pool.cap[lanes_cat]
    free = (cap - ctx - q_prompt) / cap
    block[:, 6] = np.minimum(1.0, np.maximum(-1.0, free))
    t_c = np.maximum(min_left, 0) * pool.tdec[lanes_cat] / 10.0
    block[:, 7] = np.where(t_c > 1.0, 1.0, t_c) * has_res
    alive = ~pool.failed[lanes_cat]
    if include_impact:
        p_head, d_head, has_head = _impact_heads(clusters, heads,
                                                 predict_decodes, n)
        score = impact.mixing_vec(
            pool.grad1[lanes_cat], pool.grad2[lanes_cat],
            pool.eps_lat[lanes_cat], p_head, d_head, ctx + q_prompt,
            alpha)
        block[:, 8] = (np.minimum(1.0, np.maximum(-5.0, score))
                       * has_head)
    if include_hardware:
        hb = INSTANCE_DIMS + (1 if include_impact else 0)
        block[:, hb] = np.minimum(pool.grad1[lanes_cat] * HW_G1_SCALE,
                                  1.0)
        block[:, hb + 1] = np.minimum(pool.grad2[lanes_cat]
                                      * HW_G2_SCALE, 1.0)
        block[:, hb + 2] = np.minimum(pool.cap[lanes_cat]
                                      * HW_CAP_SCALE, 1.0)
    if include_cache:
        _fill_cache_col(block, clusters, heads, pool, include_impact,
                        include_hardware)
    if include_health:
        _fill_health_cols(block, clusters, pool, lanes_cat,
                          include_impact, include_hardware,
                          include_cache)
    block *= alive[:, None]
    return _assemble(block, clusters, heads, profiles,
                     predict_buckets, dims, n_buckets)


def _impact_heads(clusters, heads, predict_decodes, n):
    """Per-lane head-of-queue prompt/decode arrays (host: reads Request
    objects) shared by the numpy and jax featurize paths."""
    p_head = np.zeros(n)
    d_head = np.zeros(n)
    has_head = np.zeros(n, bool)
    pos = 0
    for c, head, pd in zip(clusters, heads, predict_decodes):
        if head is not None:
            d_hat = pd(head) if pd else head.decode_tokens
            p_head[pos:pos + c.m] = head.prompt_tokens
            d_head[pos:pos + c.m] = d_hat
            has_head[pos:pos + c.m] = True
        pos += c.m
    return p_head, d_head, has_head


def _fill_cache_col(block, clusters, heads, pool, include_impact,
                    include_hardware):
    # PrefixCache queries are plain dict lookups on the SAME object
    # the stepping code mutates, so this scalar loop produces the
    # exact floats the scalar path does
    cb = (INSTANCE_DIMS + (1 if include_impact else 0)
          + (HW_DIMS if include_hardware else 0))
    pos_c = 0
    for c, head in zip(clusters, heads):
        hashes = (getattr(head, "prefix_hashes", None)
                  if head is not None else None)
        if hashes:
            for j, lane in enumerate(c.lane_ids):
                pc = pool.lane_cache[int(lane)]
                if pc is not None:
                    block[pos_c + j, cb] = pc.hit_fraction(
                        head.prompt_tokens, hashes)
        pos_c += c.m


def _fill_health_cols(block, clusters, pool, lanes_cat, include_impact,
                      include_hardware, include_cache):
    hlb = (INSTANCE_DIMS + (1 if include_impact else 0)
           + (HW_DIMS if include_hardware else 0)
           + (CACHE_DIMS if include_cache else 0))
    pos_h = 0
    for c in clusters:
        hs = getattr(c, "health_scores", None)
        if hs is not None:
            k = min(c.m, len(hs))
            block[pos_h:pos_h + k, hlb] = np.asarray(hs)[:k]
        pos_h += c.m
    # slowdown 1 - 1/speed: elementwise match of the scalar path
    block[:, hlb + 1] = 1.0 - 1.0 / pool.speed[lanes_cat]


def _assemble(block, clusters, heads, profiles, predict_buckets, dims,
              n_buckets):
    """Per-cluster state vectors from the [n, dims] lane block plus the
    4 router dims (host floats; identical on every backend)."""
    out = []
    pos = 0
    if predict_buckets is None:
        predict_buckets = [None] * len(clusters)
    for c, head, prof, pb in zip(clusters, heads, profiles,
                                 predict_buckets):
        m = c.m
        feats = np.zeros(dims * m + ROUTER_DIMS)
        feats[:dims * m] = block[pos:pos + m].ravel()
        pos += m
        feats[dims * m] = min(len(c.central), 512) / 512.0
        if head is not None:
            if head.predicted_bucket is not None:
                bucket = head.predicted_bucket
            elif pb is not None:
                bucket = pb(head)
            else:
                bucket = prof.bucketize(head.decode_tokens, n_buckets)
            feats[dims * m + 1] = min(head.prompt_tokens, 2048) / 2048.0
            feats[dims * m + 2] = bucket / max(n_buckets - 1, 1)
            wait = (c.t - head.arrival) / 10.0
            feats[dims * m + 3] = 1.0 if wait > 1.0 else (
                0.0 if wait < 0.0 else wait)
        out.append(feats.astype(np.float32))
    return out


_JAX_BLOCK = None          # lazily-built jitted block kernel


def _jax_block():
    global _JAX_BLOCK
    if _JAX_BLOCK is not None:
        return _JAX_BLOCK
    import jax
    import jax.numpy as jnp

    from functools import partial

    @partial(jax.jit, static_argnums=(17, 18))
    def block_fn(st, p, d, pf, dtot, qps, cap, nslots, tdec, grad1,
                 grad2, eps_lat, p_head, d_head, has_head, alpha, z,
                 include_impact, include_hardware):
        occ = st != 0
        ctx = ((pf + d) * occ).sum(1)
        left = (dtot - d) + ~occ * (1 << 62)
        min_left = left.min(1)
        has_res = occ.any(1)
        lo_p, hi_p = (p < _E0) & occ, (p >= _E1) & occ
        lo_d, hi_d = (d < _E0) & occ, (d >= _E1) & occ
        cols = [lo_p.sum(1) / nslots,
                (occ & ~lo_p & ~hi_p).sum(1) / nslots,
                hi_p.sum(1) / nslots,
                lo_d.sum(1) / nslots,
                (occ & ~lo_d & ~hi_d).sum(1) / nslots,
                hi_d.sum(1) / nslots]
        free = (cap - ctx - qps) / cap
        cols.append(jnp.minimum(1.0, jnp.maximum(-1.0, free)))
        t_c = jnp.maximum(min_left, 0) * tdec / 10.0
        cols.append(jnp.where(t_c > 1.0, 1.0, t_c) * has_res)
        if include_impact:
            # impact.mixing_vec transliterated; the final blend is the
            # only mul+add chain, so it carries the runtime-zero FMA
            # guard (see core.jaxsim module docs)
            s = ctx + qps
            t_p = grad1 * (p_head ** 2 + s)
            r_p = jnp.where(t_p <= eps_lat, 1.0, 1.0 - t_p / eps_lat)
            r_d = -grad2 * (s + p_head + d_head)
            score = (alpha * r_p + z) + ((1 - alpha) * r_d + z)
            cols.append(jnp.minimum(1.0, jnp.maximum(-5.0, score))
                        * has_head)
        if include_hardware:
            cols.append(jnp.minimum(grad1 * HW_G1_SCALE, 1.0))
            cols.append(jnp.minimum(grad2 * HW_G2_SCALE, 1.0))
            cols.append(jnp.minimum(cap * HW_CAP_SCALE, 1.0))
        return jnp.stack(cols, 1)

    _JAX_BLOCK = block_fn
    return block_fn


def featurize_jax_many(clusters, profiles, predict_decodes,
                       n_buckets: int = 8, include_impact: bool = True,
                       alpha: float = 0.5, predict_buckets=None,
                       include_hardware: bool = False,
                       include_cache: bool = False,
                       include_health: bool = False):
    """Device twin of ``featurize_vec_many``: the per-lane instance
    block (histograms, capacity fraction, earliest completion, impact,
    hardware constants) is computed by one jitted XLA program in
    64-bit mode with the same association order as the numpy path
    (plus the jaxsim runtime-zero FMA guard on the impact blend), so
    the produced float32 vectors are BIT-IDENTICAL to
    ``featurize_vec_many`` (asserted in tests/test_jaxsim.py).  The
    cache and health columns read host Python objects (PrefixCache
    dicts, gateway health trackers) and are filled host-side exactly
    as the numpy path fills them."""
    from jax.experimental import enable_x64
    pool = clusters[0].pool
    lanes_cat = np.concatenate([c.lane_ids for c in clusters])
    n = lanes_cat.size
    hw = pool._hw
    heads = [c.central[0] if c.central else None for c in clusters]
    dims = instance_dims(include_impact, include_hardware,
                         include_cache, include_health)
    block = np.zeros((n, dims))
    if include_impact:
        p_head, d_head, has_head = _impact_heads(clusters, heads,
                                                 predict_decodes, n)
    else:
        p_head = d_head = np.zeros(n)
        has_head = np.zeros(n, bool)
    # hw == 0 (fresh pool, nothing ever resident): one all-empty dummy
    # slot column keeps shapes non-degenerate and produces the same
    # values as numpy's empty-axis special case (occ is all-False, so
    # histograms are 0 and T_c is masked by has_res)
    w = max(hw, 1)
    with enable_x64():
        core = _jax_block()(
            pool.s_state[:, :w][lanes_cat].astype(np.int64),
            pool.s_prompt[:, :w][lanes_cat],
            pool.s_decoded[:, :w][lanes_cat],
            pool.s_prefilled[:, :w][lanes_cat],
            pool.s_dtotal[:, :w][lanes_cat],
            pool.qps[lanes_cat], pool.cap[lanes_cat],
            pool.nslots[lanes_cat], pool.tdec[lanes_cat],
            pool.grad1[lanes_cat], pool.grad2[lanes_cat],
            pool.eps_lat[lanes_cat], p_head, d_head, has_head,
            np.float64(alpha), np.float64(0.0),
            include_impact, include_hardware)
    ncore = (INSTANCE_DIMS + (1 if include_impact else 0)
             + (HW_DIMS if include_hardware else 0))
    block[:, :ncore] = np.asarray(core)
    if include_cache:
        _fill_cache_col(block, clusters, heads, pool, include_impact,
                        include_hardware)
    if include_health:
        _fill_health_cols(block, clusters, pool, lanes_cat,
                          include_impact, include_hardware,
                          include_cache)
    block *= ~pool.failed[lanes_cat][:, None]
    return _assemble(block, clusters, heads, profiles,
                     predict_buckets, dims, n_buckets)


def pad_state(s: np.ndarray, m: int, m_max: int,
              include_impact: bool = True,
              include_hardware: bool = False,
              include_cache: bool = False,
              include_health: bool = False) -> np.ndarray:
    """Pad an m-instance state vector to m_max instance slots (zeros --
    the same encoding as a failed instance) so episodes with different
    cluster shapes share one replay buffer / Q network."""
    if m == m_max:
        return s
    dims = instance_dims(include_impact, include_hardware,
                         include_cache, include_health)
    out = np.zeros(dims * m_max + ROUTER_DIMS, np.float32)
    out[:dims * m] = s[:dims * m]
    out[dims * m_max:] = s[dims * m:]
    return out


def action_mask(cluster: Cluster) -> np.ndarray:
    """[m+1] bool: failed instances masked out; defer always allowed.

    When a gateway stamps a circuit-breaker ``health_mask`` on the
    cluster (serving.chaos.HealthTracker), breakered instances are
    masked out too -- the tracker's guarded fallback ensures the mask
    never excludes the entire alive fleet."""
    m = cluster.m
    mask = np.zeros(m + 1, bool)
    if getattr(cluster, "is_vec", False):
        if cluster.central:
            mask[:m] = ~cluster.pool.failed[cluster.lane_ids]
            _apply_health_mask(cluster, mask, m)
        mask[m] = True
        return mask
    for i, inst in enumerate(cluster.instances):
        mask[i] = not inst.failed
    _apply_health_mask(cluster, mask, m)
    mask[m] = True
    if not cluster.central:          # nothing to route: only defer is valid
        mask[:m] = False
    return mask


def _apply_health_mask(cluster, mask: np.ndarray, m: int):
    hm = getattr(cluster, "health_mask", None)
    if hm is not None:
        k = min(m, len(hm))
        mask[:k] &= np.asarray(hm[:k], bool)


def pad_mask(mask: np.ndarray, m: int, m_max: int) -> np.ndarray:
    """Pad an [m+1] action mask to [m_max+1]: padded instance slots are
    invalid; defer moves to the last position."""
    if m == m_max:
        return mask
    out = np.zeros(m_max + 1, bool)
    out[:m] = mask[:m]
    out[m_max] = mask[m]
    return out
