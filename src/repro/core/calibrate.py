"""Engine-calibrated hardware profiles (closing the paper's Fig. 4 loop).

The routing stack costs everything -- iteration times, impact scores,
backlog penalties -- with ``HardwareProfile`` constants that the paper
*measures* on real hardware (Fig. 4) but this repo has so far hand-typed.
This module fits them from the real jax engine: it sweeps the same jitted
prefill / gang-decode functions ``serving.engine.LLMInstance`` runs, over
a batch x prompt x resident-context grid, wall-clocks each grid point
(best-of-k, so scheduler noise cannot inflate a sample), and recovers the
profile by the paper's least-squares line fits:

  prefill:  t(p)    = t_prefill_base + grad1 * p        (batch-1 prompt
            of p tokens -- Fig. 4a's "prompt time vs prompt tokens")
  decode:   t(B, c) = t_decode_base + grad2 * (B * c)   (gang decode over
            B resident slots at context c; resident tokens R = B * c --
            Fig. 4b's "decode time vs co-resident context")

Fit diagnostics (R^2, max-residual band) come back with the profile so a
calibration that did NOT behave linearly is visible instead of silently
mispricing the router; ``CalibrationResult.save`` / ``load_profile``
round-trip the fitted profile through JSON so calibrated profiles are
committable artifacts (CI's calibration-smoke job uploads one).

Entry points:
  * ``calibrate_profile(cfg, params) -> HardwareProfile`` -- sweep + fit
    on a reduced config (CPU-sized; pallas-interpret kernels are fine);
  * ``calibrate(cfg, params) -> CalibrationResult`` -- same, with fits
    and raw samples attached;
  * ``fit_calibration(prefill_samples, decode_samples)`` -- the pure fit
    (tests drive it with synthetic ground-truth timings);
  * ``launch.serve --calibrate --profile-json out.json`` -- the CLI.
"""
from __future__ import annotations

import dataclasses
import json
import time
from dataclasses import dataclass, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import (HardwareProfile, V100_LLAMA2_7B,
                                 profile_from_json, profile_to_json)


@dataclass(frozen=True)
class CalibrationConfig:
    """Measurement grid + timing discipline for one calibration run.

    The defaults are tuned for clean linear fits on a CPU smoke box
    (R^2 >= 0.95 with margin): grid points small enough to avoid
    cache-thrash superlinearity at the top, large enough that per-token
    compute dominates dispatch jitter at the bottom, and several decode
    steps chained per timed call so fixed dispatch overhead lands in
    the intercept instead of the noise.  Run the sweep with XLA pinned
    to one thread (``XLA_FLAGS="--xla_cpu_multi_thread_eigen=false
    intra_op_parallelism_threads=1"``, the repo's bench convention) --
    multi-threaded CPU XLA changes parallelization strategy with size,
    which shows up as piecewise-linear steps in the measurements."""
    # batch-1 prompt lengths for the prefill sweep (Fig. 4a x-axis);
    # starts at 32: below that the fixed dispatch floor flattens the
    # curve and only adds leverage-free noise to the fit
    prompt_grid: Tuple[int, ...] = (32, 64, 96, 128, 192, 256)
    # (batch, per-slot context) points for the decode sweep; the fit's
    # x-axis is resident tokens R = batch * context (Fig. 4b)
    decode_grid: Tuple[Tuple[int, int], ...] = (
        (1, 64), (2, 128), (2, 256), (4, 256), (4, 512), (8, 512))
    # gang-decode steps chained inside ONE jitted call (time / steps is
    # the per-iteration sample); each step consumes the previous one's
    # argmax token, the same dependency chain the engine runs
    decode_steps_per_call: int = 8
    repeats: int = 9              # timed reps per grid point (min taken)
    warmup: int = 2               # discarded compile/warm calls per point
    prefill_cache_len: int = 256  # decode-cache length prefill builds
    seed: int = 0


@dataclass(frozen=True)
class LinearFit:
    """One least-squares line y = slope * x + intercept, with quality."""
    slope: float
    intercept: float
    r2: float                 # coefficient of determination
    residual_band: float      # max |y - fit(x)| over the samples (s)
    n: int

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def linear_fit(samples: Sequence[Tuple[float, float]]) -> LinearFit:
    """Least-squares line over (x, seconds) samples (Fig. 4 procedure)."""
    if len(samples) < 2:
        raise ValueError("linear_fit needs >= 2 samples")
    x = np.array([s[0] for s in samples], float)
    y = np.array([s[1] for s in samples], float)
    a = np.vstack([x, np.ones_like(x)]).T
    (m, c), *_ = np.linalg.lstsq(a, y, rcond=None)
    pred = m * x + c
    ss_res = float(((y - pred) ** 2).sum())
    ss_tot = float(((y - y.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else (
        1.0 if ss_res == 0.0 else 0.0)
    return LinearFit(slope=float(m), intercept=float(c), r2=float(r2),
                     residual_band=float(np.abs(y - pred).max()),
                     n=len(samples))


@dataclass
class CalibrationResult:
    """A fitted profile plus everything needed to audit the fit."""
    profile: HardwareProfile
    prefill_fit: LinearFit
    decode_fit: LinearFit
    prefill_samples: List[Tuple[float, float]]
    decode_samples: List[Tuple[float, float]]

    @property
    def ok(self) -> bool:
        """The shape every sane calibration must have: both fits tight
        and the per-prefill-token cost strictly above the per-resident-
        token decode interference (a full forward vs a KV read)."""
        return (self.profile.grad1 > self.profile.grad2 > 0.0
                and self.profile.t_decode_base > 0.0)

    def to_json(self) -> dict:
        return {
            "profile": profile_to_json(self.profile),
            "prefill_fit": self.prefill_fit.to_json(),
            "decode_fit": self.decode_fit.to_json(),
            "prefill_samples": [list(s) for s in self.prefill_samples],
            "decode_samples": [list(s) for s in self.decode_samples],
        }

    def save(self, path: str):
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)

    @classmethod
    def from_json(cls, d: dict) -> "CalibrationResult":
        return cls(
            profile=profile_from_json(d["profile"]),
            prefill_fit=LinearFit(**d["prefill_fit"]),
            decode_fit=LinearFit(**d["decode_fit"]),
            prefill_samples=[tuple(s) for s in d["prefill_samples"]],
            decode_samples=[tuple(s) for s in d["decode_samples"]])


def load_profile(path: str) -> HardwareProfile:
    """Read a profile from JSON -- either a bare ``profile_to_json``
    dict or a full ``CalibrationResult.save`` artifact."""
    with open(path) as f:
        d = json.load(f)
    return profile_from_json(d.get("profile", d))


def fit_calibration(prefill_samples: Sequence[Tuple[float, float]],
                    decode_samples: Sequence[Tuple[float, float]],
                    base: HardwareProfile = V100_LLAMA2_7B,
                    name: str = "calibrated") -> CalibrationResult:
    """Pure fit: (tokens, seconds) measurements -> calibrated profile.

    Thresholds (capacity, heavy/light cut-offs, epsilon) are inherited
    from ``base`` -- they are capacity/policy constants, not timings."""
    pf = linear_fit(prefill_samples)
    df = linear_fit(decode_samples)
    profile = replace(
        base, name=name,
        grad1=max(pf.slope, 1e-9),
        grad2=max(df.slope, 1e-12),
        t_decode_base=max(df.intercept, 1e-6),
        t_prefill_base=max(pf.intercept, 0.0))
    return CalibrationResult(profile=profile, prefill_fit=pf,
                             decode_fit=df,
                             prefill_samples=list(prefill_samples),
                             decode_samples=list(decode_samples))


# -- the engine sweep --------------------------------------------------------

def _timed_grid(points, repeats: int, warmup: int) -> List[float]:
    """Wall-clock a grid of jitted calls, min-of-``repeats`` each.

    ``points`` is a list of ``(fn, args)``.  All points are warmed
    first (compiles discarded), then the timed repetitions are
    INTERLEAVED round-robin across the grid: a transient load spike on
    a busy host poisons at most one sample per point instead of every
    sample of whichever point it landed on, so the per-point min stays
    a faithful estimate of the undisturbed run time."""
    import jax
    for fn, args in points:
        for _ in range(warmup):
            jax.block_until_ready(fn(*args))
    best = [float("inf")] * len(points)
    for _ in range(repeats):
        for i, (fn, args) in enumerate(points):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def sweep_prefill(cfg, params, ccfg: CalibrationConfig
                  ) -> List[Tuple[float, float]]:
    """(prompt tokens, seconds) over the batch-1 prompt grid.  One XLA
    executable per distinct prompt length (the same retrace the engine
    itself pays per prompt shape)."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_lib
    cache_len = max(ccfg.prefill_cache_len, max(ccfg.prompt_grid))
    prefill_j = jax.jit(lambda pr, t: model_lib.prefill(
        pr, cfg, tokens=t, cache_len=cache_len))
    rng = np.random.default_rng(ccfg.seed)
    points = []
    for p in ccfg.prompt_grid:
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, p)),
                           jnp.int32)
        points.append((prefill_j, (params, toks)))
    times = _timed_grid(points, ccfg.repeats, ccfg.warmup)
    return [(float(p), t) for p, t in zip(ccfg.prompt_grid, times)]


def sweep_decode(cfg, params, ccfg: CalibrationConfig
                 ) -> List[Tuple[float, float]]:
    """(resident tokens, seconds-per-step) over the (batch, context)
    decode grid: ``decode_steps_per_call`` chained gang-decode steps per
    timed call on a cache holding batch x context resident tokens."""
    import jax
    import jax.numpy as jnp
    from repro.models import model as model_lib
    k = max(ccfg.decode_steps_per_call, 1)

    def multi_decode(pr, cache, toks):
        for _ in range(k):
            logits, cache = model_lib.decode_step(pr, cfg, cache,
                                                  tokens=toks)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, cache

    decode_j = jax.jit(multi_decode)
    rng = np.random.default_rng(ccfg.seed + 1)
    points = []
    for batch, ctx in ccfg.decode_grid:
        cache = model_lib.init_cache(cfg, batch, ctx)
        # a realistically-full cache: pos at the last written slot
        cache["pos"] = jnp.full((batch,), ctx - 1, jnp.int32)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch,)),
                           jnp.int32)
        points.append((decode_j, (params, cache, toks)))
    times = _timed_grid(points, ccfg.repeats, ccfg.warmup)
    return [(float(b * c), t / k)
            for (b, c), t in zip(ccfg.decode_grid, times)]


def calibrate(cfg, params, ccfg: Optional[CalibrationConfig] = None,
              base: HardwareProfile = V100_LLAMA2_7B,
              name: Optional[str] = None) -> CalibrationResult:
    """Sweep the real engine functions for ``cfg``/``params`` and fit a
    profile.  ``cfg`` should be a reduced (CPU-sized) ModelConfig for
    smoke use; on an accelerator the full config works unchanged."""
    ccfg = ccfg or CalibrationConfig()
    return fit_calibration(
        sweep_prefill(cfg, params, ccfg),
        sweep_decode(cfg, params, ccfg),
        base=base, name=name or f"{cfg.name}-calibrated")


def calibrate_profile(cfg, params,
                      ccfg: Optional[CalibrationConfig] = None,
                      base: HardwareProfile = V100_LLAMA2_7B,
                      name: Optional[str] = None) -> HardwareProfile:
    """The headline entry point: measured engine -> HardwareProfile."""
    return calibrate(cfg, params, ccfg, base=base, name=name).profile


def format_result(res: CalibrationResult) -> str:
    """Human-readable fit report (the --calibrate CLI prints this)."""
    p = res.profile
    lines = [
        f"calibrated profile '{p.name}':",
        f"  grad1          = {p.grad1:.3e} s/prompt-token "
        f"(R^2={res.prefill_fit.r2:.4f}, "
        f"band={res.prefill_fit.residual_band * 1e6:.1f}us, "
        f"n={res.prefill_fit.n})",
        f"  grad2          = {p.grad2:.3e} s/resident-token "
        f"(R^2={res.decode_fit.r2:.4f}, "
        f"band={res.decode_fit.residual_band * 1e6:.1f}us, "
        f"n={res.decode_fit.n})",
        f"  t_decode_base  = {p.t_decode_base:.3e} s",
        f"  t_prefill_base = {p.t_prefill_base:.3e} s",
        f"  sanity (grad1 > grad2 > 0): {'OK' if res.ok else 'FAILED'}",
    ]
    return "\n".join(lines)
