"""Fault-tolerant serving cluster manager.

Glues the intelligent router to a cluster (simulated or real engines):
  * heartbeat-based failure detection -> orphaned requests are re-queued
    at the router (idempotent ids, progress reset) and the dead instance is
    masked out of the action space;
  * elastic scale-out/in: instances can be added/removed at runtime.  With
    the decomposed Q network the SAME router weights score any instance
    count (the paper's fixed-m MLP requires retraining -- §A.11 had to
    grow the network for 8 instances);
  * router-state checkpointing (DQN params + replay buffer head) through
    repro.training.checkpoint for restart;
  * straggler mitigation: per-instance EWMA of observed iteration time
    feeds a slowdown factor into the capacity feature, so the router
    steers work away from degraded instances.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import rl_router, state as state_lib
from repro.core.dqn import DQNAgent
from repro.core.profiles import HardwareProfile
from repro.serving.request import Request, summarize
from repro.training.checkpoint import CheckpointManager


@dataclass
class ManagedClusterConfig:
    n_instances: int = 4
    heartbeat_timeout: float = 1.0      # simulated-seconds between beats
    straggler_ewma: float = 0.2
    straggler_threshold: float = 2.0    # x median iteration time
    checkpoint_dir: Optional[str] = None


class ManagedCluster:
    def __init__(self, cfg: ManagedClusterConfig,
                 router_cfg: rl_router.RouterConfig,
                 profile: HardwareProfile, agent: DQNAgent):
        self.cfg = cfg
        self.router_cfg = router_cfg
        self.profile = profile
        self.agent = agent
        self.env = rl_router.RoutingEnv(router_cfg, profile)
        self.ckpt = (CheckpointManager(cfg.checkpoint_dir)
                     if cfg.checkpoint_dir else None)
        self.events: List[str] = []

    # -- failure / elasticity hooks -----------------------------------------
    def fail_instance(self, idx: int):
        self.env.cluster.fail_instance(idx)
        self.events.append(f"t={self.env.cluster.t:.2f} FAIL instance {idx}")

    def restore_instance(self, idx: int):
        inst = self.env.cluster.instances[idx]
        inst.restore()
        inst.clock = self.env.cluster.t
        self.events.append(f"t={self.env.cluster.t:.2f} RESTORE {idx}")

    def add_instance(self) -> int:
        i = self.env.cluster.add_instance(self.router_cfg.scheduler,
                                          self.router_cfg.chunked_prefill)
        self.events.append(f"t={self.env.cluster.t:.2f} ADD instance {i}")
        return i

    # -- checkpoint / restart ----------------------------------------------
    def save_router(self, step: int):
        if self.ckpt:
            self.ckpt.save(step, self.agent.state_dict(), sync=True)

    def restore_router(self) -> bool:
        if not self.ckpt:
            return False
        out = self.ckpt.restore(self.agent.state_dict())
        if out is None:
            return False
        self.agent.load_state_dict(out[0])
        return True

    # -- serving loop ------------------------------------------------------
    def serve(self, requests: Sequence[Request],
              fault_plan: Optional[Dict[float, str]] = None) -> Dict:
        """Run an episode; fault_plan maps sim-time -> event string
        ("fail:<i>" | "restore:<i>" | "add")."""
        fault_plan = dict(fault_plan or {})
        env = self.env
        s = env.reset(requests)
        cfg = self.router_cfg
        w_sel = cfg.guidance_floor if cfg.variant == "guided" else 0.0
        done = False
        while not done:
            for t_evt in sorted(list(fault_plan)):
                if env.cluster.t >= t_evt:
                    evt = fault_plan.pop(t_evt)
                    kind, _, arg = evt.partition(":")
                    if kind == "fail":
                        self.fail_instance(int(arg))
                    elif kind == "restore":
                        self.restore_instance(int(arg))
                    elif kind == "add":
                        self.add_instance()
            mask = state_lib.action_mask(env.cluster)
            prior = w_sel * env.guidance_bonus() if w_sel else None
            if (self.agent.cfg.q_arch == "decomposed"
                    or env.cluster.m + 1 == self.agent.cfg.n_actions):
                s = env._state()
                a = self.agent.act(s, mask, epsilon=0.0, prior=prior,
                                   q_squash=cfg.q_squash if w_sel else 0.0)
            else:
                # fixed-m MLP cannot score a resized cluster: fall back to
                # the guidance heuristic
                bonus = env.guidance_bonus()
                bonus[~mask] = -np.inf
                a = int(np.argmax(bonus))
            _, _, done, _ = env.step(a)
        stats = summarize(requests)
        stats["events"] = list(self.events)
        stats["preemptions"] = sum(r.preemptions for r in requests)
        return stats
