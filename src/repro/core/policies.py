"""Heuristic routing baselines (paper §A.1-A.2).

Every policy implements ``act(cluster) -> Optional[int]``: an instance index
for the head-of-queue request, ``m`` (or None) to defer.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core import impact
from repro.core.simulator import Cluster


def _head(cluster: Cluster):
    return cluster.central[0]


class RoundRobin:
    """Alternate over alive instances (paper's primary baseline)."""
    name = "round_robin"

    def __init__(self):
        self._next = 0

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        idx = alive[self._next % len(alive)]
        self._next += 1
        return idx


class JoinShortestQueue:
    """Least unprocessed prompt+decode tokens (§A.2.1)."""
    name = "jsq"

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        loads = [cluster.instances[i].outstanding_tokens() for i in alive]
        return alive[int(np.argmin(loads))]


class DecodeBalancer:
    """Balance the sum of (oracle) decode tokens per instance (§A.1.6)."""
    name = "decode_balancer"

    def __init__(self):
        self.assigned: dict = {}

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        req = _head(cluster)
        loads = []
        for i in alive:
            inst = cluster.instances[i]
            live = sum(max(r.decode_tokens - r.decoded, 0)
                       for r in inst.residents) + \
                sum(r.decode_tokens for r in inst.queue)
            loads.append(live)
        pick = alive[int(np.argmin(loads))]
        return pick


class DedicatedSmallLarge:
    """Half the instances take heavy-decode requests, half take light
    (§A.1.4) -- the paper's example of a severely sub-optimal router."""
    name = "dedicated"

    def __init__(self, profile):
        self.profile = profile
        self._rr = [0, 0]

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        req = _head(cluster)
        heavy = self.profile.decode_is_heavy(req.decode_tokens)
        half = max(len(alive) // 2, 1)
        group = alive[:half] if heavy else alive[half:] or alive[:half]
        g = 0 if heavy else 1
        idx = group[self._rr[g] % len(group)]
        self._rr[g] += 1
        return idx


class MaxCapacityUsage:
    """Route to the instance with most free capacity if it fits (§A.2.2)."""
    name = "max_capacity"

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        req = _head(cluster)
        frees = [cluster.instances[i].free_tokens() for i in alive]
        best = int(np.argmax(frees))
        if frees[best] < req.prompt_tokens + req.decode_tokens:
            return len(cluster.instances)          # defer
        return alive[best]


class MinMin:
    """Classical min-min (§A.2.3): pick the instance minimizing the
    estimated finish time of the head request (≈ SJF on homogeneous
    instances).  Uses the upper bound of the predicted decode bucket when a
    prediction is attached, else the oracle decode length."""
    name = "min_min"

    def __init__(self, profile):
        self.profile = profile

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        req = _head(cluster)
        d_est = req.decode_tokens
        size = req.prompt_tokens + d_est
        finish = []
        for i in alive:
            inst = cluster.instances[i]
            # start immediately if it fits; else wait for the earliest
            # completion.  Light tie-break on outstanding work.
            fits = (inst.free_tokens() >= size
                    and len(inst.residents) < inst.n_slots)
            wait = 0.0 if fits else inst.earliest_completion()
            finish.append(wait + self.profile.request_time(
                req.prompt_tokens, d_est)
                + 1e-6 * inst.outstanding_tokens())
        return alive[int(np.argmin(finish))]


class ImpactGreedy:
    """Pure workload-impact heuristic: route to argmax r_mixing (Eq. 1-2).
    This is the 'lightweight heuristic' the RL variants are guided by."""
    name = "impact_greedy"

    def __init__(self, profile, alpha: float = 0.5):
        self.profile = profile
        self.alpha = alpha

    def act(self, cluster: Cluster) -> Optional[int]:
        alive = cluster.alive()
        if not alive:
            return None
        req = _head(cluster)
        sums = [cluster.instances[i].resident_token_sum() +
                sum(r.prompt_tokens + r.decode_tokens
                    for r in cluster.instances[i].queue)
                for i in alive]
        scores = impact.mixing_per_instance(
            self.profile, req.prompt_tokens, req.decode_tokens, sums,
            self.alpha)
        return alive[int(np.argmax(scores))]


def make_policy(name: str, profile):
    if name == "round_robin":
        return RoundRobin()
    if name == "jsq":
        return JoinShortestQueue()
    if name == "decode_balancer":
        return DecodeBalancer()
    if name == "dedicated":
        return DedicatedSmallLarge(profile)
    if name == "max_capacity":
        return MaxCapacityUsage()
    if name == "min_min":
        return MinMin(profile)
    if name == "impact_greedy":
        return ImpactGreedy(profile)
    raise KeyError(name)
