"""The intelligent router: heuristic-guided RL (paper §5.3, §6).

Three variants (paper §6 Setup):
  baseline  -- reward = backlog penalty + completion reward (terms 1+2 of
               Eq. 3)
  aware     -- baseline + r_mixing(chosen) added directly to the reward
               ("workload-augmented", fixed weight 1)
  guided    -- heuristic-guided (Cheng et al. 2021): reward +=
               w_k * h(s_t, a) with h = r_mixing(chosen) - max_l r_mixing(l)
               <= 0, w_k = gamma * exp(-beta_d * k) decaying per episode;
               the training discount is gamma_k = gamma - w_k (short horizon
               + strong guidance early; original MDP recovered as k grows).

Sign note: Eq. 3 prints "- (gamma - gamma_k) h"; with the paper's h <= 0
that would *reward* bad placements, contradicting §5.3's own description
("h returns zero when the request is assigned to the model with the least
workload mixing impact" -- i.e. zero is the best case).  We implement the
evidently intended penalty  + w_k * h.
"""
from __future__ import annotations

import warnings
from collections import deque
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Sequence

import numpy as np

from repro.core import impact, prefix_cache, state as state_lib
from repro.core.dqn import DQNAgent, DQNConfig
from repro.core.profiles import HardwareProfile
from repro.core.simulator import Cluster
from repro.serving.request import Request, summarize


@dataclass
class RouterConfig:
    variant: str = "guided"          # baseline | aware | guided
    n_instances: int = 4
    dt: float = 0.02
    gamma: float = 0.997             # ~7 s credit horizon at dt=0.02
    r_w: float = 60.0                # completion reward (§A.9.3)
    alpha: float = 0.5               # Eq.(1)/(2) balance (§6 Setup)
    beta_d: float = 0.5              # guidance decay (§6 Setup)
    scheduler: str = "fcfs"
    chunked_prefill: int = 0
    n_buckets: int = 8
    actions_per_tick: int = 1
    learn_every: int = 4
    eps_start: float = 1.0
    eps_end: float = 0.05
    explore_episodes: int = 20       # §A.9.2: no exploration after ep 20
    n_slots: Optional[int] = None
    max_time: float = 36_000.0
    hidden: tuple = (64, 64)
    lr: float = 3e-4
    include_impact_features: bool = True
    # per-instance hardware block (grad1/grad2/kv-capacity) in the state
    # (PR-1 follow-up): lets one agent trained across calibrated +
    # synthetic profiles condition on the hardware itself instead of
    # inferring speed from load dynamics.  Off by default: existing
    # checkpoints keep their state shape.
    include_hardware_features: bool = False
    # prefix-cache model (core.prefix_cache): per-instance KV budget
    # for cached prompt prefixes (0 disables the cache model).  With
    # ``include_cache_features`` the head request's prospective
    # per-instance hit fraction joins the state (CACHE_DIMS extra dims
    # per instance -- existing checkpoints keep their shape while it is
    # off); ``cache_weight`` adds the same affinity signal directly to
    # mixing_scores so the guided variant's heuristic prior is
    # cache-aware too.
    include_cache_features: bool = False
    prefix_cache_tokens: int = 0
    prefix_block: int = 32
    cache_weight: float = 0.0
    # gateway health block (serving.chaos.HealthTracker): per-instance
    # degradation score + straggler slowdown join the state so the agent
    # can route around degraded nodes before the breaker trips.  Off by
    # default: existing checkpoints keep their state shape.
    include_health_features: bool = False
    reward_scale: float = 300.0
    q_squash: float = 0.05       # bound on Q's selection influence (guided)
    q_arch: str = "mlp"              # "mlp" (paper) | "decomposed" (ours)
    # SLA safety valve: if the head request has waited this long at the
    # router, a defer action is overridden with the best-impact placement
    # (a production watchdog; also bounds episode length against
    # defer-forever policies).  Each rescue costs sla_penalty so the agent
    # cannot lean on the watchdog.
    defer_timeout: float = 5.0
    sla_penalty: float = 10.0
    # NOTE: potential-based shaping was tried and REFUTED here: with every
    # episode completing all requests, the telescoped backlog sum is
    # policy-independent and the learning signal vanished (see
    # EXPERIMENTS.md §Perf lessons).  The raw Eq.(3) backlog integral is
    # the latency signal; reward centering handles its magnitude.
    potential_shaping: bool = False
    r_w_shaped: float = 1.0          # completion bonus under shaping
    # decision-time guidance floor: actions are selected from
    # Q(s,a) + floor * r_mixing-advantage(a).  The paper anneals guidance
    # to exactly zero; we found (see EXPERIMENTS.md, refuted-hypothesis
    # log) that with a pure annealed DQN the argmax is dominated by Q
    # noise and collapses to defer-everything / one-instance policies.
    # A strong persistent prior keeps the workload heuristic in charge
    # where Q differences are small and lets the learned values override
    # it where they are confident -- worst case is impact-greedy parity.
    guidance_floor: float = 1.0
    defer_prior_bias: float = -0.05  # slight routing preference in the prior
    # n-step truncated-return targets (no bootstrapping): Q regresses the
    # discounted return over the next `nstep` decisions.  Bootstrapped
    # 1-step DQN (nstep=0, the paper's setup) proved unstable on this MDP
    # (tiny action advantages under a huge action-independent backlog
    # term); truncated Monte-Carlo targets are plain supervised regression
    # and capture placement effects, which materialize within seconds.
    nstep: int = 80
    nstep_gamma: float = 0.97
    seed: int = 0


#: mixing-score penalty for an instance the gateway's circuit breaker
#: has opened on -- large enough to lose every argmax against a healthy
#: candidate, finite so a fully-breakered fleet still routes somewhere
HEALTH_PENALTY = 0.75


def mixing_scores(cluster, req: Request, d_hat: int,
                  alpha: float = 0.5,
                  cache_weight: float = 0.0) -> np.ndarray:
    """Per-instance r_mixing for routing ``req`` onto ``cluster`` now
    (each instance judged by its own profile; failed instances -inf).
    Shared by the RL env, the cluster manager, and the gateway's
    policy layer -- one implementation of the paper's Eq. 1-2 scoring.
    ``cache_weight`` adds the request's prospective prefix-cache hit
    fraction per instance (core.prefix_cache), making the heuristic
    cache-affine; the fractions come from the same shared scalar query
    on both backends, so scores stay bit-identical py-vs-vec."""
    if getattr(cluster, "is_vec", False):
        # vecsim backend: Eq. 1-2 evaluated in one vector pass over the
        # packed lane arrays (bit-identical to the scalar loop)
        pool, lanes = cluster.pool, cluster.lane_ids
        scores = impact.mixing_vec(
            pool.grad1[lanes], pool.grad2[lanes], pool.eps_lat[lanes],
            float(req.prompt_tokens), d_hat,
            pool.rts[lanes] + pool.qps[lanes], alpha)
        scores[pool.failed[lanes]] = -np.inf
    else:
        sums = [inst.resident_token_sum() + inst.queued_prompt_sum()
                for inst in cluster.instances]
        scores = impact.mixing_heterogeneous(
            [inst.profile for inst in cluster.instances],
            req.prompt_tokens, d_hat, sums, alpha)
        for i, inst in enumerate(cluster.instances):
            if inst.failed:
                scores[i] = -np.inf
    if cache_weight:
        # failed lanes stay -inf (-inf + finite == -inf)
        scores = scores + cache_weight * np.asarray(
            prefix_cache.hit_fractions(cluster, req))
    hm = getattr(cluster, "health_mask", None)
    if hm is not None:
        # breakered-but-alive instances get a finite penalty (identical
        # np ops on both backends, so scores stay bit-exact py-vs-vec)
        k = min(cluster.m, len(hm))
        scores[:k] = scores[:k] + np.where(
            np.asarray(hm[:k], bool), 0.0, -HEALTH_PENALTY)
    return scores


def guidance_from_scores(cluster, req: Request, d_hat: int,
                         scores: np.ndarray,
                         defer_prior_bias: float = -0.05) -> np.ndarray:
    """Per-action r_mixing advantage for ``req`` given its per-instance
    ``scores`` (route_i: scores_i - max; defer: min - max), with the
    capacity-fit correction of §5.3 goal (c): placements that would
    overflow the KV budget are penalized, and if nothing fits the defer
    action is encouraged instead."""
    out = np.zeros(cluster.m + 1, np.float32)
    need = req.prompt_tokens + d_hat
    if getattr(cluster, "is_vec", False):
        pool, lanes = cluster.pool, cluster.lane_ids
        fits = ((pool.cap[lanes] - pool.rts[lanes] - pool.qps[lanes]
                 >= need) & ~pool.failed[lanes])
    else:
        fits = np.array([inst.free_tokens() >= need and not inst.failed
                         for inst in cluster.instances])
    scores = scores + np.where(fits, 0.0, -0.3)
    finite = scores[np.isfinite(scores)]
    top = finite.max() if finite.size else 0.0
    out[:cluster.m] = np.where(np.isfinite(scores), scores - top, -1e9)
    defer_bias = 0.2 - top if not fits.any() else defer_prior_bias
    out[cluster.m] = ((finite.min() - top) if finite.size > 1
                      else 0.0) + defer_bias
    return out


class BacklogTracker:
    """Incremental Eq.(3) backlog-penalty accumulators over one cluster.

    The penalty is ``-sum_unfinished (1 - frac_r) / t_hat_r`` with
    d-hat/t-hat fixed per request; instead of rescanning every arrived
    request every 0.02 s tick (which dominated episode wall time), we
    maintain S = sum 1/t_hat and T = sum frac/t_hat via arrival /
    decode / preempt / finish events and read ``penalty() = T - S`` in
    O(1).  On the Python stepper the decode/preempt events come from
    SimInstance hooks (installed here); the vec backend maintains the
    same accumulators inside its fused round loop
    (``pool.set_backlog_terms``).  Shared by RoutingEnv and the online
    gateway trainer (training.online) so both compute identical
    reward streams over identical event streams."""

    def __init__(self, cluster, profile, predict_decode):
        self.cluster = cluster
        self.profile = profile
        self.predict_decode = predict_decode
        self.vec = getattr(cluster, "is_vec", False)
        self.S = 0.0
        self.T = 0.0
        self.inv: Dict[int, tuple] = {}      # rid -> (1/d_hat, 1/t_hat)
        if not self.vec:
            for inst in cluster.instances:
                inst.on_token = self.on_token
                inst.on_preempt = self.on_preempt

    def register(self, r) -> None:
        """Account one request that entered the router queue."""
        d_hat = max(self.predict_decode(r), 1)
        inv_t = 1.0 / max(
            self.profile.request_time(r.prompt_tokens, d_hat), 1e-3)
        if self.vec:
            self.cluster.pool.set_backlog_terms(
                self.cluster.gid_of(r), self.cluster.ep, d_hat, inv_t)
        else:
            self.inv[r.rid] = (1.0 / d_hat, inv_t)
            self.S += inv_t

    def on_token(self, r):
        iv = self.inv.get(r.rid)
        if iv is None:
            return
        f0 = (r.decoded - 1) * iv[0]
        if f0 >= 1.0:                 # progress already capped at 1
            return
        self.T += (min(r.decoded * iv[0], 1.0) - f0) * iv[1]

    def on_preempt(self, r):
        # called BEFORE reset_progress: r still holds its progress
        iv = self.inv.get(r.rid)
        if iv is not None and r.decoded:
            self.T -= min(r.decoded * iv[0], 1.0) * iv[1]

    def note_finished(self, done_now):
        if self.vec:
            return            # the pool settles S/T at completion time
        for r in done_now:
            iv = self.inv.pop(r.rid, None)
            if iv is not None:
                self.S -= iv[1]
                self.T -= min(r.decoded * iv[0], 1.0) * iv[1]

    def penalty(self) -> float:
        if self.vec:
            pool = self.cluster.pool
            ep = self.cluster.ep
            return float(pool.bk_t[ep] - pool.bk_s[ep])
        return self.T - self.S


class RoutingEnv:
    """One router action per dt tick (the paper's 0.02 s cadence).

    ``profile`` may be one HardwareProfile (homogeneous, cfg.n_instances
    wide -- the paper's setup) or a sequence of per-instance profiles
    (heterogeneous cluster; its length overrides cfg.n_instances).

    ``backend`` names any ``core.backends`` registry backend:
    ``"vec"`` steps the episode on the vectorized structure-of-arrays
    simulator (`core.vecsim`), ``"jax"`` on the device-resident jitted
    round loop (`core.jaxsim`).  Passing a shared ``pool`` +
    ``pool_ep`` instead packs this episode into a multi-episode pool
    so the batched trainer advances all its episodes in fused rounds.
    ``sim_backend=`` is the deprecated pre-registry alias of
    ``backend=``."""

    def __init__(self, cfg: RouterConfig, profile,
                 predict_decode: Optional[Callable] = None,
                 backend: Optional[str] = None,
                 sim_backend: Optional[str] = None,
                 pool=None, pool_ep: int = 0):
        self.cfg = cfg
        if isinstance(profile, HardwareProfile):
            self.profiles = (profile,) * cfg.n_instances
        else:
            self.profiles = tuple(profile)
        self.profile = self.profiles[0]     # router-level reference
        self.m = len(self.profiles)
        if sim_backend is not None:
            warnings.warn(
                "RoutingEnv(sim_backend=...) is deprecated; use "
                "backend=... — backends now resolve through the "
                "core.backends registry", DeprecationWarning,
                stacklevel=2)
            backend = backend or sim_backend
        backend = backend or "py"
        self.sim_backend = "vec" if pool is not None else backend
        self._pool = pool
        self._pool_ep = pool_ep
        # d-hat: estimated decode tokens for a request (predictor hook;
        # oracle fallback)
        self.predict_decode = predict_decode or (
            lambda r: r.decode_tokens)

    def reset(self, requests: Sequence[Request]):
        c = self.cfg
        if self._pool is not None:
            from repro.core.vecsim import VecCluster
            self.cluster = VecCluster(
                self.profiles, self.m, c.scheduler, c.dt,
                c.chunked_prefill, c.n_slots, pool=self._pool,
                ep=self._pool_ep,
                prefix_cache_tokens=c.prefix_cache_tokens,
                prefix_block=c.prefix_block)
        else:
            self.cluster = Cluster(
                self.profiles, self.m, c.scheduler, c.dt,
                c.chunked_prefill, c.n_slots,
                backend=self.sim_backend,
                prefix_cache_tokens=c.prefix_cache_tokens,
                prefix_block=c.prefix_block)
        self._vec = getattr(self.cluster, "is_vec", False)
        self.pending = sorted(requests, key=lambda r: r.arrival)
        self.n_total = len(self.pending)
        # Incremental backlog penalty (Eq. 3 term 1): see BacklogTracker.
        self._bk = BacklogTracker(self.cluster, self.profile,
                                  self.predict_decode)
        self._score_cache = None
        self._i = 0
        self._deliver()
        return self._state()

    def _deliver(self):
        while (self._i < self.n_total
               and self.pending[self._i].arrival <= self.cluster.t):
            r = self.pending[self._i]
            self.cluster.enqueue(r)
            self._bk.register(r)
            self._i += 1

    def _note_finished(self, done_now):
        self._bk.note_finished(done_now)

    def _state(self) -> np.ndarray:
        return state_lib.featurize(
            self.cluster, self.profile, n_buckets=self.cfg.n_buckets,
            include_impact=self.cfg.include_impact_features,
            predict_decode=self.predict_decode, alpha=self.cfg.alpha,
            include_hardware=self.cfg.include_hardware_features,
            include_cache=self.cfg.include_cache_features,
            include_health=self.cfg.include_health_features)

    def mask(self) -> np.ndarray:
        return state_lib.action_mask(self.cluster)

    def _scores(self, req) -> np.ndarray:
        """Per-instance r_mixing for routing ``req`` now (each instance
        judged by its own profile; failed instances -inf).  Cached per
        (request, tick): act-time guidance and the step() reward both need
        the same scores and the cluster cannot change in between."""
        cluster = self.cluster
        key = (req.rid, cluster.t)
        if self._score_cache is not None and self._score_cache[0] == key:
            return self._score_cache[1]
        d_hat = max(self.predict_decode(req), 1)
        scores = mixing_scores(cluster, req, d_hat, self.cfg.alpha,
                               cache_weight=self.cfg.cache_weight)
        self._score_cache = (key, scores)
        return scores

    def guidance_bonus(self) -> np.ndarray:
        """Per-action r_mixing advantage for the current head request
        (route_i: scores_i - max; defer: min - max), zeros if no request."""
        cluster = self.cluster
        if not cluster.central:
            return np.zeros(cluster.m + 1, np.float32)
        req = cluster.central[0]
        d_hat = max(self.predict_decode(req), 1)
        return guidance_from_scores(cluster, req, d_hat,
                                    self._scores(req),
                                    self.cfg.defer_prior_bias)

    def _backlog_penalty(self) -> float:
        return self._bk.penalty()

    def _apply_action(self, action: int, guide_w: float = 0.0) -> float:
        """Apply one routing decision (SLA watchdog included); returns
        the immediate mixing-term reward.  Factored out of step() so
        the batched trainer's fused multi-episode stepping can apply
        all episodes' actions before one fused advance."""
        c = self.cfg
        cluster = self.cluster
        mix_term = 0.0
        scores = None
        if cluster.central:
            scores = self._scores(cluster.central[0])
        if (action >= cluster.m and scores is not None
                and cluster.t - cluster.central[0].arrival
                > c.defer_timeout):
            # SLA watchdog: force the best-impact placement, at a price
            action = int(np.argmax(scores))
            mix_term -= c.sla_penalty
        if action < cluster.m and cluster.central:
            if c.variant == "aware":
                mix_term += float(scores[action])
            elif c.variant == "guided":
                mix_term += guide_w * float(scores[action] - scores.max())
            cluster.route(action)
        elif scores is not None and c.variant == "guided":
            # deferring forfeits the currently-best placement; under the
            # guiding heuristic ("route to argmax r_mixing now") that costs
            # the quality spread it gives up.  (Strategic delay can still
            # be learned once the guidance anneals away.)
            finite = scores[np.isfinite(scores)]
            if finite.size > 1:
                mix_term += guide_w * float(finite.min() - finite.max())
        return mix_term

    def _after_tick(self, done_now) -> tuple:
        """Per-tick bookkeeping after a cluster advance: -> (reward
        delta, done flag).  Shared by step() and the fused stepping."""
        c = self.cfg
        self._note_finished(done_now)
        self._deliver()
        if not c.potential_shaping:
            delta = (self._backlog_penalty() * c.dt
                     + c.r_w * len(done_now))
        else:
            delta = c.r_w_shaped * len(done_now)
        done = (len(self.cluster.completed) >= self.n_total
                or self.cluster.t > c.max_time)
        return delta, done

    def _span_bounds(self, cap: int = 256) -> list:
        """Tick boundaries (sequential ``t += dt``, bit-matching the
        per-tick stepper) from now until the next arrival, past
        ``max_time``, or ``cap`` ticks -- the window the fused batched
        stepper may advance in one shot: no arrivals can land inside
        it, so no decision point can be crossed.  A non-empty router
        queue is already a decision point after one tick (the per-tick
        stepper re-decides immediately on a deferred head), so the
        span is a single tick then."""
        c = self.cfg
        t = self.cluster.t
        if self.cluster.central:
            return [t + c.dt]
        na = (self.pending[self._i].arrival
              if self._i < self.n_total else None)
        bounds = []
        while len(bounds) < cap:
            t = t + c.dt
            bounds.append(t)
            if (na is not None and t >= na) or t > c.max_time:
                break
        return bounds

    def _after_span(self, done_now, bk_reward: float) -> tuple:
        """Span-level bookkeeping: -> (reward delta, done flag).
        ``bk_reward`` is the pool-reconstructed per-tick backlog
        integral over the span (zero-length contribution under
        potential shaping, which rewards completions only)."""
        c = self.cfg
        pool, ep = self.cluster.pool, self.cluster.ep
        s_before = float(pool.bk_s[ep])
        self._deliver()
        if not c.potential_shaping:
            # the per-tick stepper samples the backlog AFTER the
            # arrival tick's deliveries; fold the new arrivals' S into
            # the span's final sample
            delta_s = float(pool.bk_s[ep]) - s_before
            delta = (bk_reward - delta_s * c.dt
                     + c.r_w * len(done_now))
        else:
            delta = c.r_w_shaped * len(done_now)
        done = (len(self.cluster.completed) >= self.n_total
                or self.cluster.t > c.max_time)
        return delta, done

    def step(self, action: int, guide_w: float = 0.0):
        """One DECISION: apply the action, then advance dt ticks until the
        next decision point (non-empty router queue) or episode end,
        accumulating the Eq.(3) reward.  Ticks with an empty queue have no
        choice to make (forced defer), so they are not decision states --
        this keeps the replay buffer full of actual decisions while
        preserving the paper's 0.02 s simulation cadence."""
        c = self.cfg
        cluster = self.cluster
        reward = self._apply_action(action, guide_w)
        completed = 0
        phi_before = self._backlog_penalty()
        while True:
            done_now = cluster.advance()
            delta, done = self._after_tick(done_now)
            completed += len(done_now)
            reward += delta
            if done or cluster.central:
                break
        if c.potential_shaping:
            # potential-based shaping on the backlog level: the raw Eq.(3)
            # backlog integral has a huge action-independent component that
            # drowns action advantages in the TD signal; telescoping the
            # potential keeps the optimal policy (Ng et al. 1999) while the
            # per-step reward tracks backlog CHANGES.
            phi_after = self._backlog_penalty()
            reward += (c.gamma * phi_after - phi_before)
        return self._state(), reward, done, {"completed": completed}


def make_agent(cfg: RouterConfig, m: Optional[int] = None) -> DQNAgent:
    """Build the DQN agent for an m-instance action space (defaults to
    cfg.n_instances; the batched runner passes its padded width m_max)."""
    m = m or cfg.n_instances
    inst_dims = state_lib.instance_dims(cfg.include_impact_features,
                                        cfg.include_hardware_features,
                                        cfg.include_cache_features,
                                        cfg.include_health_features)
    dcfg = DQNConfig(
        state_dim=state_lib.state_dim(m, cfg.include_impact_features,
                                      cfg.include_hardware_features,
                                      cfg.include_cache_features,
                                      cfg.include_health_features),
        n_actions=m + 1, hidden=cfg.hidden,
        gamma=cfg.gamma, lr=cfg.lr, q_arch=cfg.q_arch,
        inst_dims=inst_dims, router_dims=state_lib.ROUTER_DIMS,
        center_rewards=not cfg.potential_shaping)
    return DQNAgent(dcfg, seed=cfg.seed)


def guidance_weight(cfg: RouterConfig, episode: int) -> float:
    if cfg.variant != "guided":
        return 0.0
    return cfg.gamma * float(np.exp(-cfg.beta_d * episode))


class NStepAssembler:
    """Truncated n-step Monte-Carlo return assembly (RouterConfig.nstep):
    every decision's span reward is appended to all open windows, and a
    window that has collected ``nstep`` rewards matures into a training
    tuple (s0, a0, discounted return).  Shared by the offline ``train``
    loop and the online gateway trainer (training.online) so both emit
    identical targets for identical decision/reward streams."""

    def __init__(self, nstep: int, gamma: float):
        self.nstep = nstep
        self.g = gamma
        self.window: deque = deque()

    def add(self, s, a: int, r: float):
        """Record one decision + its span reward; returns the (0 or 1)
        matured (s0, a0, ret) tuples this decision flushed."""
        for _, _, rs in self.window:
            rs.append(r)
        self.window.append((s, a, [r]))
        if len(self.window) > self.nstep:
            return (self._pop(),)
        return ()

    def _pop(self):
        s0, a0, rs = self.window.popleft()
        ret = 0.0
        for i, ri in enumerate(rs):
            ret += (self.g ** i) * ri
        return s0, a0, ret

    def drain(self):
        """Flush every open window (episode / stream end)."""
        while self.window:
            yield self._pop()


def train(cfg: RouterConfig, profile: HardwareProfile,
          workload_fn: Callable[[int], Sequence[Request]],
          n_episodes: int, agent: Optional[DQNAgent] = None,
          predict_decode: Optional[Callable] = None,
          valid_fn: Optional[Callable[[], Sequence[Request]]] = None,
          verbose: bool = False) -> Dict:
    """Train the RL router; returns {agent, history}.

    valid_fn: workload for periodic GREEDY validation; the best-validating
    snapshot is restored at the end (protects against the well-known
    late-training DQN collapse when epsilon hits zero)."""
    import jax
    import jax.numpy as jnp
    agent = agent or make_agent(cfg)
    env = RoutingEnv(cfg, profile, predict_decode)
    history = []
    best = None
    for ep in range(n_episodes):
        requests = workload_fn(ep)
        s = env.reset(requests)
        w_k = guidance_weight(cfg, ep)
        # training discount: gamma_k = gamma - w_k (guided); else gamma
        gamma_k = cfg.gamma - w_k if cfg.variant == "guided" else cfg.gamma
        frac = min(ep / max(cfg.explore_episodes, 1), 1.0)
        eps = cfg.eps_start + (cfg.eps_end - cfg.eps_start) * frac
        if ep >= cfg.explore_episodes:
            eps = 0.0               # §A.9.2: exploit after episode 20
        # per-episode discount (heuristic-guided horizon shortening).
        # With n-step Monte-Carlo targets (nstep>0, observe() passes
        # done=1) gamma never enters the TD target, and mutating the
        # static cfg forces an XLA recompile per distinct value -- so the
        # retrace is only applied in bootstrapped (nstep=0) mode.
        if cfg.nstep == 0 and agent.cfg.gamma != gamma_k:
            import dataclasses as _dc
            agent.cfg = _dc.replace(agent.cfg, gamma=round(gamma_k, 3))
        w_sel = max(w_k, cfg.guidance_floor) \
            if cfg.variant == "guided" else 0.0
        scale = 1.0 if cfg.potential_shaping else cfg.reward_scale
        ep_reward, ticks, done = 0.0, 0, False
        asm = NStepAssembler(cfg.nstep, cfg.nstep_gamma)
        while not done:
            mask = env.mask()
            prior = w_sel * env.guidance_bonus() if w_sel else None
            a = agent.act(s, mask, epsilon=eps, prior=prior,
                          q_squash=cfg.q_squash if w_sel else 0.0)
            s2, r, done, _ = env.step(a, guide_w=w_k)
            if cfg.nstep > 0:
                # NOTE: matured windows bootstrap on the PRE-step state +
                # post-step mask; both are dead values under done=1.0 MC
                # targets (kept for byte-stable replay rows).
                for s0, a0, ret in asm.add(s, a, r / scale):
                    agent.observe(s0, a0, ret, s, 1.0, env.mask())
            else:
                agent.observe(s, a, r / scale, s2, float(done), env.mask())
            if ticks % cfg.learn_every == 0:
                agent.learn()
            s = s2
            ep_reward += r
            ticks += 1
        for s0, a0, ret in asm.drain():
            agent.observe(s0, a0, ret, s, 1.0, env.mask())
        stats = summarize(requests)
        stats.update({"episode": ep, "reward": ep_reward, "ticks": ticks,
                      "epsilon": eps, "guide_w": w_k})
        # greedy-validation snapshot selection
        if valid_fn is not None and eps <= 0.6:
            v = evaluate(cfg, profile, agent, valid_fn(),
                         predict_decode)
            stats["valid_e2e"] = v["e2e_mean"]
            if best is None or v["e2e_mean"] < best[0]:
                best = (v["e2e_mean"], jax.tree.map(jnp.copy, agent.params))
        history.append(stats)
        if verbose:
            print(f"ep {ep:3d} eps={eps:.2f} w_k={w_k:.3f} "
                  f"reward={ep_reward:10.1f} e2e={stats['e2e_mean']:.2f}"
                  + (f" valid={stats['valid_e2e']:.2f}"
                     if "valid_e2e" in stats else ""))
    if best is not None:
        agent.params = best[1]
        agent.target = jax.tree.map(jnp.copy, best[1])
    return {"agent": agent, "history": history}


def evaluate(cfg: RouterConfig, profile: HardwareProfile, agent: DQNAgent,
             requests: Sequence[Request],
             predict_decode: Optional[Callable] = None) -> Dict:
    env = RoutingEnv(cfg, profile, predict_decode)
    s = env.reset(requests)
    done = False
    w_sel = cfg.guidance_floor if cfg.variant == "guided" else 0.0
    while not done:
        prior = w_sel * env.guidance_bonus() if w_sel else None
        a = agent.act(s, env.mask(), epsilon=0.0, prior=prior,
                      q_squash=cfg.q_squash if w_sel else 0.0)
        s, _, done, _ = env.step(a)
    if getattr(env.cluster, "is_vec", False):
        env.cluster.sync_all()       # in-flight requests on truncation
    stats = summarize(requests)
    stats["spikes"] = sum(len(i.spikes) for i in env.cluster.instances)
    stats["router_wait_mean"] = float(np.mean(
        [r.routed_at - r.arrival for r in requests
         if r.routed_at is not None])) if requests else 0.0
    return stats
