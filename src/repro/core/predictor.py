"""Output-length (decode-bucket) predictor (paper §5.1).

A small JAX transformer encoder classifies an input prompt into a decode
bucket.  Faithful elements of the paper's design:

  * buckets are TIME-ALIGNED and unequal (0.5 * 4^k second boundaries
    mapped to token counts via the hardware profile) rather than equal
    token ranges;
  * the task type is appended as a HINT token to the prompt
    ("This is a <task> task"), which is what lifts accuracy from
    near-chance (S^3-style, 5.5% in the paper) to useful levels;
  * a task classifier (same encoder, task labels) shows the task itself is
    recoverable from content (paper §A.7: 93.79%), justifying the hint.

A feature-based variant (prompt length + app id -> bucket) reproduces the
§A.12 production-trace predictor where prompt content is unavailable.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import workload as wl
from repro.core.profiles import HardwareProfile


@dataclass(frozen=True)
class PredictorConfig:
    vocab: int = wl.VOCAB + len(wl.TASKS) + 1   # + hint tokens + pad
    seq_len: int = 64
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    n_buckets: int = 8
    use_hint: bool = True
    lr: float = 3e-4
    batch: int = 128


def _pad_token(cfg: PredictorConfig) -> int:
    return cfg.vocab - 1


def hint_token(cfg: PredictorConfig, task_id: int) -> int:
    return wl.VOCAB + task_id


def encode_sample(cfg: PredictorConfig, s: wl.Sample) -> np.ndarray:
    toks = list(s.token_ids[:cfg.seq_len - 1])
    if cfg.use_hint:
        toks.append(hint_token(cfg, s.task_id))   # "This is a <task> task"
    toks = toks[:cfg.seq_len]
    toks += [_pad_token(cfg)] * (cfg.seq_len - len(toks))
    return np.asarray(toks, np.int32)


def init_params(key, cfg: PredictorConfig, n_out: Optional[int] = None):
    n_out = n_out or cfg.n_buckets
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 2 + 6 * cfg.n_layers)

    def dense(k, *sh):
        return jax.random.normal(k, sh) / np.sqrt(sh[0])

    params = {"embed": dense(ks[0], cfg.vocab, d) * np.sqrt(d) / d,
              "head": dense(ks[1], d, n_out)}
    layers = []
    for i in range(cfg.n_layers):
        base = 2 + 6 * i
        layers.append({
            "wq": dense(ks[base], d, d), "wk": dense(ks[base + 1], d, d),
            "wv": dense(ks[base + 2], d, d), "wo": dense(ks[base + 3], d, d),
            "w1": dense(ks[base + 4], d, 4 * d),
            "w2": dense(ks[base + 5], 4 * d, d),
            "ln1": jnp.zeros((d,)), "ln2": jnp.zeros((d,)),
        })
    params["layers"] = layers
    return params


def _norm(x, w):
    mu = jnp.mean(x, -1, keepdims=True)
    var = jnp.var(x, -1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * (1 + w)


def apply(params, cfg: PredictorConfig, tokens: jax.Array) -> jax.Array:
    """tokens [B, L] -> logits [B, n_out]."""
    pad = _pad_token(cfg)
    mask = (tokens != pad)
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    x = params["embed"][tokens]
    pos = jnp.arange(cfg.seq_len)
    x = x + 0.02 * jnp.sin(pos[:, None] * jnp.exp(
        -jnp.arange(d)[None, :] / d * 6.0))
    att_mask = (mask[:, None, None, :]).astype(jnp.float32)
    for lp in params["layers"]:
        hx = _norm(x, lp["ln1"])
        q = (hx @ lp["wq"]).reshape(*hx.shape[:2], h, hd)
        k = (hx @ lp["wk"]).reshape(*hx.shape[:2], h, hd)
        v = (hx @ lp["wv"]).reshape(*hx.shape[:2], h, hd)
        sc = jnp.einsum("bqhk,bshk->bhqs", q, k) / np.sqrt(hd)
        sc = jnp.where(att_mask > 0, sc, -1e30)
        w = jax.nn.softmax(sc, -1)
        o = jnp.einsum("bhqs,bshk->bqhk", w, v).reshape(hx.shape)
        x = x + o @ lp["wo"]
        hx = _norm(x, lp["ln2"])
        x = x + jax.nn.gelu(hx @ lp["w1"]) @ lp["w2"]
    pooled = jnp.sum(x * mask[..., None], 1) / jnp.maximum(
        jnp.sum(mask, 1, keepdims=True), 1)
    return pooled @ params["head"]


# jitted inference entry (one compile per (cfg, batch-shape); callers
# pad to a fixed batch so the serving hot path compiles exactly once)
apply_jit = jax.jit(apply, static_argnames=("cfg",))


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(0, 1))
def _train_step(params, opt, cfg: PredictorConfig, tokens, labels):
    def loss_fn(p):
        logits = apply(p, cfg, tokens)
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[:, None], 1)[:, 0]
        return jnp.mean(logz - gold)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    step = opt["step"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         opt["v"], grads)
    params = jax.tree.map(
        lambda p, m, v: p - cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, new_m, new_v)
    return params, {"m": new_m, "v": new_v, "step": step}, loss


class BucketPredictor:
    """Trainable decode-bucket predictor over prompt content (+ hint)."""

    def __init__(self, cfg: PredictorConfig, profile: HardwareProfile,
                 seed: int = 0, n_out: Optional[int] = None,
                 equal_buckets: bool = False):
        self.cfg, self.profile = cfg, profile
        self.equal_buckets = equal_buckets
        self.n_out = n_out or cfg.n_buckets
        self.params = init_params(jax.random.PRNGKey(seed), cfg, self.n_out)
        self.opt = {"m": jax.tree.map(jnp.zeros_like, self.params),
                    "v": jax.tree.map(jnp.zeros_like, self.params),
                    "step": jnp.zeros((), jnp.int32)}

    def label(self, s: wl.Sample) -> int:
        if self.equal_buckets:        # S^3-style equal 250-token buckets
            return min(s.decode_tokens // 250, self.n_out - 1)
        return min(self.profile.bucketize(s.decode_tokens,
                                          self.cfg.n_buckets),
                   self.n_out - 1)

    def fit(self, samples: Sequence[wl.Sample], epochs: int = 3,
            seed: int = 0, labels: Optional[Sequence[int]] = None,
            verbose: bool = False) -> List[float]:
        cfg = self.cfg
        x = np.stack([encode_sample(cfg, s) for s in samples])
        y = np.asarray(labels if labels is not None
                       else [self.label(s) for s in samples], np.int32)
        rng = np.random.default_rng(seed)
        losses = []
        for ep in range(epochs):
            order = rng.permutation(len(x))
            for i in range(0, len(x) - cfg.batch + 1, cfg.batch):
                idx = order[i:i + cfg.batch]
                self.params, self.opt, loss = _train_step(
                    self.params, self.opt, cfg, jnp.asarray(x[idx]),
                    jnp.asarray(y[idx]))
            losses.append(float(loss))
            if verbose:
                print(f"  predictor epoch {ep}: loss {float(loss):.3f}")
        return losses

    def predict(self, samples: Sequence[wl.Sample],
                chunk: int = 512) -> np.ndarray:
        """Batched greedy prediction.  Chunks are PADDED to ``chunk``
        rows so the jitted forward compiles once regardless of the
        request count (pad rows are all-pad-token and discarded)."""
        cfg = self.cfg
        x = np.stack([encode_sample(cfg, s) for s in samples])
        out = []
        pad_row = np.full((1, cfg.seq_len), _pad_token(cfg), np.int32)
        for i in range(0, len(x), chunk):
            part = x[i:i + chunk]
            n = len(part)
            if n < chunk:
                part = np.concatenate(
                    [part, np.repeat(pad_row, chunk - n, axis=0)])
            logits = apply_jit(self.params, cfg, jnp.asarray(part))
            out.append(np.argmax(np.asarray(logits[:n]), -1))
        return np.concatenate(out)

    def accuracy(self, samples: Sequence[wl.Sample],
                 labels: Optional[Sequence[int]] = None) -> float:
        y = np.asarray(labels if labels is not None
                       else [self.label(s) for s in samples])
        return float(np.mean(self.predict(samples) == y))

    def bucket_upper_tokens(self, bucket: int) -> int:
        edges = self.profile.bucket_edges(self.cfg.n_buckets)
        if bucket >= len(edges):
            return int(edges[-1] * 2)
        return int(edges[bucket])

    def bucket_of(self, decode_tokens: int) -> int:
        """The bucket an ACTUAL decode length lands in -- label() over
        a realized length instead of a Sample (predictor-drift
        bucket-accuracy in StreamMetrics)."""
        if self.equal_buckets:
            return min(decode_tokens // 250, self.n_out - 1)
        return min(self.profile.bucketize(decode_tokens,
                                          self.cfg.n_buckets),
                   self.n_out - 1)

    def decode_estimate(self, samples: Sequence[wl.Sample]) -> np.ndarray:
        """d-hat per sample = upper bound of the predicted bucket (what the
        router's impact estimator consumes)."""
        return np.array([self.bucket_upper_tokens(b)
                         for b in self.predict(samples)])


class TaskClassifier(BucketPredictor):
    """§A.7: predict the task from content alone (no hint)."""

    def __init__(self, profile, seed: int = 0):
        cfg = PredictorConfig(use_hint=False)
        super().__init__(cfg, profile, seed, n_out=len(wl.TASKS))

    def label(self, s: wl.Sample) -> int:
        return s.task_id


# -- router-facing d-hat plumbing (predictor in the routing loop) -----------

def serviceable_decode(profile: HardwareProfile, d_hat: int,
                       prompt_tokens: int) -> int:
    """Clamp a decode estimate to the instance-serviceable KV budget
    (vLLM-style max-tokens bound): a top-bucket upper edge can exceed
    the whole pool, and an unserviceable d-hat would make the router's
    capacity-fit check defer the request forever.  ONE definition,
    shared by training-time annotation and the serving gateway, so the
    router trains on exactly the signal it serves with."""
    cap = int(profile.capacity_tokens * 0.95)
    return max(min(int(d_hat), cap - prompt_tokens), 1)


def annotate_requests(predictor: "BucketPredictor", requests,
                      samples) -> None:
    """Batch-predict decode buckets for ``samples`` (one padded jitted
    forward per 512) and stamp the aligned ``requests`` with
    ``predicted_bucket`` / ``predicted_decode`` -- the d-hat the state
    featurizer, the impact estimator, and the backlog penalty consume
    instead of the oracle length."""
    if not requests:
        return
    buckets = predictor.predict(samples)
    for r, b in zip(requests, buckets):
        r.predicted_bucket = int(b)
        r.predicted_decode = serviceable_decode(
            predictor.profile, predictor.bucket_upper_tokens(int(b)),
            r.prompt_tokens)


def predicted_decode(req) -> int:
    """``predict_decode`` hook reading the stamped d-hat (oracle
    fallback for requests that never passed the predictor)."""
    d = req.predicted_decode
    return d if d is not None else req.decode_tokens


def annotating_stream(scenario_fn, predictor: "BucketPredictor"):
    """Wrap a scenario stream so every episode's requests are stamped
    with predictor d-hats before training sees them: this is how
    ``batched_rl.train_batched`` runs with the LEARNED length predictor
    in the loop (pass ``predict_decode=predicted_decode`` alongside)."""
    def fn(ep: int):
        scn = scenario_fn(ep)
        if scn.samples is not None:
            annotate_requests(predictor, scn.requests, scn.samples)
        return scn
    return fn


def quick_bucket_predictor(profile: HardwareProfile,
                           n_train: int = 2000, epochs: int = 2,
                           seed: int = 0,
                           cfg: Optional[PredictorConfig] = None
                           ) -> "BucketPredictor":
    """Train a small bucket predictor on fresh synthetic samples --
    the shared setup step for the gateway bench/launcher and the
    predictor-in-the-loop trainer."""
    cfg = cfg or PredictorConfig()
    pred = BucketPredictor(cfg, profile, seed=seed)
    pred.fit(wl.generate(n_train, seed=seed + 1), epochs=epochs,
             seed=seed + 2)
    return pred


# -- §A.12 trace predictor (no prompt content) ------------------------------

class TracePredictor:
    """(log prompt_len, app one-hot) -> bucket, tiny MLP (random-forest
    stand-in; sklearn is unavailable offline)."""

    def __init__(self, profile: HardwareProfile, n_apps: int,
                 n_buckets: int = 8, seed: int = 0):
        self.profile, self.n_buckets = profile, n_buckets
        self.n_apps = n_apps
        key = jax.random.PRNGKey(seed)
        k1, k2 = jax.random.split(key)
        d_in = 2 + n_apps
        self.w1 = jax.random.normal(k1, (d_in, 64)) / np.sqrt(d_in)
        self.b1 = jnp.zeros((64,))
        self.w2 = jax.random.normal(k2, (64, n_buckets)) / np.sqrt(64)
        self.b2 = jnp.zeros((n_buckets,))

    def _feats(self, samples):
        f = np.zeros((len(samples), 2 + self.n_apps), np.float32)
        for i, s in enumerate(samples):
            f[i, 0] = np.log1p(s.prompt_tokens) / 10.0
            f[i, 1] = (s.prompt_tokens % 997) / 997.0
            f[i, 2 + s.task_id % self.n_apps] = 1.0
        return f

    def fit(self, samples, epochs: int = 60, lr: float = 1e-2,
            seed: int = 0):
        x = jnp.asarray(self._feats(samples))
        y = jnp.asarray([min(self.profile.bucketize(s.decode_tokens,
                                                    self.n_buckets),
                             self.n_buckets - 1) for s in samples])
        params = (self.w1, self.b1, self.w2, self.b2)

        def loss_fn(p):
            w1, b1, w2, b2 = p
            logits = jax.nn.relu(x @ w1 + b1) @ w2 + b2
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, y[:, None], 1)[:, 0]
            return jnp.mean(logz - gold)

        grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        for _ in range(epochs):
            _, g = grad_fn(params)
            params = jax.tree.map(lambda p, gi: p - lr * gi, params, g)
        self.w1, self.b1, self.w2, self.b2 = params

    def predict(self, samples) -> np.ndarray:
        x = jnp.asarray(self._feats(samples))
        logits = jax.nn.relu(x @ self.w1 + self.b1) @ self.w2 + self.b2
        return np.asarray(jnp.argmax(logits, -1))

    def accuracy(self, samples) -> float:
        y = np.asarray([min(self.profile.bucketize(s.decode_tokens,
                                                   self.n_buckets),
                            self.n_buckets - 1) for s in samples])
        return float(np.mean(self.predict(samples) == y))
