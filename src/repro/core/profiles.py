"""Hardware/model cost profiles (the paper's grad1/grad2 calibration).

The paper (§4.2, Fig. 4) characterizes iteration time of an instance as:
  * prefill: grows linearly and fast with prompt tokens  (grad1 s/token)
  * decode:  grows slowly with resident context tokens   (grad2 s/token)
and classifies requests heavy/light by phase-time thresholds (0.5s prompt,
5s decode).  Both gradients are per (model, hardware) calibration constants;
the paper ships Llama-2-7B/V100 numbers, and says to re-profile elsewhere.

We keep the V100 profile as the reproduction default, derive a TPU v5e
profile analytically from the roofline constants, and provide ``fit()`` to
calibrate from engine measurements (same linear-fit procedure as Fig. 4).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, replace
from typing import Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class HardwareProfile:
    name: str
    grad1: float            # s per prefill token in an iteration (Fig. 4a)
    grad2: float            # s per co-resident context token (Fig. 4b)
    t_decode_base: float    # base decode iteration time (s)
    heavy_prompt_s: float = 0.5    # heavy/light prompt threshold (s)
    heavy_decode_s: float = 5.0    # heavy/light decode threshold (s)
    epsilon: float = 1.0           # Eq.(1) latency-impact tolerance
    capacity_tokens: int = 66_000  # KV pool (token budget) per instance
    max_batch: int = 128           # slot count per instance
    # fixed prefill dispatch overhead per iteration that prefills (s);
    # the intercept of the calibrated prefill fit (core.calibrate).  The
    # paper's Fig. 4 line is forced through the origin, so the shipped
    # V100/A100 profiles keep 0.0 -- behaviour (and the vecsim bit-parity
    # surface) is unchanged unless a calibrated profile sets it.
    t_prefill_base: float = 0.0

    # -- the paper's §4.2 processing-time estimates -----------------------
    def prefill_time(self, p: int) -> float:
        return self.grad1 * p + self.t_prefill_base

    def decode_time(self, d: int) -> float:
        return self.t_decode_base * d

    def request_time(self, p: int, d: int) -> float:
        """p x (time per prompt token) + d x (average decode batch time)."""
        return self.prefill_time(p) + self.decode_time(d)

    def iteration_time(self, prefill_tokens: int, resident_other: int
                       ) -> float:
        """One engine iteration: base + prefill work + decode interference."""
        return (self.t_decode_base + self.grad1 * prefill_tokens
                + self.grad2 * resident_other
                + self.t_prefill_base * (prefill_tokens > 0))

    # -- heavy/light classification (LL/LH/HL/HH) --------------------------
    def prompt_is_heavy(self, p: int) -> bool:
        return self.prefill_time(p) >= self.heavy_prompt_s

    def decode_is_heavy(self, d: int) -> bool:
        return self.decode_time(d) >= self.heavy_decode_s

    def classify(self, p: int, d: int) -> str:
        return (("H" if self.prompt_is_heavy(p) else "L")
                + ("H" if self.decode_is_heavy(d) else "L"))

    # -- decode-bucket edges (§5.1: time-aligned, unequal) ------------------
    def bucket_edges(self, n_buckets: int = 8) -> Tuple[float, ...]:
        """Token-count edges at 0.5 * 4^k second boundaries: 0-0.5s,
        0.5-2s, 2-4s, ... mapped to decode-token counts.  Cached by
        value (the featurizer calls this once per routing decision;
        keying on t_decode_base rather than self avoids pinning every
        recalibrated profile instance in a process-lifetime cache)."""
        return _bucket_edges(self.t_decode_base, n_buckets)

    def bucketize(self, d: int, n_buckets: int = 8) -> int:
        edges = self.bucket_edges(n_buckets)
        return int(np.searchsorted(edges, d, side="right"))


@functools.lru_cache(maxsize=256)
def _bucket_edges(t_decode_base: float, n_buckets: int
                  ) -> Tuple[float, ...]:
    tok_per_s = 1.0 / t_decode_base
    secs = [0.5 * (4 ** k) for k in range(n_buckets - 1)]
    return tuple(s * tok_per_s for s in secs)


# Llama-2-7B on V100 (paper's Fig. 4 calibration).  KV capacity: 16 GB HBM
# - 14 GB fp16 weights = ~2 GB pool / 0.5 MB per token (32L x 4096 x 2 x
# fp16) ~= 4000 tokens -- this small pool is what makes preemption and
# router queueing matter in the paper's experiments.
V100_LLAMA2_7B = HardwareProfile(
    name="v100-llama2-7b", grad1=3.2e-4, grad2=3.3e-5,
    t_decode_base=0.0167, capacity_tokens=4_000, max_batch=128)

# Llama-3.1-8B on A100-40GB (paper §6.2: ~4x faster; re-benchmarked
# gradients; GQA kv=8 -> 128 KB/token -> ~180k tokens; we keep 60k to match
# the paper's observable preemption behaviour at 80 rps on the trace).
A100_LLAMA31_8B = HardwareProfile(
    name="a100-llama31-8b", grad1=8.0e-5, grad2=8.0e-6,
    t_decode_base=0.0042, capacity_tokens=60_000, max_batch=256)


def tpu_v5e_profile(n_params: float, tp: int = 16,
                    name: str = "v5e") -> HardwareProfile:
    """Analytic v5e profile from roofline constants.

    prefill s/token = 2*N / (tp * 197e12 * mfu), decode s/token =
    2*N_bytes / (tp * 819e9) (weights-bound decode).  mfu ~ 0.5 prefill.
    """
    peak = 197e12 * 0.5
    hbm = 819e9
    grad1 = 2 * n_params / (tp * peak)
    t_dec = 2 * n_params / (tp * hbm)          # bf16 weight reads
    grad2 = t_dec * 0.002                      # KV-read marginal cost
    cap = int(tp * 16e9 * 0.4 / 1e5)           # rough KV token budget
    return HardwareProfile(name=name, grad1=grad1, grad2=grad2,
                           t_decode_base=max(t_dec, 1e-4),
                           capacity_tokens=max(cap, 10_000))


def profile_to_json(profile: HardwareProfile) -> dict:
    """A committable artifact for a (calibrated) profile -- plain field
    dict, round-tripped by :func:`profile_from_json`."""
    return dataclasses.asdict(profile)


def profile_from_json(d: dict) -> HardwareProfile:
    """Inverse of :func:`profile_to_json`.  Unknown keys are ignored
    (forward compatibility: newer writers may add diagnostics)."""
    known = {f.name for f in dataclasses.fields(HardwareProfile)}
    return HardwareProfile(**{k: v for k, v in d.items() if k in known})


def fit(samples_prefill: Sequence[Tuple[int, float]],
        samples_decode: Sequence[Tuple[int, float]],
        base: HardwareProfile = V100_LLAMA2_7B) -> HardwareProfile:
    """Fit grad1/grad2 from (tokens, iteration_time) measurements
    (least-squares line, as in the paper's Fig. 4).  Kept for
    simulator-side Fig. 4 sweeps; the measured engine-side calibration
    with fit diagnostics lives in ``core.calibrate`` (this shares its
    line fitter, so the two paths cannot drift)."""
    from repro.core.calibrate import linear_fit   # avoid import cycle
    pf = linear_fit(samples_prefill)
    df = linear_fit(samples_decode)
    return replace(base, name=base.name + "-fit", grad1=pf.slope,
                   grad2=df.slope,
                   t_decode_base=max(df.intercept, 1e-4),
                   t_prefill_base=max(pf.intercept, 0.0))
