"""Vectorized structure-of-arrays simulator core (``vecsim``).

``SimInstance`` advances one request at a time in pure Python; every
training decision and every gateway tick bottoms out in that loop, so
episode wall time is O(requests x instances x episodes).  This module
packs all requests of all instances -- and, under the batched RL
trainer, all *episodes* -- into fixed-width numpy arrays and advances
every instance of every episode in fused vector ops per "round" (one
engine iteration on every lane that is behind its episode clock):

  * a request **arena**: per-request ``prompt / prefilled / decoded /
    admit_seq / phase / ...`` rows for every request ever enqueued
    (authoritative while a request is queued or finished);
  * **lane** arrays: per-instance ``clock / rts / qps / outstanding /
    failed`` plus profile constants, a ring-buffer queue ``q_gid[L, Q]``
    and slot-aligned resident matrices (``s_prompt / s_prefilled /
    s_decoded / ...`` [L, S], authoritative while a request is
    resident, so a round touches no arena gathers on its hot path);
  * one round = vectorized admission (scheduler pick over masked queue
    heads), chunked-prefill progress, gang decode, spike detection, and
    newest-first capacity preemption (oldest-resident liveness grace),
    replicating ``SimInstance._iteration`` decision for decision.

All token quantities are integers carried in float64/int64 (float64
arithmetic on integers below 2^53 is exact), and every arithmetic
expression mirrors the scalar code's association order, so clocks,
admission decisions, and preemption choices are **bit-exact** against
the Python stepper (asserted by tests/test_vecsim.py).  The only
divergences are documented: the ordering of completions *within* one
``advance`` call, per-token ``token_times`` (synthesized evenly spaced
between the true first/last emission, so ``Request.tbt`` -- which
telescopes -- is exact), and the float summation order of the RL
backlog accumulators (reward-only, never decisions).

Entry points:
  * ``Cluster(..., backend="vec")`` returns a :class:`VecCluster`
    (drop-in for the Cluster protocol: run_heuristic, the gateway, the
    RL env, and ManagedCluster all work unchanged);
  * ``VecSimPool(n_episodes)`` + ``VecCluster(..., pool=, ep=)`` packs
    many episodes into ONE set of arrays so the batched RL trainer
    steps all of them per round (``pool.advance([eps...])``) -- cost
    becomes O(rounds), not O(requests x instances x episodes).
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.prefix_cache import PrefixCache
from repro.core.profiles import HardwareProfile
from repro.serving import trace as _trace
from repro.serving.request import Phase, Request

# phase codes (arena ``phase`` column) <-> serving.request.Phase
PH_QUEUED, PH_IQUEUE, PH_PREFILL, PH_DECODE, PH_PREEMPTED, PH_DONE = \
    range(6)
_PH_TO_ENUM = (Phase.QUEUED, Phase.INSTANCE_QUEUE, Phase.PREFILL,
               Phase.DECODE, Phase.PREEMPTED, Phase.DONE)
_ENUM_TO_PH = {p: i for i, p in enumerate(_PH_TO_ENUM)}

# resident slot states
SS_EMPTY, SS_PREFILL, SS_DECODE = 0, 1, 2

SCHED_FCFS, SCHED_BIN, SCHED_LWL = 0, 1, 2
_SCHED_CODE = {"fcfs": SCHED_FCFS, "bin_packing": SCHED_BIN,
               "least_work_left": SCHED_LWL}

# large-but-overflow-safe sentinel (added to int64 admission counters)
_BIG = np.int64(1) << 62


class VecSimPool:
    """Structure-of-arrays state for E episodes' worth of instances.

    Lanes are pool-global instance slots; each episode owns an ordered
    subset (``ep_lanes[ep]``).  The request arena grows monotonically
    (episode resets park old rows; ~150 B/request, so even thousand-
    episode training runs stay in the tens of MB)."""

    def __init__(self, n_episodes: int = 1, arena_cap: int = 1024):
        e = n_episodes
        self.E = e
        self._hw = 0                # high-water resident column + 1
        self._all = np.empty(0, np.int64)   # cached arange(L)
        self._target = np.empty(0)          # persistent advance buffer
        self._ep_min_clock = np.zeros(e)    # lower bound per episode
        self._hw_check = 0                  # periodic hw re-tighten
        self._span = None                   # advance_span bucket state
        self._lanes_cache: Dict[tuple, tuple] = {}   # eps -> lane set
        self._lanes_ver = 0
        self.ep_t = np.zeros(e)
        self.ep_dt = np.full(e, 0.02)
        # RL backlog accumulators (Eq. 3 term 1): S = sum 1/t_hat over
        # delivered-unfinished, T = sum frac/t_hat.  Maintained by the
        # round loop once any request registers inv terms (``track``).
        self.bk_s = np.zeros(e)
        self.bk_t = np.zeros(e)
        self.track = False
        # sum of inv_d*inv_t over decoding-and-uncapped residents, per
        # LANE: the per-round T accrual reduces to one masked bincount
        # (each uncapped decoding request contributes inv_d*inv_t per
        # token) with event-time corrections at the d_hat cap crossing.
        # Lane granularity matters: only lanes active in a round decode.
        self.lane_ivv = np.zeros(0)
        # python-int gates for the round loop (numpy .any() costs
        # microseconds per call on small arrays; these are free)
        self._tot_q = 0            # queued requests across all lanes
        self._tot_pref = 0         # residents still prefilling
        self._tot_dec = 0          # residents decoding
        self._next_fin = 10 ** 9   # lower bound on rounds to next finish
        self._all_fcfs = True
        self.ep_lanes: List[np.ndarray] = [np.empty(0, np.int64)
                                           for _ in range(e)]
        self._ep_gids: List[List[int]] = [[] for _ in range(e)]
        # -- lanes -------------------------------------------------------
        self._L = 0
        self._free: List[int] = []
        self._S = 8                 # resident slot columns (grows)
        self._Q = 16                # queue ring width (grows)
        z = np.zeros
        self.lane_ep = z(0, np.int64)
        self.lane_local = z(0, np.int64)    # instance index inside its ep
        self.failed = z(0, bool)
        self.clock = z(0)
        self.rts = z(0)             # resident context token sum
        self.qps = z(0)             # queued prompt token sum
        self.outst = z(0)           # outstanding prompt+decode tokens
        self.cap = z(0)
        self.nslots = z(0, np.int64)
        self.grad1 = z(0)
        self.grad2 = z(0)
        self.tdec = z(0)
        self.tpre = z(0)            # profile.t_prefill_base
        self.eps_lat = z(0)         # profile.epsilon (Eq. 1 tolerance)
        self.speed = z(0)           # straggler factor (1.0 = nominal)
        self.chunk = z(0, np.int64)
        self.sched = z(0, np.int8)
        self.admit_ctr = z(0, np.int64)
        self.res_cnt = z(0, np.int64)
        self.pref_cnt = z(0, np.int64)      # residents still prefilling
        self.qhead = z(0, np.int64)
        self.qcnt = z(0, np.int64)
        self.q_gid = np.full((0, self._Q), -1, np.int64)
        # -- resident slot matrices [L, S] ------------------------------
        s = self._S
        self.res_gid = np.full((0, s), -1, np.int64)
        self.s_state = np.zeros((0, s), np.int8)
        self.s_prompt = np.zeros((0, s), np.int64)
        self.s_dtotal = np.zeros((0, s), np.int64)
        self.s_prefilled = np.zeros((0, s), np.int64)
        self.s_decoded = np.zeros((0, s), np.int64)
        self.s_admit = np.zeros((0, s), np.int64)
        self.s_first = np.zeros((0, s))
        self.s_pfdone = np.zeros((0, s))
        self.s_invd = np.zeros((0, s))
        self.s_invt = np.zeros((0, s))
        self.s_capat = np.zeros((0, s), np.int64)   # d_hat cap tokens
        self.spikes: List[List[float]] = []
        self.lane_profile: List[HardwareProfile] = []
        # per-lane prefix/KV cache objects (core.prefix_cache) -- the
        # SAME class the Python stepper uses, so hit/miss decisions are
        # bit-identical by construction.  ``_any_cache`` is a python
        # gate: cache-free pools (every existing workload) never touch
        # the admission-time scalar loop.
        self.lane_cache: List[Optional[PrefixCache]] = []
        self._any_cache = False
        # -- request arena ----------------------------------------------
        self._G = 0
        self._cap_g = arena_cap
        g = arena_cap
        self.prompt = np.zeros(g, np.int64)
        self.dtotal = np.zeros(g, np.int64)
        self.prefilled = np.zeros(g, np.int64)
        self.decoded = np.zeros(g, np.int64)
        self.admit_seq = np.full(g, -1, np.int64)
        self.phase = np.zeros(g, np.int8)
        self.lane = np.full(g, -1, np.int64)
        self.preempts = np.zeros(g, np.int64)
        self.routed_at = np.full(g, np.nan)
        self.prefill_done = np.full(g, np.nan)
        self.first_tok = np.full(g, np.nan)
        self.finished = np.full(g, np.nan)
        self.nemit = np.zeros(g, np.int64)
        self.inv_d = np.zeros(g)
        self.inv_t = np.zeros(g)
        self.capat = np.zeros(g, np.int64)
        self.cachedp = np.zeros(g, np.int64)   # cached-prefix-length lane
        self.objs: List[Request] = []
        # lifecycle tracing: the fused round loop appends PACKED
        # per-round arrays (fancy-index copies of the round's lanes/
        # gids/timestamps) to _trbuf; drain_trace() unpacks them into
        # recorder events at advance/span boundaries so per-event
        # Python work never runs inside the vectorized loop
        self.trace = _trace.NULL
        self._trbuf: List[tuple] = []

    # -- growth ----------------------------------------------------------
    _LANE_1D = ("lane_ep", "lane_local", "failed", "clock", "rts", "qps",
                "outst", "cap", "nslots", "grad1", "grad2", "tdec",
                "tpre", "eps_lat", "speed", "chunk", "sched",
                "admit_ctr", "res_cnt", "pref_cnt", "qhead", "qcnt",
                "lane_ivv")
    _SLOT_2D = ("res_gid", "s_state", "s_prompt", "s_dtotal",
                "s_prefilled", "s_decoded", "s_admit", "s_first",
                "s_pfdone", "s_invd", "s_invt", "s_capat")
    # ``nemit`` is the emission count at (re)admission time; a resident's
    # live total is nemit + s_decoded (every decoded token of the current
    # run emits exactly once), so the hot decode loop never touches it.
    _ARENA = ("prompt", "dtotal", "prefilled", "decoded", "admit_seq",
              "phase", "lane", "preempts", "routed_at", "prefill_done",
              "first_tok", "finished", "nemit", "inv_d", "inv_t",
              "capat", "cachedp")

    @staticmethod
    def _fill_value(name):
        if name in ("routed_at", "prefill_done", "first_tok", "finished"):
            return np.nan
        if name in ("admit_seq", "lane", "res_gid", "q_gid"):
            return -1
        return 0

    def _add_lanes(self, n: int) -> List[int]:
        ids = list(range(self._L, self._L + n))
        for name in self._LANE_1D:
            a = getattr(self, name)
            setattr(self, name,
                    np.concatenate([a, np.zeros(n, a.dtype)]))
        for name in self._SLOT_2D:
            a = getattr(self, name)
            pad = np.full((n, a.shape[1]), self._fill_value(name),
                          a.dtype)
            setattr(self, name, np.concatenate([a, pad]))
        self.q_gid = np.concatenate(
            [self.q_gid, np.full((n, self._Q), -1, np.int64)])
        self.spikes.extend([] for _ in range(n))
        self.lane_profile.extend([None] * n)
        self.lane_cache.extend([None] * n)
        self._L += n
        self._all = np.arange(self._L, dtype=np.int64)
        self._target = np.full(self._L, -np.inf)
        return ids

    def _grow_res(self):
        s = self._S
        for name in self._SLOT_2D:
            a = getattr(self, name)
            pad = np.full((self._L, s), self._fill_value(name), a.dtype)
            setattr(self, name, np.concatenate([a, pad], axis=1))
        self._S = 2 * s

    def _grow_queue(self):
        q = self._Q
        new = np.full((self._L, 2 * q), -1, np.int64)
        for lane in range(self._L):
            c = self.qcnt[lane]
            if c:
                pos = (self.qhead[lane] + np.arange(c)) % q
                new[lane, :c] = self.q_gid[lane, pos]
        self.q_gid = new
        self.qhead[:] = 0
        self._Q = 2 * q

    def _grow_arena(self):
        g = self._cap_g
        for name in self._ARENA:
            a = getattr(self, name)
            b = np.full(2 * g, self._fill_value(name), a.dtype)
            b[:g] = a
            setattr(self, name, b)
        self._cap_g = 2 * g

    # -- episode / lane management --------------------------------------
    def configure_episode(self, ep: int,
                          profiles: Sequence[HardwareProfile],
                          scheduler: str = "fcfs", dt: float = 0.02,
                          chunked_prefill: int = 0,
                          n_slots: Optional[int] = None,
                          prefix_cache_tokens: int = 0,
                          prefix_block: int = 32) -> np.ndarray:
        """(Re)assign lanes for an episode and reset its clocks and
        backlog accumulators.  Reuses freed lanes; grows the pool as
        needed."""
        for lane in self.ep_lanes[ep]:
            # freed lanes must go COLD: stale residents/queues would
            # keep the _tot_* gates, the _next_fin countdown, and the
            # _hw re-tightening pinned hot (correctness is unaffected
            # -- active masks exclude them -- but the fast paths the
            # pool exists for would be silently defeated)
            self._release_lane(int(lane))
            self._free.append(int(lane))
        # drop the previous occupant's Request references: the arena
        # rows stay (cheap), but the Python objects -- and their
        # synthesized token_times -- must not be pinned for the pool's
        # lifetime across a long training run
        for gid in self._ep_gids[ep]:
            self.objs[gid] = None
        self._ep_gids[ep] = []
        m = len(profiles)
        take = [self._free.pop() for _ in range(min(m, len(self._free)))]
        if len(take) < m:
            take += self._add_lanes(m - len(take))
        lanes = np.array(sorted(take), np.int64)
        self.ep_lanes[ep] = lanes
        self._lanes_ver += 1
        self._ep_min_clock[ep] = 0.0
        self.ep_t[ep] = 0.0
        self.ep_dt[ep] = dt
        self.bk_s[ep] = 0.0
        self.bk_t[ep] = 0.0
        for k, (lane, prof) in enumerate(zip(lanes, profiles)):
            self._config_lane(int(lane), ep, k, prof, scheduler,
                              chunked_prefill, n_slots,
                              prefix_cache_tokens, prefix_block)
        return lanes

    def _release_lane(self, lane: int):
        """Retire a lane's occupancy from the python-int gates and
        clear its slot/queue state (idempotent; also run by
        _config_lane at reuse time)."""
        self._tot_q -= int(self.qcnt[lane])
        self._tot_pref -= int(self.pref_cnt[lane])
        self._tot_dec -= int(np.count_nonzero(
            self.s_state[lane] == SS_DECODE))
        self.res_cnt[lane] = 0
        self.pref_cnt[lane] = 0
        self.lane_ivv[lane] = 0.0
        self.qhead[lane] = 0
        self.qcnt[lane] = 0
        self.q_gid[lane] = -1
        self.res_gid[lane] = -1
        self.s_state[lane] = SS_EMPTY
        self.rts[lane] = 0.0
        self.qps[lane] = 0.0
        self.outst[lane] = 0.0
        self.lane_cache[lane] = None

    def _config_lane(self, lane: int, ep: int, local: int,
                     prof: HardwareProfile, scheduler: str,
                     chunked_prefill: int, n_slots: Optional[int],
                     prefix_cache_tokens: int = 0,
                     prefix_block: int = 32):
        self.lane_ep[lane] = ep
        self.lane_local[lane] = local
        self.failed[lane] = False
        self.clock[lane] = 0.0
        self.rts[lane] = 0.0
        self.qps[lane] = 0.0
        self.outst[lane] = 0.0
        self.cap[lane] = prof.capacity_tokens
        self.nslots[lane] = n_slots or prof.max_batch
        self.grad1[lane] = prof.grad1
        self.grad2[lane] = prof.grad2
        self.tdec[lane] = prof.t_decode_base
        self.tpre[lane] = prof.t_prefill_base
        self.eps_lat[lane] = prof.epsilon
        self.speed[lane] = 1.0
        self.chunk[lane] = chunked_prefill
        self.sched[lane] = _SCHED_CODE[scheduler]
        if self.sched[lane] != SCHED_FCFS:
            self._all_fcfs = False
        self.admit_ctr[lane] = 0
        self._release_lane(lane)
        self.spikes[lane] = []
        self.lane_profile[lane] = prof
        self.lane_cache[lane] = (PrefixCache(prefix_cache_tokens,
                                             prefix_block)
                                 if prefix_cache_tokens > 0 else None)
        if prefix_cache_tokens > 0:
            self._any_cache = True

    def extend_episode(self, ep: int, prof: HardwareProfile,
                       scheduler: str, chunked_prefill: int,
                       n_slots: Optional[int],
                       prefix_cache_tokens: int = 0,
                       prefix_block: int = 32) -> int:
        """Elastic scale-out: one more lane for an episode; its clock
        starts at the episode's current time (Cluster.add_instance
        parity)."""
        lane = (self._free.pop() if self._free
                else self._add_lanes(1)[0])
        local = len(self.ep_lanes[ep])
        self._config_lane(lane, ep, local, prof, scheduler,
                          chunked_prefill, n_slots,
                          prefix_cache_tokens, prefix_block)
        self.clock[lane] = self.ep_t[ep]
        self.ep_lanes[ep] = np.append(self.ep_lanes[ep], lane)
        self._lanes_ver += 1
        # the new lane's clock sits at ep_t, which may be BEHIND the
        # episode's cached min-clock bound (existing lanes overshoot
        # ticks); without lowering it the advance() fast path would
        # skip stepping the new lane entirely
        self._ep_min_clock[ep] = min(self._ep_min_clock[ep],
                                     self.clock[lane])
        return lane

    # -- request intake --------------------------------------------------
    def register(self, req: Request, ep: int = 0) -> int:
        if self._G == self._cap_g:
            self._grow_arena()
        g = self._G
        self._G += 1
        self._ep_gids[ep].append(g)
        self.prompt[g] = req.prompt_tokens
        self.dtotal[g] = req.decode_tokens
        self.prefilled[g] = req.prefilled
        self.decoded[g] = req.decoded
        self.phase[g] = _ENUM_TO_PH.get(req.phase, PH_QUEUED)
        self.preempts[g] = req.preemptions
        self.cachedp[g] = req.cached_prefix
        self.objs.append(req)
        return g

    def submit(self, gid: int, lane: int):
        """Route a registered request onto an instance lane
        (SimInstance.submit parity)."""
        self.phase[gid] = PH_IQUEUE
        self.lane[gid] = lane
        self.routed_at[gid] = self.clock[lane]
        self._qpush_right(lane, gid)
        self.qps[lane] += self.prompt[gid]
        self.outst[lane] += self.prompt[gid] + self.dtotal[gid]

    def set_backlog_terms(self, gid: int, ep: int, d_hat: int,
                          inv_t: float):
        """Stamp the RL env's per-request backlog terms; S accrues on
        delivery (RoutingEnv._deliver parity).  ``d_hat`` is the decode
        estimate whose reciprocal scales per-token progress (the T
        contribution saturates once ``decoded >= d_hat``)."""
        self.inv_d[gid] = 1.0 / d_hat
        self.inv_t[gid] = inv_t
        self.capat[gid] = d_hat
        self.bk_s[ep] += inv_t
        self.track = True

    # -- queue ring ------------------------------------------------------
    def _qpush_right(self, lane: int, gid: int):
        if self.qcnt[lane] == self._Q:
            self._grow_queue()
        pos = (self.qhead[lane] + self.qcnt[lane]) % self._Q
        self.q_gid[lane, pos] = gid
        self.qcnt[lane] += 1
        self._tot_q += 1

    def _qpush_left(self, lane: int, gid: int):
        if self.qcnt[lane] == self._Q:
            self._grow_queue()
        self.qhead[lane] = (self.qhead[lane] - 1) % self._Q
        self.q_gid[lane, self.qhead[lane]] = gid
        self.qcnt[lane] += 1
        self._tot_q += 1

    def _qpop_at(self, lane: int, k: int) -> int:
        """Remove the k-th (logical) queue entry, preserving order."""
        q, h, c = self._Q, int(self.qhead[lane]), int(self.qcnt[lane])
        gid = int(self.q_gid[lane, (h + k) % q])
        for j in range(k, c - 1):
            self.q_gid[lane, (h + j) % q] = \
                self.q_gid[lane, (h + j + 1) % q]
        self.qcnt[lane] -= 1
        self._tot_q -= 1
        return gid

    def queue_gids(self, lane: int) -> np.ndarray:
        c = int(self.qcnt[lane])
        pos = (int(self.qhead[lane]) + np.arange(c)) % self._Q
        return self.q_gid[lane, pos]

    def resident_cols(self, lane: int) -> np.ndarray:
        """Occupied slot columns in admission order (Python residents
        list-order parity)."""
        row = self.res_gid[lane]
        cols = np.flatnonzero(row >= 0)
        return cols[np.argsort(self.s_admit[lane, cols])]

    # -- the fused round loop -------------------------------------------
    def advance(self, eps: Sequence[int]) -> Dict[int, List[int]]:
        """Advance each episode's clock by its dt and run every lane of
        every episode to the new time in fused rounds.  Returns
        completed gids per episode (ordering within one call is
        round-major, unlike the Python stepper's instance-major -- no
        consumer depends on intra-tick ordering)."""
        key = tuple(int(e) for e in eps)
        if len(key) == 1:
            # scalar fast path: most advances cover one episode (ticks
            # are 0.02 s but an iteration is >= t_decode_base, so about
            # half the calls find every lane already past the target
            # and must cost almost nothing)
            e = key[0]
            t = self.ep_t[e] + self.ep_dt[e]
            self.ep_t[e] = t
            if self._ep_min_clock[e] >= t:
                return {e: []}
            lanes_all = self.ep_lanes[e]
            done: Dict[int, List[int]] = {e: []}
            if lanes_all.size == 0:
                return done
            self._advance_rounds(lanes_all, done)
            self._ep_min_clock[e] = self.clock[lanes_all].min()
            return done
        cache = self._lanes_cache.get(key)
        if cache is None or cache[0] != self._lanes_ver:
            # single-episode calls returned via the scalar fast path
            lanes_all = np.concatenate([self.ep_lanes[e] for e in key])
            eps_arr = np.asarray(key, np.int64)
            cache = (self._lanes_ver, lanes_all, eps_arr)
            self._lanes_cache[key] = cache
        _, lanes_all, eps_arr = cache
        self.ep_t[eps_arr] = self.ep_t[eps_arr] + self.ep_dt[eps_arr]
        done = {e: [] for e in key}
        if lanes_all.size == 0:
            return done
        if (self._ep_min_clock[eps_arr] >= self.ep_t[eps_arr]).all():
            return done
        self._advance_rounds(lanes_all, done)
        for e in key:
            lanes = self.ep_lanes[e]
            if lanes.size:
                self._ep_min_clock[e] = self.clock[lanes].min()
        return done

    def advance_span(self, spans) -> Dict[int, tuple]:
        """Advance several episodes by SEVERAL ticks each in one fused
        round sequence -- the batched trainer's stepping primitive.

        ``spans`` is a list of ``(ep, boundaries)`` where ``boundaries``
        is the episode's next tick times built by sequential ``t += dt``
        adds (so clock targets match the Python stepper bit for bit).
        Lanes of all episodes iterate in shared rounds toward their
        episode's FINAL boundary; because an engine iteration is
        typically several dt long, lanes that a per-tick advance would
        touch in different calls now coincide in the same round -- this
        is what makes stepping cost O(rounds) instead of
        O(episodes x instances x ticks).

        Returns ``{ep: (completed gids, backlog_reward)}`` where
        ``backlog_reward`` is ``sum over ticks of (T-S) * dt`` with the
        per-tick samples reconstructed from bucketed contributions: a
        round's T/S deltas count toward exactly the samples a per-tick
        stepper would have seen (same values up to float summation
        order, which is already this backend's documented reward-side
        divergence)."""
        done: Dict[int, List[int]] = {}
        pen0 = {}
        k_tot = 0
        offs = {}
        for ep, bounds in spans:
            done[ep] = []
            offs[ep] = k_tot
            k_tot += len(bounds) + 1
            pen0[ep] = float(self.bk_t[ep] - self.bk_s[ep])
        d_flat = np.zeros(k_tot)
        lane_off = np.zeros(self._L, np.int64)
        lane_k = np.zeros(self._L, np.int64)
        span_t0 = np.zeros(self._L)
        target = np.full(self._L, -np.inf)
        for ep, bounds in spans:
            lanes = self.ep_lanes[ep]
            t0 = self.ep_t[ep]
            self.ep_t[ep] = bounds[-1]
            if lanes.size == 0:
                continue
            span_t0[lanes] = t0
            lane_off[lanes] = offs[ep]
            lane_k[lanes] = len(bounds)
            target[lanes] = bounds[-1]
        self._span = (span_t0, lane_off, lane_k, d_flat)
        try:
            self._run_rounds(target, done)
        finally:
            self._span = None
        out = {}
        for ep, bounds in spans:
            lanes = self.ep_lanes[ep]
            if lanes.size:
                self._ep_min_clock[ep] = self.clock[lanes].min()
            k = len(bounds)
            off = offs[ep]
            pen = pen0[ep] + np.cumsum(d_flat[off + 1:off + k + 1])
            out[ep] = (done[ep], float(pen.sum() * self.ep_dt[ep]))
        return out

    def _span_bucket(self, lanes, clocks):
        """Flat d_flat indices for contributions whose iteration starts
        at ``clocks`` on ``lanes`` (full-width or subset aligned)."""
        span_t0, lane_off, lane_k, _ = self._span
        b = np.floor((clocks - span_t0[lanes])
                     / self.ep_dt[self.lane_ep[lanes]]).astype(np.int64) \
            + 1
        np.clip(b, 1, lane_k[lanes], out=b)
        return lane_off[lanes] + b

    def _run_rounds(self, target: np.ndarray,
                    done: Dict[int, List[int]]):
        """Round loop over an explicit full-width target vector.

        This is the backend override point: everything above it
        (enqueue/route/collect, span bookkeeping) is pure SoA state
        manipulation shared by every pooled backend, and everything
        below is the per-round simulation semantics.  ``JaxSimPool``
        (core.jaxsim) overrides ONLY this method to run the same
        rounds as one jitted ``while_loop``; any future backend (e.g.
        an accelerator-resident port) should do the same."""
        behind = self.clock < target
        if behind.any():
            runnable = ((self.res_cnt > 0) | (self.qcnt > 0)) \
                & ~self.failed
            jump = behind & ~runnable
            if jump.any():
                self.clock[jump] = target[jump]
            active = behind & runnable
            while active.any():
                self._iterate(active, done)
                active &= self.clock < target
                if not active.any():
                    break
                dry = active & ~((self.res_cnt > 0)
                                 | (self.qcnt > 0))
                if dry.any():
                    self.clock[dry] = target[dry]
                    active &= ~dry
        if self._trbuf:
            self.drain_trace()

    def _advance_rounds(self, lanes_all: np.ndarray,
                        done: Dict[int, List[int]]):
        # periodically re-tighten the resident column high-water mark
        # (a transient burst can double it and every matrix op pays)
        self._hw_check += 1
        if self._hw_check >= 512:
            self._hw_check = 0
            peak = int(self.res_cnt.max()) if self._L else 0
            if self._hw > 2 * peak + 2:
                occ = (self.res_gid >= 0).any(0)
                self._hw = (int(np.flatnonzero(occ).max()) + 1
                            if occ.any() else 0)
        # full-width target vector (persistent buffer): lanes outside
        # the advance set carry -inf and can never activate.  All
        # round-loop state is held as [L]-wide masks so the hot ops
        # below never fancy-index.
        target = self._target
        target[lanes_all] = self.ep_t[self.lane_ep[lanes_all]]
        self._run_rounds(target, done)
        target[lanes_all] = -np.inf     # stale targets must not linger
        return done

    def _iterate(self, active: np.ndarray, done: Dict[int, List[int]]):
        """One engine iteration on every lane where ``active`` ([L]
        bool) is set -- the vectorized transliteration of
        ``SimInstance._iteration``.  Operating full-width with a mask
        (row index == lane id) keeps every hot op an in-place
        contiguous vector op; inactive lanes contribute zeros and are
        never written (x + 0.0 == x exactly, so clock/rts stay
        bit-identical)."""
        hw = self._hw
        # span reward bucketing reads iteration START clocks after the
        # clock write below, so it needs a real snapshot; the per-tick
        # path only reads clock0 before the write and an alias is free
        clock0 = (self.clock.copy() if self._span is not None
                  else self.clock)
        rts = self.rts                     # rebound before any mutation
        # -- admission: one request per lane if a slot is free ----------
        if self._tot_q:
            can = active & (self.res_cnt < self.nslots) & (self.qcnt > 0)
            al = np.flatnonzero(can)
            if al.size:
                budget = self.cap[al] - rts[al]
                picks = self._sched_pick(al, budget)
                sel = picks >= 0
                if sel.any():
                    al2 = al[sel]
                    gids = self._queue_remove(al2, picks[sel])
                    self.qps[al2] -= self.prompt[gids]
                    seq = self.admit_ctr[al2]
                    self.admit_seq[gids] = seq
                    self.admit_ctr[al2] = seq + 1
                    self.phase[gids] = PH_PREFILL
                    if self._any_cache:
                        # prefix-cache lookups are per-lane scalar ops
                        # (at most one admission per lane per round);
                        # the arena ``prefilled`` must carry the credit
                        # BEFORE _res_insert copies it into the slot
                        for k in range(al2.size):
                            pc = self.lane_cache[int(al2[k])]
                            if pc is None:
                                continue
                            gid = int(gids[k])
                            r = self.objs[gid]
                            if r is None or not r.prefix_hashes:
                                continue
                            cached = pc.admit(int(self.prompt[gid]),
                                              r.prefix_hashes)
                            if cached:
                                self.prefilled[gid] = cached
                                self.cachedp[gid] = cached
                    self._res_insert(al2, gids, seq)
                    hw = self._hw
                    # SimInstance adds the admitted request's
                    # prefilled+decoded to rts here; by the queue
                    # invariant (queued progress is always zero --
                    # preemption resets before requeue) that term is
                    # exactly 0 UNLESS a prefix-cache hit credited the
                    # prompt.  The in-place add below lands on the
                    # ``rts`` alias the it_time expression reads, and
                    # the cached part of the prompt leaves the
                    # outstanding-work sum (it is never prefilled) --
                    # both mirror the scalar stepper; with no cache the
                    # adds are exactly 0 and x + 0.0 == x keeps bits.
                    if self._any_cache:
                        add = self.prefilled[gids] + self.decoded[gids]
                        self.rts[al2] += add
                        self.outst[al2] -= add
                    if self.trace.enabled:
                        # clock0 may alias self.clock here, but the
                        # fancy index copies the pre-advance values
                        self._trbuf.append(
                            ("adm", clock0[al2], al2, gids,
                             self.cachedp[gids]))
        act2 = active[:, None]
        # -- prefill progress (full, or one chunk per iteration) --------
        prefill_tokens = 0
        had_transition = False
        if self._tot_pref:
            st = self.s_state[:, :hw]                    # views
            spf = self.s_prefilled[:, :hw]
            spr = self.s_prompt[:, :hw]
            pref = (st == SS_PREFILL) & act2
            rem = (spr - spf) * pref
            step = np.minimum(self.chunk[:, None], rem) * pref
            # unchunked lanes: only the FIRST (by admission order)
            # prefilling resident runs, for its full remaining prompt
            un = self.chunk == 0
            if un.any():
                aseq = self.s_admit[:, :hw] + (~pref) * _BIG
                first = aseq.argmin(1)
                ustep = np.zeros_like(step)
                rows = np.flatnonzero(un & pref.any(1))
                ustep[rows, first[rows]] = rem[rows, first[rows]]
                if un.all():
                    step = ustep
                else:
                    step = np.where(un[:, None], ustep, step)
            if self.trace.enabled:
                # chunk events only on chunked lanes (SimInstance
                # emits them only when self.chunk is set)
                chm = (step > 0) & (self.chunk[:, None] > 0)
                if chm.any():
                    trl, trc = np.nonzero(chm)
                    self._trbuf.append(
                        ("chunk", clock0[trl], trl,
                         self.res_gid[trl, trc], step[trl, trc]))
            spf += step                                  # in place
            prefill_tokens = step.sum(1)
            fin_pref = pref & (spf >= spr)
            n_tr = int(np.count_nonzero(fin_pref))
            if n_tr:
                had_transition = True
                if self.trace.enabled:
                    trl, trc = np.nonzero(fin_pref)
                    self._trbuf.append(
                        ("pfd", clock0[trl], trl,
                         self.res_gid[trl, trc]))
                st[fin_pref] = SS_DECODE
                pfd = self.s_pfdone[:, :hw]
                pfd[fin_pref] = np.broadcast_to(
                    clock0[:, None], fin_pref.shape)[fin_pref]
                self.pref_cnt -= fin_pref.sum(1)
                self._tot_pref -= n_tr
                self._tot_dec += n_tr
                self._next_fin = 0        # force the completion check
                if self.track:
                    # transitioned residents start contributing their
                    # per-token T increment (all uncapped: decoded==0)
                    ivv = (self.s_invd[:, :hw] * self.s_invt[:, :hw]
                           * fin_pref)
                    self.lane_ivv += ivv.sum(1)
            self.outst -= prefill_tokens
        # -- iteration time + spikes (Fig. 1a); the prefill-base term
        # mirrors HardwareProfile.iteration_time's association order
        # (x + 0.0 == x, so zero-tpre profiles stay bit-identical) ------
        # the straggler factor multiplies the finished sum exactly like
        # SimInstance (x * 1.0 == x, so nominal lanes stay bit-identical)
        it_time = (self.tdec + self.grad1 * prefill_tokens
                   + self.grad2 * rts
                   + self.tpre * (prefill_tokens > 0)) * self.speed
        sp = active & (it_time > 2.0 * self.tdec * self.speed)
        if sp.any():
            for i in np.flatnonzero(sp):
                self.spikes[int(i)].append(float(it_time[i]))
        clock1 = clock0 + it_time
        np.copyto(self.clock, clock1, where=active)
        rts = rts + prefill_tokens
        # -- gang decode ------------------------------------------------
        if self._tot_dec:
            dec = (self.s_state[:, :hw] == SS_DECODE) & act2
            per_lane = dec.sum(1)
            sdec = self.s_decoded[:, :hw]
            sdec += dec                                  # in place
            if had_transition:
                # a first-ever token can only be emitted in a round
                # where some request just finished its prefill (it
                # decodes the same iteration); all other rounds skip
                # the first-token bookkeeping entirely
                sfirst = self.s_first[:, :hw]
                fresh = dec & np.isnan(sfirst)
                if fresh.any():
                    if self.trace.enabled:
                        trl, trc = np.nonzero(fresh)
                        self._trbuf.append(
                            ("ft", clock1[trl], trl,
                             self.res_gid[trl, trc]))
                    sfirst[fresh] = np.broadcast_to(
                        clock1[:, None], fresh.shape)[fresh]
            rts = rts + per_lane
            self.outst -= per_lane
            if self.track:
                # T accrues inv_d*inv_t per decoding-uncapped resident
                # (event-maintained per-lane sums, masked by the round's
                # active lanes) with a correction on the round a
                # request crosses its d_hat cap
                delta = self.lane_ivv * active
                self.bk_t += np.bincount(self.lane_ep, weights=delta,
                                         minlength=self.E)
                if self._span is not None:
                    np.add.at(self._span[3],
                              self._span_bucket(self._all, clock0),
                              delta)
                crossed = dec & (sdec == self.s_capat[:, :hw])
                if crossed.any():
                    cl, cc = np.nonzero(crossed)
                    ivd = self.s_invd[cl, cc]
                    ivt = self.s_invt[cl, cc]
                    capat = self.s_capat[cl, cc]
                    full_tok = ivd * ivt
                    part = (1.0 - (capat - 1) * ivd) * ivt
                    np.add.at(self.bk_t, self.lane_ep[cl],
                              part - full_tok)
                    np.subtract.at(self.lane_ivv, cl, full_tok)
                    if self._span is not None:
                        np.add.at(self._span[3],
                                  self._span_bucket(cl, clock0[cl]),
                                  part - full_tok)
            # -- completions (countdown skips the check on rounds
            #    where no decoding resident can possibly finish) ------
            self._next_fin -= 1
            if self._next_fin <= 0:
                fin = dec & (sdec >= self.s_dtotal[:, :hw])
                np.copyto(self.rts, rts, where=active)
                if fin.any():
                    self._complete(fin, clock0, clock1, done)
                dmask = self.s_state[:, :hw] == SS_DECODE
                if dmask.any():
                    left = (self.s_dtotal[:, :hw] - sdec)[dmask]
                    self._next_fin = int(left.min())
                else:
                    self._next_fin = 10 ** 9
            else:
                np.copyto(self.rts, rts, where=active)
        else:
            np.copyto(self.rts, rts, where=active)
        # -- capacity enforcement: evict newest-admitted ----------------
        over = self.rts > self.cap
        if over.any():
            over &= active & (self.res_cnt > 1)
            for i in np.flatnonzero(over):
                self._preempt_lane(int(i), float(clock0[i]))

    def _complete(self, fin, clock0, clock1, done):
        """Retire finished residents: arena write-back + slot clear.
        ``fin`` is a full-width [L, hw] mask (row index == lane id)."""
        lf, fc = np.nonzero(fin)
        fg = self.res_gid[lf, fc]
        if self.trace.enabled:
            self._trbuf.append(("fin", clock1[lf], lf, fg))
        self.phase[fg] = PH_DONE
        self.finished[fg] = clock1[lf]
        self.prefilled[fg] = self.s_prefilled[lf, fc]
        self.decoded[fg] = self.s_decoded[lf, fc]
        self.first_tok[fg] = self.s_first[lf, fc]
        self.nemit[fg] += self.s_decoded[lf, fc]
        self.prefill_done[fg] = self.s_pfdone[lf, fc]
        drop = (self.s_prefilled[lf, fc] + self.s_decoded[lf, fc]
                ).astype(np.float64)
        if lf.size == 1:
            lane = int(lf[0])
            self.rts[lane] -= drop[0]
            self.res_cnt[lane] -= 1
        else:
            np.subtract.at(self.rts, lf, drop)
            np.subtract.at(self.res_cnt, lf, 1)
        self.res_gid[lf, fc] = -1
        self.s_state[lf, fc] = SS_EMPTY
        self._tot_dec -= lf.size
        if self.track:
            ivt_f = self.inv_t[fg]
            if ivt_f.any():
                ep_idx = self.lane_ep[lf]
                prog = np.minimum(self.decoded[fg] * self.inv_d[fg],
                                  1.0) * ivt_f
                self.bk_s -= np.bincount(ep_idx, weights=ivt_f,
                                         minlength=self.E)
                self.bk_t -= np.bincount(ep_idx, weights=prog,
                                         minlength=self.E)
                if self._span is not None:
                    # a finisher settles T -= prog and S -= inv_t in
                    # the tick its final iteration started
                    np.add.at(self._span[3],
                              self._span_bucket(lf, clock0[lf]),
                              ivt_f - prog)
                # finishers that never hit their d_hat cap stop
                # contributing to the per-round T accrual
                uncap = self.decoded[fg] < self.capat[fg]
                if uncap.any():
                    np.subtract.at(self.lane_ivv, lf,
                                   self.inv_d[fg] * ivt_f * uncap)
        if self._any_cache:
            # completion-time full-chain insert (prompt + reply KV
            # stays cached).  SimInstance inserts in residents
            # (admission) order; np.nonzero yields column order, so
            # same-round finishers are replayed by admit_seq -- a
            # global stable sort preserves each lane's relative order.
            order = (np.argsort(self.admit_seq[fg], kind="stable")
                     if lf.size > 1 else range(lf.size))
            for k in order:
                pc = self.lane_cache[int(lf[k])]
                if pc is None:
                    continue
                r = self.objs[int(fg[k])]
                if r is not None and r.full_hashes:
                    pc.insert(r.full_hashes)
        for lane, gid in zip(lf, fg):
            self._sync_done(int(gid))
            done[int(self.lane_ep[lane])].append(int(gid))

    def _sched_pick(self, lanes: np.ndarray,
                    budget: np.ndarray) -> np.ndarray:
        """Per-lane queue position to admit (or -1), replicating the
        serving.scheduler picks.  FCFS (the default everywhere) is a
        fused head check; the scanning schedulers fall back to a
        per-lane vector scan."""
        if self._all_fcfs:
            head = self.q_gid[lanes, self.qhead[lanes]]
            # queue invariant: queued requests carry zero progress, so
            # the admission cost is exactly the prompt
            fits = self.prompt[head] <= budget
            return fits.astype(np.int64) - 1       # True -> 0, False -> -1
        out = np.full(lanes.size, -1, np.int64)
        fcfs = self.sched[lanes] == SCHED_FCFS
        if fcfs.any():
            lf = lanes[fcfs]
            head = self.q_gid[lf, self.qhead[lf]]
            fits = (self.prompt[head] + self.decoded[head]
                    <= budget[fcfs])
            out[fcfs] = np.where(fits, 0, -1)
        if not fcfs.all():
            for i in np.flatnonzero(~fcfs):
                lane = int(lanes[i])
                gq = self.queue_gids(lane)
                adm = self.prompt[gq] + self.decoded[gq]
                fit = adm <= budget[i]
                if not fit.any():
                    continue
                if self.sched[lane] == SCHED_BIN:
                    size = np.where(
                        fit, self.prompt[gq] + self.dtotal[gq], -1)
                    out[i] = int(np.argmax(size))   # first max: FCFS tie
                else:                                # least_work_left
                    key = np.where(fit, self.dtotal[gq], _BIG)
                    out[i] = int(np.argmin(key))     # first min: FCFS tie
        return out

    def _queue_remove(self, lanes: np.ndarray,
                      pos: np.ndarray) -> np.ndarray:
        gids = np.empty(lanes.size, np.int64)
        h = pos == 0
        if h.all():
            heads = self.qhead[lanes]
            gids = self.q_gid[lanes, heads]
            self.qhead[lanes] = (heads + 1) % self._Q
            self.qcnt[lanes] -= 1
            self._tot_q -= lanes.size
            return gids
        if h.any():
            lh = lanes[h]
            heads = self.qhead[lh]
            gids[h] = self.q_gid[lh, heads]
            self.qhead[lh] = (heads + 1) % self._Q
            self.qcnt[lh] -= 1
            self._tot_q -= int(h.sum())
        for i in np.flatnonzero(~h):
            gids[i] = self._qpop_at(int(lanes[i]), int(pos[i]))
        return gids

    def _res_insert(self, lanes: np.ndarray, gids: np.ndarray,
                    seq: np.ndarray):
        """Load admitted requests from the arena into free slots
        (first-fit column, which keeps occupancy dense under the
        ``_hw`` high-water mark)."""
        while (self.res_cnt[lanes] >= self._S).any():
            self._grow_res()
        if lanes.size == 1:
            lane, gid = int(lanes[0]), int(gids[0])
            col = int((self.res_gid[lane] == -1).argmax())
            self.res_gid[lane, col] = gid
            self.s_state[lane, col] = SS_PREFILL
            self.s_prompt[lane, col] = self.prompt[gid]
            self.s_dtotal[lane, col] = self.dtotal[gid]
            self.s_prefilled[lane, col] = self.prefilled[gid]
            self.s_decoded[lane, col] = self.decoded[gid]
            self.s_admit[lane, col] = seq[0]
            self.s_first[lane, col] = self.first_tok[gid]
            self.s_pfdone[lane, col] = self.prefill_done[gid]
            self.s_invd[lane, col] = self.inv_d[gid]
            self.s_invt[lane, col] = self.inv_t[gid]
            self.s_capat[lane, col] = self.capat[gid]
            self.res_cnt[lane] += 1
            self.pref_cnt[lane] += 1
            self._tot_pref += 1
            self._hw = max(self._hw, col + 1)
            return
        free = (self.res_gid[lanes] == -1).argmax(1)
        self.res_gid[lanes, free] = gids
        self.s_state[lanes, free] = SS_PREFILL
        self.s_prompt[lanes, free] = self.prompt[gids]
        self.s_dtotal[lanes, free] = self.dtotal[gids]
        self.s_prefilled[lanes, free] = self.prefilled[gids]
        self.s_decoded[lanes, free] = self.decoded[gids]
        self.s_admit[lanes, free] = seq
        self.s_first[lanes, free] = self.first_tok[gids]
        self.s_pfdone[lanes, free] = self.prefill_done[gids]
        self.s_invd[lanes, free] = self.inv_d[gids]
        self.s_invt[lanes, free] = self.inv_t[gids]
        self.s_capat[lanes, free] = self.capat[gids]
        self.res_cnt[lanes] += 1
        self.pref_cnt[lanes] += 1
        self._tot_pref += lanes.size
        self._hw = max(self._hw, int(free.max()) + 1)

    def _evict_slot(self, lane: int, col: int) -> int:
        """Remove a resident slot, writing progress back to the arena
        (shared by preemption and fail); returns the gid."""
        gid = int(self.res_gid[lane, col])
        self.prefilled[gid] = self.s_prefilled[lane, col]
        self.decoded[gid] = self.s_decoded[lane, col]
        self.first_tok[gid] = self.s_first[lane, col]
        self.nemit[gid] += self.s_decoded[lane, col]
        self.prefill_done[gid] = self.s_pfdone[lane, col]
        if self.s_state[lane, col] == SS_PREFILL:
            self.pref_cnt[lane] -= 1
            self._tot_pref -= 1
        else:
            self._tot_dec -= 1
            if self.track and self.s_invt[lane, col] \
                    and self.s_decoded[lane, col] < self.s_capat[lane,
                                                                 col]:
                self.lane_ivv[lane] -= (self.s_invd[lane, col]
                                        * self.s_invt[lane, col])
        self.res_gid[lane, col] = -1
        self.s_state[lane, col] = SS_EMPTY
        self.res_cnt[lane] -= 1
        return gid

    def _preempt_lane(self, lane: int, t0: float = 0.0):
        """Newest-admitted eviction until within budget; the oldest
        resident is never evicted (liveness grace).  ``t0`` is the
        containing iteration's start clock (span reward bucketing)."""
        cap = self.cap[lane]
        while self.rts[lane] > cap and self.res_cnt[lane] > 1:
            row = self.res_gid[lane]
            occ = np.flatnonzero(row >= 0)
            col = int(occ[np.argmax(self.s_admit[lane, occ])])
            gid = self._evict_slot(lane, col)
            progress = float(self.prefilled[gid] + self.decoded[gid])
            if self.trace.enabled:
                # SimInstance stamps preemptions at the post-advance
                # clock (the eviction loop runs after the clock write)
                self._trbuf.append(("pre", float(self.clock[lane]),
                                    lane, gid, progress))
            self.rts[lane] -= progress
            self.outst[lane] += progress   # requeued at full size again
            self._reset_progress(gid, t0)
            self._qpush_left(lane, gid)
            self.qps[lane] += self.prompt[gid]

    def _reset_progress(self, gid: int, t0: float = 0.0):
        """Preemption: work is lost (Request.reset_progress parity),
        including the env's backlog T debit."""
        if self.decoded[gid] and self.inv_t[gid]:
            lane = int(self.lane[gid])
            debit = min(self.decoded[gid] * self.inv_d[gid],
                        1.0) * self.inv_t[gid]
            self.bk_t[int(self.lane_ep[lane])] -= debit
            if self._span is not None:
                lanes = np.array([lane])
                idx = self._span_bucket(lanes, np.array([t0]))
                self._span[3][idx[0]] -= debit
        self.prefilled[gid] = 0
        self.decoded[gid] = 0
        self.cachedp[gid] = 0
        self.phase[gid] = PH_PREEMPTED
        self.preempts[gid] += 1

    # -- fault injection -------------------------------------------------
    def fail_lane(self, lane: int) -> List[int]:
        """Node failure: orphaned gids in residents-then-queue order
        (SimInstance.fail parity); lane state cleared."""
        if self.trace.enabled:
            self._trbuf.append(("fail", float(self.clock[lane]), lane))
        orphans = [self._evict_slot(lane, int(c))
                   for c in self.resident_cols(lane)]
        orphans += [int(x) for x in self.queue_gids(lane)]
        self.failed[lane] = True
        self.q_gid[lane] = -1
        self._tot_q -= int(self.qcnt[lane])
        self.qcnt[lane] = 0
        self.qhead[lane] = 0
        self.rts[lane] = 0.0
        self.qps[lane] = 0.0
        self.outst[lane] = 0.0
        self.pref_cnt[lane] = 0
        if self.lane_cache[lane] is not None:
            # the KV pool dies with the node (SimInstance.fail parity)
            self.lane_cache[lane].clear()
        for gid in orphans:
            self._reset_progress(gid)
            self.phase[gid] = PH_QUEUED
            self.lane[gid] = -1
            # the attempt died: clear timing stamps (SimInstance.fail
            # parity) so TTFT/TBT/E2E measure the serving attempt
            self.first_tok[gid] = np.nan
            self.nemit[gid] = 0
            self.prefill_done[gid] = np.nan
            r = self.objs[gid]
            r.prefilled = 0
            r.decoded = 0
            r.cached_prefix = 0
            r.preemptions = int(self.preempts[gid])
            r.phase = Phase.QUEUED
            r.instance = None
            r.first_token = None
            r.token_times = []
            r.prefill_done = None
        if self._trbuf:
            self.drain_trace()   # called between advances
        return orphans

    def recover_lane(self, lane: int, t: Optional[float] = None):
        """Undo fail_lane: the lane comes back *empty* at its clock
        (SimInstance.recover parity).  ``t`` lower-bounds the clock for
        callers recovering between advances (the round loop has already
        fast-forwarded failed lanes, so this is usually a no-op)."""
        if t is not None:
            self.clock[lane] = max(float(self.clock[lane]), float(t))
        self.failed[lane] = False
        if self.trace.enabled:
            self._trbuf.append(("recover", float(self.clock[lane]),
                                lane))
            self.drain_trace()

    def steal_request(self, gid: int) -> bool:
        """Withdraw a routed request for hedged re-dispatch
        (SimInstance.steal parity): remove it from its lane's queue or
        resident slots with the same sum fixups, reset progress and
        timing stamps.  Returns False if the request is no longer on an
        instance (completed this tick)."""
        lane = int(self.lane[gid])
        if lane < 0:
            return False
        if self.phase[gid] in (PH_PREFILL, PH_DECODE):
            cols = np.flatnonzero(self.res_gid[lane] == gid)
            if not cols.size:
                return False
            self._evict_slot(lane, int(cols[0]))
            self.rts[lane] -= self.prefilled[gid] + self.decoded[gid]
            self.outst[lane] -= (
                (self.prompt[gid] - self.prefilled[gid])
                + (self.dtotal[gid] - self.decoded[gid]))
        elif self.phase[gid] == PH_IQUEUE:
            ks = np.flatnonzero(self.queue_gids(lane) == gid)
            if not ks.size:
                return False
            self._qpop_at(lane, int(ks[0]))
            self.qps[lane] -= self.prompt[gid]
            self.outst[lane] -= self.prompt[gid] + self.dtotal[gid]
        else:
            return False
        self._reset_progress(gid)
        self.phase[gid] = PH_QUEUED
        self.lane[gid] = -1
        self.first_tok[gid] = np.nan
        self.nemit[gid] = 0
        self.prefill_done[gid] = np.nan
        r = self.objs[gid]
        if r is not None:
            r.prefilled = 0
            r.decoded = 0
            r.cached_prefix = 0
            r.preemptions = int(self.preempts[gid])
            r.phase = Phase.QUEUED
            r.instance = None
            r.first_token = None
            r.token_times = []
            r.prefill_done = None
        return True

    # -- trace drain -----------------------------------------------------
    def drain_trace(self):
        """Unpack the round loop's packed event buffers into recorder
        events.  Runs once per advance/advance_span call (and after a
        fail_lane), so the per-event Python cost is paid outside the
        fused rounds; head-sampling is applied here by the recorder's
        own rid filter, identical to the Python stepper's inline
        emission."""
        buf = self._trbuf
        self._trbuf = []
        tr = self.trace
        objs = self.objs
        loc = self.lane_local
        for rec in buf:
            kind = rec[0]
            if kind == "fail":
                tr.emit(rec[1], _trace.EV_FAIL, -1, int(loc[rec[2]]))
                continue
            if kind == "recover":
                tr.emit(rec[1], _trace.EV_RECOVER, -1,
                        int(loc[rec[2]]))
                continue
            if kind == "pre":
                _, t, lane, gid, lost = rec
                r = objs[gid]
                if r is not None:
                    tr.emit(t, _trace.EV_PREEMPT, r.rid,
                            int(loc[lane]), r.tenant,
                            {"lost": int(lost)})
                continue
            if kind == "adm":
                _, ts, lanes, gids, cached = rec
                for t, ln, g, c in zip(ts, lanes, gids, cached):
                    r = objs[int(g)]
                    if r is not None:
                        tr.emit(float(t), _trace.EV_INST_ADMIT, r.rid,
                                int(loc[ln]), r.tenant,
                                {"cached": int(c)})
            elif kind == "chunk":
                _, ts, lanes, gids, toks = rec
                for t, ln, g, k in zip(ts, lanes, gids, toks):
                    r = objs[int(g)]
                    if r is not None:
                        tr.emit(float(t), _trace.EV_PREFILL_CHUNK,
                                r.rid, int(loc[ln]), r.tenant,
                                {"tokens": int(k)})
            else:
                etype = (_trace.EV_PREFILL_DONE if kind == "pfd"
                         else _trace.EV_FIRST_TOKEN if kind == "ft"
                         else _trace.EV_COMPLETE)
                _, ts, lanes, gids = rec
                for t, ln, g in zip(ts, lanes, gids):
                    r = objs[int(g)]
                    if r is not None:
                        tr.emit(float(t), etype, r.rid,
                                int(loc[ln]), r.tenant)

    # -- object sync -----------------------------------------------------
    def _sync_done(self, gid: int):
        r = self.objs[gid]
        r.phase = Phase.DONE
        r.prefilled = int(self.prefilled[gid])
        r.decoded = int(self.decoded[gid])
        r.cached_prefix = int(self.cachedp[gid])
        r.preemptions = int(self.preempts[gid])
        r.admitted_idx = int(self.admit_seq[gid])
        lane = int(self.lane[gid])
        r.instance = int(self.lane_local[lane])
        r.routed_at = float(self.routed_at[gid])
        r.prefill_done = float(self.prefill_done[gid])
        first = float(self.first_tok[gid])
        r.first_token = None if np.isnan(first) else first
        r.finished = float(self.finished[gid])
        ne = int(self.nemit[gid])
        # evenly-spaced synthesis between the true first and last
        # emission (the last token's time IS the finish time):
        # Request.tbt telescopes to (last-first)/(n-1), which is exact;
        # only per-token jitter (bench_table3's gap variance) is lost.
        if ne >= 2:
            step = (r.finished - first) / (ne - 1)
            r.token_times = (first + step * np.arange(ne)).tolist()
        elif ne == 1:
            r.token_times = [first]

    def sync_request(self, gid: int):
        """Write live (possibly in-flight) arena state back to the
        Python Request object.  Residents are synced through their
        slot-matrix state (the arena is stale while resident)."""
        if self.phase[gid] == PH_DONE:
            self._sync_done(gid)
            return
        r = self.objs[gid]
        lane = int(self.lane[gid])
        if self.phase[gid] in (PH_PREFILL, PH_DECODE) and lane >= 0:
            row = self.res_gid[lane]
            cols = np.flatnonzero(row == gid)
            if cols.size:
                c = int(cols[0])
                r.prefilled = int(self.s_prefilled[lane, c])
                r.decoded = int(self.s_decoded[lane, c])
                # cachedp never changes while resident, so the arena
                # lane is current even though slot state is live
                r.cached_prefix = int(self.cachedp[gid])
                r.phase = (Phase.PREFILL
                           if self.s_state[lane, c] == SS_PREFILL
                           else Phase.DECODE)
                first = float(self.s_first[lane, c])
                r.first_token = None if np.isnan(first) else first
                pfd = float(self.s_pfdone[lane, c])
                if not np.isnan(pfd):
                    r.prefill_done = pfd
                r.admitted_idx = int(self.s_admit[lane, c])
                r.preemptions = int(self.preempts[gid])
                r.instance = int(self.lane_local[lane])
                r.routed_at = float(self.routed_at[gid])
                return
        r.phase = _PH_TO_ENUM[self.phase[gid]]
        r.prefilled = int(self.prefilled[gid])
        r.decoded = int(self.decoded[gid])
        r.cached_prefix = int(self.cachedp[gid])
        r.preemptions = int(self.preempts[gid])
        r.instance = int(self.lane_local[lane]) if lane >= 0 else None
        if lane >= 0:
            r.routed_at = float(self.routed_at[gid])
        if not np.isnan(self.first_tok[gid]):
            r.first_token = float(self.first_tok[gid])
        if not np.isnan(self.prefill_done[gid]):
            r.prefill_done = float(self.prefill_done[gid])


class VecInstanceView:
    """Read surface of one lane, SimInstance-compatible: O(1) token
    sums for the routing policies and the featurizer, materialized
    (and synced) Request lists only when legacy code actually scans
    ``residents`` / ``queue``."""

    def __init__(self, pool: VecSimPool, lane: int, instance_id: int):
        self.pool = pool
        self.lane = lane
        self.instance_id = instance_id
        # SimInstance hook-surface compatibility (unused on vec: the
        # pool maintains the backlog accumulators itself)
        self.on_token = None
        self.on_preempt = None

    # -- identity / profile ---------------------------------------------
    @property
    def profile(self) -> HardwareProfile:
        return self.pool.lane_profile[self.lane]

    @property
    def failed(self) -> bool:
        return bool(self.pool.failed[self.lane])

    @property
    def n_slots(self) -> int:
        return int(self.pool.nslots[self.lane])

    @property
    def clock(self) -> float:
        return float(self.pool.clock[self.lane])

    @clock.setter
    def clock(self, t: float):
        self.pool.clock[self.lane] = t

    @property
    def spikes(self) -> List[float]:
        return self.pool.spikes[self.lane]

    @property
    def prefix_cache(self):
        """The lane's PrefixCache (None when the cache model is off);
        the SAME object the stepping code mutates, so policy/featurizer
        hit-fraction queries are bit-identical to the py backend."""
        return self.pool.lane_cache[self.lane]

    # -- router-visible state -------------------------------------------
    def resident_token_sum(self) -> float:
        return float(self.pool.rts[self.lane])

    def queued_prompt_sum(self) -> float:
        return float(self.pool.qps[self.lane])

    def outstanding_tokens(self) -> float:
        return float(self.pool.outst[self.lane])

    def free_tokens(self) -> float:
        p = self.pool
        return float(p.cap[self.lane] - p.rts[self.lane]
                     - p.qps[self.lane])

    def earliest_completion(self) -> float:
        p = self.pool
        row = p.res_gid[self.lane]
        occ = row >= 0
        if not occ.any():
            return 0.0
        left = int((p.s_dtotal[self.lane][occ]
                    - p.s_decoded[self.lane][occ]).min())
        return max(left, 0) * p.tdec[self.lane]

    @property
    def residents(self) -> List[Request]:
        p = self.pool
        out = []
        for c in p.resident_cols(self.lane):
            gid = int(p.res_gid[self.lane, c])
            p.sync_request(gid)
            out.append(p.objs[gid])
        return out

    @property
    def queue(self) -> List[Request]:
        p = self.pool
        out = []
        for gid in p.queue_gids(self.lane):
            p.sync_request(int(gid))
            out.append(p.objs[int(gid)])
        return out

    def load_summary(self) -> Dict:
        res = self.residents
        return {
            "n_resident": len(res),
            "n_queued": int(self.pool.qcnt[self.lane]),
            "p_tokens": [r.prompt_tokens for r in res],
            "d_tokens": [r.decoded for r in res],
            "resident_tokens": self.resident_token_sum(),
            "free_tokens": self.free_tokens(),
            "earliest_completion": self.earliest_completion(),
            "clock": self.clock,
        }

    @property
    def speed_factor(self) -> float:
        return float(self.pool.speed[self.lane])

    @speed_factor.setter
    def speed_factor(self, f: float):
        self.pool.speed[self.lane] = f

    def recover(self):
        self.pool.recover_lane(self.lane)

    def restore(self):
        self.pool.failed[self.lane] = False


class VecCluster:
    """Cluster-protocol view over (one episode of) a VecSimPool.

    Constructed directly (``Cluster(..., backend="vec")`` routes here)
    it owns a private single-episode pool; the batched RL trainer
    instead passes a shared ``pool`` + ``ep`` so all its episodes'
    instances advance in the same fused rounds."""

    is_vec = True

    def __init__(self, profile, n_instances: int,
                 scheduler: str = "fcfs", dt: float = 0.02,
                 chunked_prefill: int = 0,
                 n_slots: Optional[int] = None,
                 pool: Optional[VecSimPool] = None, ep: int = 0,
                 prefix_cache_tokens: int = 0, prefix_block: int = 32,
                 trace=None):
        if isinstance(profile, HardwareProfile):
            profiles = [profile] * n_instances
        else:
            profiles = list(profile)
            if len(profiles) != n_instances:
                raise ValueError(
                    f"{len(profiles)} profiles for {n_instances} "
                    "instances")
        self.pool = pool or VecSimPool(1)
        if trace is not None:
            # pool-level: a shared-pool trainer would trace ALL its
            # episodes' lanes; the gateway/cluster path owns a private
            # single-episode pool, so lane set == this cluster
            self.pool.trace = trace
        self.ep = ep
        self.dt = dt
        self._prefix_cache_tokens = prefix_cache_tokens
        self._prefix_block = prefix_block
        self.lane_ids = self.pool.configure_episode(
            ep, profiles, scheduler, dt, chunked_prefill, n_slots,
            prefix_cache_tokens=prefix_cache_tokens,
            prefix_block=prefix_block)
        self.profile = profiles[0]
        self.profiles = tuple(profiles)
        self.instances = [VecInstanceView(self.pool, int(lane), i)
                          for i, lane in enumerate(self.lane_ids)]
        self.central: deque = deque()
        self.completed: List[Request] = []
        self.queue_len_trace: List[int] = []
        self._gid: Dict[int, int] = {}        # rid -> arena gid

    @property
    def m(self) -> int:
        return len(self.instances)

    @property
    def t(self) -> float:
        return float(self.pool.ep_t[self.ep])

    def gid_of(self, req: Request) -> int:
        return self._gid[req.rid]

    def alive(self) -> List[int]:
        failed = self.pool.failed[self.lane_ids]
        return [i for i in range(self.m) if not failed[i]]

    def enqueue(self, req: Request):
        req.phase = Phase.QUEUED
        if req.rid not in self._gid:
            self._gid[req.rid] = self.pool.register(req, self.ep)
        self.central.append(req)

    def route(self, idx: int) -> Request:
        req = self.central.popleft()
        gid = self._gid[req.rid]
        self.pool.submit(gid, int(self.lane_ids[idx]))
        # keep the object's routing fields live (policies may read them)
        req.phase = Phase.INSTANCE_QUEUE
        req.instance = idx
        req.routed_at = float(self.pool.routed_at[gid])
        return req

    def advance(self) -> List[Request]:
        """Advance the episode clock by dt; returns completions."""
        done_map = self.pool.advance([self.ep])
        return self.collect(done_map[self.ep])

    def collect(self, gids: List[int]) -> List[Request]:
        """Turn completed gids into (already-synced) Request objects
        and fold them into the episode bookkeeping -- shared by
        advance() and the batched trainer's fused advance."""
        done = [self.pool.objs[g] for g in gids]
        self.completed.extend(done)
        self.queue_len_trace.append(len(self.central))
        return done

    def collect_span(self, gids: List[int], n_ticks: int
                     ) -> List[Request]:
        """collect() for a multi-tick span advance (the central queue
        cannot change inside a span, so the trace entries repeat)."""
        done = [self.pool.objs[g] for g in gids]
        self.completed.extend(done)
        self.queue_len_trace.extend([len(self.central)] * n_ticks)
        return done

    def add_instance(self, scheduler: str = "fcfs",
                     chunked_prefill: int = 0,
                     profile: Optional[HardwareProfile] = None) -> int:
        lane = self.pool.extend_episode(
            self.ep, profile or self.profile, scheduler,
            chunked_prefill, None,
            prefix_cache_tokens=self._prefix_cache_tokens,
            prefix_block=self._prefix_block)
        idx = len(self.instances)
        self.instances.append(VecInstanceView(self.pool, lane, idx))
        self.lane_ids = self.pool.ep_lanes[self.ep]
        self.profiles = self.profiles + (profile or self.profile,)
        return idx

    def fail_instance(self, idx: int, requeue: bool = True
                      ) -> List[Request]:
        orphans = [self.pool.objs[gid]
                   for gid in self.pool.fail_lane(
                       int(self.lane_ids[idx]))]
        if requeue:
            for r in orphans:
                self.central.appendleft(r)
        return orphans

    def recover_instance(self, idx: int):
        self.pool.recover_lane(int(self.lane_ids[idx]), self.t)

    def set_speed_factor(self, idx: int, factor: float):
        self.pool.speed[int(self.lane_ids[idx])] = float(factor)

    def steal(self, req: Request) -> bool:
        gid = self._gid.get(req.rid)
        if gid is None:
            return False
        return self.pool.steal_request(gid)

    def set_trace(self, trace):
        """Attach a TraceRecorder after construction (Cluster parity)."""
        self.pool.trace = trace

    def sync_all(self):
        """Write every registered request's arena state back to its
        Python object (episode-end reporting)."""
        for gid in self._gid.values():
            self.pool.sync_request(gid)
