"""Radix-style longest-prefix KV cache model (per instance).

Real engines (vLLM with ``enable_prefix_caching``, SGLang's radix tree)
keep the KV blocks of recently served prompts; a new request whose
prompt shares a cached prefix pays prefill only for the uncached
suffix.  For routing, that makes placement *history-dependent*: the
same request is cheap on the instance that served the previous turn of
its conversation and expensive anywhere else -- the affinity signal
the cache-aware policies and the RL state feature consume.

The model is deliberately minimal and fully deterministic:

  * prompts are identified by a chain of per-block content hashes
    (``Request.prefix_hashes``; block = ``block`` tokens, vLLM's
    block-hash scheme).  Like vLLM, a block's hash covers its whole
    prefix, so chains form a radix tree keyed by hash equality --
    matching is "longest shared prefix of two hash chains";
  * ``admit`` returns the cached-token credit for an admission, capped
    at ``prompt_tokens - 1`` (at least one token must be prefilled so
    the engine produces the first logits -- vLLM has the same rule),
    and inserts the prompt's own chain (its blocks are resident after
    the prefill);
  * LRU eviction under a token budget.  Chains are touched
    deepest-block-first, so a parent block is always at least as
    recent as any of its children and LRU eviction removes leaves
    before the prefixes they extend (the radix invariant);
  * the SAME object (plain dict ops, no clocks, no floats) backs the
    Python stepper, a vecsim lane, and the real engine, so hit/miss
    decisions are bit-identical across backends by construction.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence


class PrefixCache:
    """LRU cache over prefix block hashes under a token budget."""

    __slots__ = ("capacity_tokens", "block", "_blocks", "hit_tokens",
                 "lookup_tokens")

    def __init__(self, capacity_tokens: int, block: int = 32):
        if block <= 0:
            raise ValueError(f"block must be positive, got {block}")
        self.capacity_tokens = int(capacity_tokens)
        self.block = int(block)
        self._blocks: OrderedDict = OrderedDict()   # hash -> None (LRU@front)
        # cumulative admission-time stats (exact integers on every
        # backend; benchmarks report hit_tokens / lookup_tokens)
        self.hit_tokens = 0
        self.lookup_tokens = 0

    def __len__(self) -> int:
        return len(self._blocks)

    @property
    def cached_token_count(self) -> int:
        return len(self._blocks) * self.block

    # -- read-only queries (policies / state features) -------------------
    def match(self, hashes: Optional[Sequence]) -> int:
        """Longest cached prefix, in blocks.  Never touches LRU order,
        so featurizing/scoring a request cannot perturb the simulation."""
        if not hashes:
            return 0
        blocks = self._blocks
        n = 0
        for h in hashes:
            if h not in blocks:
                break
            n += 1
        return n

    def cached_tokens(self, prompt_tokens: int,
                      hashes: Optional[Sequence]) -> int:
        """The prefill credit an admission *would* get right now."""
        n = self.match(hashes)
        if not n:
            return 0
        return min(n * self.block, max(int(prompt_tokens) - 1, 0))

    def hit_fraction(self, prompt_tokens: int,
                     hashes: Optional[Sequence]) -> float:
        p = int(prompt_tokens)
        if p <= 0:
            return 0.0
        return self.cached_tokens(p, hashes) / p

    # -- mutations (admission / completion) ------------------------------
    def insert(self, hashes: Optional[Sequence]):
        """Touch-or-add a whole chain, deepest block first (a parent is
        always at least as recent as its children), then evict LRU
        blocks until back under the token budget."""
        if not hashes:
            return
        blocks = self._blocks
        for h in reversed(hashes):
            if h in blocks:
                blocks.move_to_end(h)
            else:
                blocks[h] = None
        budget = self.capacity_tokens
        b = self.block
        while len(blocks) * b > budget:
            blocks.popitem(last=False)

    def admit(self, prompt_tokens: int,
              hashes: Optional[Sequence]) -> int:
        """Admission: credit the cached prefix, record stats, and
        insert the prompt's own chain (its KV is resident after this
        prefill).  Returns the credited token count."""
        if not hashes:
            return 0
        p = int(prompt_tokens)
        cached = self.cached_tokens(p, hashes)
        self.hit_tokens += cached
        self.lookup_tokens += p
        self.insert(hashes)
        return cached

    def clear(self):
        """Instance failure: the KV pool (and its cached prefixes) is
        gone.  Lifetime stats survive a restart."""
        self._blocks.clear()


def hit_fractions(cluster, req) -> "list":
    """Prospective per-instance hit fraction of ``req`` on every
    instance of a Cluster-protocol backend (py, vec, or engine
    adapter).  Read-only; instances without a cache (or a request
    without hashes) score 0.  The scalar loop is shared by every
    caller -- mixing_scores, the sticky policy, and both featurize
    paths -- so the produced floats are identical everywhere."""
    hashes = getattr(req, "prefix_hashes", None)
    p = req.prompt_tokens
    out = [0.0] * cluster.m
    if not hashes or p <= 0:
        return out
    for i, inst in enumerate(cluster.instances):
        pc = getattr(inst, "prefix_cache", None)
        if pc is not None:
            out[i] = pc.hit_fraction(p, hashes)
    return out
