"""Device-resident jitted round loop over the vecsim SoA layout.

``JaxSimPool`` subclasses :class:`repro.core.vecsim.VecSimPool` and
overrides exactly one method — ``_run_rounds`` — the single choke
point both per-tick ``advance`` and the batched trainer's
``advance_span`` flow through.  Everything else (episode/lane
management, the request arena, queue rings, fail/recover/steal,
``VecCluster`` views, trace draining) is inherited unchanged, so all
host-side reads between spans (featurize, policy scores, masks) hit
the same synced numpy arrays and decision parity with the numpy
backend holds by construction.

The override stages the pool's live state onto the device once per
``_run_rounds`` call, runs the WHOLE round sequence inside a single
jitted ``lax.while_loop`` (masked admission, chunked-prefill progress,
gang decode, spike detection, newest-first preemption, backlog/reward
bucketing — a line-for-line transliteration of
``VecSimPool._iterate``), and syncs the results back.  Per-span cost
becomes one device dispatch instead of O(rounds) numpy passes.

Parity contract (gated by ``tests/test_jaxsim.py`` and
``benchmarks/bench_jaxsim.py``):

  * decisions, clocks, TTFT, preemptions, per-request token counts:
    **bit-exact** vs the numpy vecsim (which is itself bit-exact vs
    the Python stepper).  Everything decision-relevant is integer
    arithmetic or identically-associated float expressions.
  * rewards (the backlog S/T accumulators and span bucket sums):
    equal up to float SUMMATION ORDER — the jitted loop reduces
    per-lane/episode contributions with ``segment_sum`` where the
    numpy path runs sequential ``np.add.at`` element loops.  This is
    the SAME documented tolerance class as the existing py-vs-vec
    contract (see vecsim's module docstring); tests assert rewards to
    1e-9 relative.
  * spike VALUES are not materialized on the device path (counts are
    — every consumer in the repo counts ``len(spikes)``); the host
    lists are padded with ``nan`` placeholders per detected spike.

Graceful fallback: lifecycle tracing, prefix-cache admission (per-lane
radix-tree walks are inherently host-side), spans longer than
``SPAN_BUCKETS-1`` ticks, and sub-``min_span_ticks`` spans (dispatch
overhead would dominate) all route to the inherited numpy
``_run_rounds`` — bit-identical results either way, so mixing paths
within one episode is safe.

Arena compaction: the request arena grows monotonically (thousands of
rows over a training run) while only queued+resident requests are
touchable by a round.  Each call gathers those candidate rows into a
compact ``[C_pad]`` block (power-of-two padded to bound retraces),
remaps gids, and scatters results back — device transfer stays
proportional to live requests, not arena capacity.  Masked arena
writes use an out-of-bounds sink index with ``mode='drop'``.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import enable_x64
from jax.ops import segment_sum

from repro.core.vecsim import (
    PH_DONE, PH_PREEMPTED, PH_PREFILL, SCHED_BIN, SCHED_FCFS,
    SS_DECODE, SS_EMPTY, SS_PREFILL, VecSimPool, _BIG,
)

# d_lane reward-bucket columns: col 0 is the discard bucket (lanes
# outside any span clip there), cols 1..SPAN_BUCKETS-1 are span ticks.
# Matches RoutingEnv._span_bounds(cap=256) and the bench drivers'
# SPAN_CAP=256 — longer spans fall back to the numpy path.
SPAN_BUCKETS = 257


def _round_body(ro, c):
    """One fused engine iteration on every active lane — the jitted
    transliteration of ``VecSimPool._iterate`` (same phase order:
    admission, prefill, clock/spike, gang decode + backlog, completion,
    preemption, dry-lane jump)."""
    L, S = c["res_gid"].shape
    Q = c["q"].shape[1]
    C_pad = ro["prompt"].shape[0]
    E = c["bk_t"].shape[0]
    iota_l = jnp.arange(L)
    iota_s = jnp.arange(S)
    iota_q = jnp.arange(Q)

    active = c["active"]
    clock0 = c["clock"]
    rts0 = c["rts"]
    res_gid, st = c["res_gid"], c["s_state"]
    s_prompt, s_dtotal = c["s_prompt"], c["s_dtotal"]
    s_prefilled, s_decoded = c["s_prefilled"], c["s_decoded"]
    s_admit, s_first = c["s_admit"], c["s_first"]
    s_pfdone, s_invd = c["s_pfdone"], c["s_invd"]
    s_invt, s_capat = c["s_invt"], c["s_capat"]
    q, qcnt = c["q"], c["qcnt"]
    res_cnt, pref_cnt = c["res_cnt"], c["pref_cnt"]
    qps, outst = c["qps"], c["outst"]
    lane_ivv, bk_s, bk_t = c["lane_ivv"], c["bk_s"], c["bk_t"]
    d_lane = c["d_lane"]
    prefilled_c, decoded_c = c["a_prefilled"], c["a_decoded"]
    admit_seq_c, phase_c = c["a_admit_seq"], c["a_phase"]
    preempts_c, first_tok_c = c["a_preempts"], c["a_first_tok"]
    prefill_done_c, finished_c = c["a_prefill_done"], c["a_finished"]
    nemit_c = c["a_nemit"]

    # -- admission: one request per lane if a slot is free ------------
    can = active & (res_cnt < ro["nslots"]) & (qcnt > 0)
    budget = ro["cap"] - rts0
    valid = iota_q[None, :] < qcnt[:, None]
    gq_safe = jnp.where(valid, q, 0)
    # queue invariant: queued progress is zero (preemption resets
    # before requeue), so decoded_c adds exactly 0 on the fcfs path too
    adm_cost = ro["prompt"][gq_safe] + decoded_c[gq_safe]
    fit = valid & (adm_cost <= budget[:, None])
    any_fit = fit.any(1)
    pick_fcfs = jnp.where(fit[:, 0], 0, -1)
    size = jnp.where(fit, ro["prompt"][gq_safe] + ro["dtotal"][gq_safe],
                     -1)
    pick_bin = jnp.where(any_fit, jnp.argmax(size, 1), -1)  # first max
    work = jnp.where(fit, ro["dtotal"][gq_safe], _BIG)
    pick_lwl = jnp.where(any_fit, jnp.argmin(work, 1), -1)  # first min
    pick = jnp.where(ro["sched"] == SCHED_FCFS, pick_fcfs,
                     jnp.where(ro["sched"] == SCHED_BIN, pick_bin,
                               pick_lwl))
    admit = can & (pick >= 0)
    pick_s = jnp.maximum(pick, 0)
    gid_adm = jnp.take_along_axis(q, pick_s[:, None], 1)[:, 0]
    gid_safe = jnp.where(admit, gid_adm, 0)
    g_adm = jnp.where(admit, gid_adm, C_pad)           # drop sink
    # logical-order removal of the picked position
    keep = jnp.minimum(iota_q[None, :]
                       + (iota_q[None, :] >= pick_s[:, None]), Q - 1)
    q = jnp.where(admit[:, None], jnp.take_along_axis(q, keep, 1), q)
    qcnt = qcnt - admit
    qps = qps - ro["prompt"][gid_safe] * admit
    seq = c["admit_ctr"]
    admit_seq_c = admit_seq_c.at[g_adm].set(seq, mode="drop")
    admit_ctr = c["admit_ctr"] + admit
    phase_c = phase_c.at[g_adm].set(PH_PREFILL, mode="drop")
    # first-fit slot insert (S is preconditioned host-side, so an
    # admitting lane always has a free column)
    col = jnp.argmax(res_gid == -1, axis=1)

    def _ins(m, val):
        return m.at[iota_l, col].set(
            jnp.where(admit, val, m[iota_l, col]))

    res_gid = _ins(res_gid, gid_adm)
    st = _ins(st, jnp.full(L, SS_PREFILL, st.dtype))
    s_prompt = _ins(s_prompt, ro["prompt"][gid_safe])
    s_dtotal = _ins(s_dtotal, ro["dtotal"][gid_safe])
    s_prefilled = _ins(s_prefilled, prefilled_c[gid_safe])
    s_decoded = _ins(s_decoded, decoded_c[gid_safe])
    s_admit = _ins(s_admit, seq)
    s_first = _ins(s_first, first_tok_c[gid_safe])
    s_pfdone = _ins(s_pfdone, prefill_done_c[gid_safe])
    s_invd = _ins(s_invd, ro["inv_d"][gid_safe])
    s_invt = _ins(s_invt, ro["inv_t"][gid_safe])
    s_capat = _ins(s_capat, ro["capat"][gid_safe])
    res_cnt = res_cnt + admit
    pref_cnt = pref_cnt + admit
    # (no prefix cache on this path: admitted progress is exactly 0,
    # so the scalar stepper's rts/outst adjustment is a no-op)

    act2 = active[:, None]
    # -- prefill progress (full, or one chunk per iteration) ----------
    pref = (st == SS_PREFILL) & act2
    rem = (s_prompt - s_prefilled) * pref
    step = jnp.minimum(ro["chunk"][:, None], rem) * pref
    # unchunked lanes: only the FIRST (by admission order) prefilling
    # resident runs, for its full remaining prompt
    aseq = s_admit + (~pref) * _BIG
    firstc = jnp.argmin(aseq, 1)
    ustep = jnp.zeros_like(step).at[iota_l, firstc].set(
        jnp.where(pref.any(1), rem[iota_l, firstc], 0))
    step = jnp.where((ro["chunk"] == 0)[:, None], ustep, step)
    s_prefilled = s_prefilled + step
    prefill_tokens = step.sum(1)
    fin_pref = pref & (s_prefilled >= s_prompt)
    st = jnp.where(fin_pref, SS_DECODE, st)
    s_pfdone = jnp.where(fin_pref, clock0[:, None], s_pfdone)
    pref_cnt = pref_cnt - fin_pref.sum(1)
    lane_ivv = lane_ivv + (s_invd * s_invt * fin_pref).sum(1)
    outst = outst - prefill_tokens

    # -- iteration time + spikes (same association order as the numpy
    # -- expression, so nominal/zero-tpre lanes stay bit-identical).
    # XLA:CPU unconditionally lets the LLVM backend contract mul+add
    # into FMA (TargetOptions AllowFPOpFusion=Fast, no flag), which
    # rounds once where numpy rounds twice and drifts the clock by
    # 1 ulp.  Adding the RUNTIME zero ``ro["fp_zero"]`` after each
    # product forces the rounding boundary: the compiler cannot fold
    # ``x + z`` (z is a parameter), and any fma it forms around the
    # zero term is exact.  All terms are non-negative, so +0.0 cannot
    # flip a signed zero. -----------------------------------------------
    z = ro["fp_zero"]
    it_time = (ro["tdec"] + (ro["grad1"] * prefill_tokens + z)
               + (ro["grad2"] * rts0 + z)
               + (ro["tpre"] * (prefill_tokens > 0) + z)
               ) * ro["speed"] + z
    spike = active & (it_time > 2.0 * ro["tdec"] * ro["speed"])
    spike_cnt = c["spike_cnt"] + spike
    clock1 = clock0 + it_time
    clock = jnp.where(active, clock1, clock0)
    rts1 = rts0 + prefill_tokens

    # -- gang decode + backlog T accrual ------------------------------
    dec = (st == SS_DECODE) & act2
    per_lane = dec.sum(1)
    s_decoded = s_decoded + dec
    fresh = dec & jnp.isnan(s_first)
    s_first = jnp.where(fresh, clock1[:, None], s_first)
    rts2 = rts1 + per_lane
    outst = outst - per_lane
    delta = lane_ivv * active
    bk_t = bk_t + segment_sum(delta, ro["lane_ep"], num_segments=E)
    # span reward bucket for every contribution whose iteration starts
    # at clock0; lanes outside a span clip to the discard column 0
    b_all = jnp.clip(
        jnp.floor((clock0 - ro["span_t0"])
                  / ro["ep_dt_lane"]).astype(jnp.int64) + 1,
        1, ro["lane_k"])
    d_lane = d_lane.at[iota_l, b_all].add(delta)
    crossed = dec & (s_decoded == s_capat)
    full_tok = s_invd * s_invt
    part = (1.0 - (s_capat - 1) * s_invd) * s_invt
    corr_lane = ((part - full_tok) * crossed).sum(1)
    bk_t = bk_t + segment_sum(corr_lane, ro["lane_ep"], num_segments=E)
    lane_ivv = lane_ivv - (full_tok * crossed).sum(1)
    d_lane = d_lane.at[iota_l, b_all].add(corr_lane)

    # -- completions --------------------------------------------------
    fin = dec & (s_decoded >= s_dtotal)
    g_fin = jnp.where(fin, res_gid, C_pad)
    phase_c = phase_c.at[g_fin].set(PH_DONE, mode="drop")
    finished_c = finished_c.at[g_fin].set(
        jnp.broadcast_to(clock1[:, None], (L, S)), mode="drop")
    prefilled_c = prefilled_c.at[g_fin].set(s_prefilled, mode="drop")
    decoded_c = decoded_c.at[g_fin].set(s_decoded, mode="drop")
    first_tok_c = first_tok_c.at[g_fin].set(s_first, mode="drop")
    nemit_c = nemit_c.at[g_fin].add(s_decoded, mode="drop")
    prefill_done_c = prefill_done_c.at[g_fin].set(s_pfdone, mode="drop")
    done_round = c["done_round"].at[g_fin].set(c["round_no"],
                                               mode="drop")
    done_col = c["done_col"].at[g_fin].set(
        jnp.broadcast_to(iota_s[None, :], (L, S)), mode="drop")
    drop_sum = ((s_prefilled + s_decoded) * fin).sum(1)
    rts = jnp.where(active, rts2 - drop_sum, rts0)
    res_cnt = res_cnt - fin.sum(1)
    # backlog settle: T -= progress, S -= inv_t, bucketed at the tick
    # the final iteration started; uncapped finishers leave lane_ivv
    prog = jnp.minimum(s_decoded * s_invd, 1.0) * s_invt
    bk_s = bk_s - segment_sum((s_invt * fin).sum(1), ro["lane_ep"],
                              num_segments=E)
    bk_t = bk_t - segment_sum((prog * fin).sum(1), ro["lane_ep"],
                              num_segments=E)
    d_lane = d_lane.at[iota_l, b_all].add(((s_invt - prog) * fin).sum(1))
    uncap = fin & (s_decoded < s_capat)
    lane_ivv = lane_ivv - (s_invd * s_invt * uncap).sum(1)
    res_gid = jnp.where(fin, -1, res_gid)
    st = jnp.where(fin, SS_EMPTY, st)

    # -- capacity enforcement: evict newest-admitted ------------------
    # closed form of the sequential loop: sort residents newest-first
    # (admit seq strictly increases per lane, so no ties), evict the
    # smallest prefix k whose progress sum brings rts within cap,
    # bounded by res_cnt-1 (the oldest resident is never evicted).
    # All quantities integer-valued f64 / int64, so prefix sums match
    # the loop's sequential subtractions bit for bit.
    over = (rts > ro["cap"]) & active & (res_cnt > 1)
    occ = res_gid >= 0
    keys = jnp.where(occ, s_admit, -1)
    order = jnp.argsort(-keys, axis=1)
    g_sorted = jnp.take_along_axis(res_gid, order, 1)
    prog_mat = s_prefilled + s_decoded
    prog_sorted = jnp.take_along_axis(prog_mat, order, 1) \
        * (g_sorted >= 0)
    csum = jnp.cumsum(prog_sorted, 1)
    ok = (rts[:, None] - csum) <= ro["cap"][:, None]
    k_fit = jnp.where(ok.any(1), jnp.argmax(ok, 1) + 1, S)
    k = jnp.where(over, jnp.minimum(k_fit, res_cnt - 1), 0)
    evict_sorted = iota_s[None, :] < k[:, None]
    evict = jnp.zeros((L, S), bool).at[iota_l[:, None], order].set(
        evict_sorted)
    g_ev = jnp.where(evict, res_gid, C_pad)
    # arena write-back then progress reset (net of _evict_slot +
    # _reset_progress; prefill_done is retained across preemption)
    prefilled_c = prefilled_c.at[g_ev].set(0, mode="drop")
    decoded_c = decoded_c.at[g_ev].set(0, mode="drop")
    first_tok_c = first_tok_c.at[g_ev].set(s_first, mode="drop")
    nemit_c = nemit_c.at[g_ev].add(s_decoded, mode="drop")
    prefill_done_c = prefill_done_c.at[g_ev].set(s_pfdone, mode="drop")
    phase_c = phase_c.at[g_ev].set(PH_PREEMPTED, mode="drop")
    preempts_c = preempts_c.at[g_ev].add(1, mode="drop")
    debit_lane = (jnp.minimum(s_decoded * s_invd, 1.0) * s_invt
                  * (evict & (s_decoded > 0))).sum(1)
    bk_t = bk_t - segment_sum(debit_lane, ro["lane_ep"],
                              num_segments=E)
    d_lane = d_lane.at[iota_l, b_all].add(-debit_lane)
    lane_ivv = lane_ivv - (s_invd * s_invt
                           * (evict & (st == SS_DECODE)
                              & (s_decoded < s_capat))).sum(1)
    pref_cnt = pref_cnt - (evict & (st == SS_PREFILL)).sum(1)
    prog_ev = (prog_mat * evict).sum(1)
    rts = rts - prog_ev
    qps = qps + (s_prompt * evict).sum(1)
    outst = outst + prog_ev
    res_cnt = res_cnt - k
    res_gid = jnp.where(evict, -1, res_gid)
    st = jnp.where(evict, SS_EMPTY, st)
    # requeue in ascending admit-seq order at the queue FRONT (the
    # sequential loop pushes-left newest-first, which lands oldest-
    # evicted at the head)
    idx_rev = jnp.clip(k[:, None] - 1 - iota_s[None, :], 0, S - 1)
    ev_asc = jnp.take_along_axis(g_sorted, idx_rev, 1)
    evq = jnp.take_along_axis(
        ev_asc, jnp.broadcast_to(jnp.clip(iota_q, 0, S - 1)[None, :],
                                 (L, Q)), 1)
    tail = jnp.take_along_axis(
        q, jnp.clip(iota_q[None, :] - k[:, None], 0, Q - 1), 1)
    q = jnp.where(k[:, None] > 0,
                  jnp.where(iota_q[None, :] < k[:, None], evq, tail), q)
    qcnt = qcnt + k

    # -- loop bookkeeping ---------------------------------------------
    active = active & (clock < ro["target"])
    dry = active & ~((res_cnt > 0) | (qcnt > 0))
    clock = jnp.where(dry, ro["target"], clock)
    active = active & ~dry

    return dict(
        active=active, clock=clock, rts=rts, qps=qps, outst=outst,
        admit_ctr=admit_ctr, res_cnt=res_cnt, pref_cnt=pref_cnt,
        qcnt=qcnt, q=q, res_gid=res_gid, s_state=st, s_prompt=s_prompt,
        s_dtotal=s_dtotal, s_prefilled=s_prefilled,
        s_decoded=s_decoded, s_admit=s_admit, s_first=s_first,
        s_pfdone=s_pfdone, s_invd=s_invd, s_invt=s_invt,
        s_capat=s_capat, lane_ivv=lane_ivv, spike_cnt=spike_cnt,
        bk_s=bk_s, bk_t=bk_t, d_lane=d_lane, a_prefilled=prefilled_c,
        a_decoded=decoded_c, a_admit_seq=admit_seq_c, a_phase=phase_c,
        a_preempts=preempts_c, a_first_tok=first_tok_c,
        a_prefill_done=prefill_done_c, a_finished=finished_c,
        a_nemit=nemit_c, done_round=done_round, done_col=done_col,
        round_no=c["round_no"] + 1)


@jax.jit
def _run_kernel(ro, carry):
    """All rounds of one ``_run_rounds`` call, on device."""
    return lax.while_loop(lambda c: c["active"].any(),
                          lambda c: _round_body(ro, c), carry)


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


class JaxSimPool(VecSimPool):
    """VecSimPool whose round loop runs as one jitted device program.

    Drop-in: ``Cluster(..., backend="jax")`` and
    ``BatchedRLConfig(backend="jax")`` resolve here through the
    ``core.backends`` registry.  ``min_span_ticks`` tunes the hybrid
    dispatch threshold: spans estimated shorter than this many ticks
    run on the inherited numpy path (device dispatch overhead
    dominates 1–2 round spans on CPU XLA); results are identical
    either way."""

    def __init__(self, n_episodes: int = 1, arena_cap: int = 1024,
                 min_span_ticks: int = 8):
        super().__init__(n_episodes, arena_cap)
        self.min_span_ticks = min_span_ticks
        # dispatch instrumentation (bench_jaxsim reports these)
        self.n_jax_calls = 0
        self.n_numpy_calls = 0
        # grow-only compact-arena padding: every distinct (L, S, Q,
        # C_pad) tuple is one XLA compile of the (large) round kernel,
        # so the pad must not track the live candidate count up and
        # down -- it only ratchets, and shapes go static after the
        # first episode
        self._c_pad = 64

    # -- the single override ------------------------------------------
    def _run_rounds(self, target: np.ndarray,
                    done: Dict[int, List[int]]):
        if not self._jax_eligible(target):
            self.n_numpy_calls += 1
            return super()._run_rounds(target, done)
        behind = self.clock < target
        if not behind.any():
            return
        runnable = ((self.res_cnt > 0) | (self.qcnt > 0)) & ~self.failed
        jump = behind & ~runnable
        if jump.any():
            self.clock[jump] = target[jump]
        active = behind & runnable
        if not active.any():
            return
        self.n_jax_calls += 1
        self._dispatch(active, target, done)

    def _jax_eligible(self, target) -> bool:
        if self.trace.enabled or self._any_cache or self._L == 0:
            return False
        if self._span is not None:
            lane_k = self._span[2]
            k_max = int(lane_k.max()) if lane_k.size else 0
            if k_max >= SPAN_BUCKETS:
                return False
            return k_max >= self.min_span_ticks
        # per-tick advance: estimate the span length in ticks
        gap = target - self.clock
        behind = gap > 0
        if not behind.any():
            return True            # nothing to do; either path returns
        ticks = gap[behind] / self.ep_dt[self.lane_ep[behind]]
        return float(ticks.max()) >= self.min_span_ticks

    # -- staging / writeback ------------------------------------------
    def _dispatch(self, active: np.ndarray, target: np.ndarray,
                  done: Dict[int, List[int]]):
        L, S, Q = self._L, self._S, self._Q
        # precondition widths so the kernel never needs to grow:
        # residents are bounded by min(nslots, res_cnt+qcnt) (no new
        # submissions inside a span), queues by res_cnt+qcnt (preempt
        # requeues at most res_cnt-1)
        need_s = int(np.minimum(self.nslots,
                                self.res_cnt + self.qcnt).max())
        while self._S < need_s:
            self._grow_res()
        need_q = int((self.res_cnt + self.qcnt).max())
        while self._Q < need_q:
            self._grow_queue()
        S, Q = self._S, self._Q
        # candidate rows: every queued or resident gid, all lanes (the
        # carry holds full-width matrices, so even inactive lanes'
        # gids must survive the remap round trip)
        pos = (self.qhead[:, None] + np.arange(Q)) % Q
        gq = self.q_gid[np.arange(L)[:, None], pos]     # logical order
        qvalid = np.arange(Q) < self.qcnt[:, None]
        cand = np.unique(np.concatenate(
            [self.res_gid[self.res_gid >= 0], gq[qvalid]]))
        C = cand.size
        need = _next_pow2(max(C, 1))
        if need > self._c_pad:
            self._c_pad = need
        C_pad = self._c_pad
        gmap = np.full(self._cap_g, -1, np.int64)
        gmap[cand] = np.arange(C)
        res_gid_c = np.where(self.res_gid >= 0,
                             gmap[np.maximum(self.res_gid, 0)], -1)
        q_c = np.where(qvalid, gmap[np.maximum(gq, 0)], -1)

        def _pad(col):
            out = np.zeros(C_pad, col.dtype)
            out[:C] = col[cand]
            return out

        if self._span is not None:
            span_t0, lane_off, lane_k, _ = self._span
        else:
            span_t0 = np.zeros(L)
            lane_k = np.zeros(L, np.int64)
        ro = dict(
            target=target, cap=self.cap, nslots=self.nslots,
            grad1=self.grad1, grad2=self.grad2, tdec=self.tdec,
            tpre=self.tpre, speed=self.speed, chunk=self.chunk,
            sched=self.sched.astype(np.int64), lane_ep=self.lane_ep,
            ep_dt_lane=self.ep_dt[self.lane_ep], span_t0=span_t0,
            lane_k=lane_k, prompt=_pad(self.prompt),
            dtotal=_pad(self.dtotal), inv_d=_pad(self.inv_d),
            inv_t=_pad(self.inv_t), capat=_pad(self.capat),
            fp_zero=np.float64(0.0))
        carry = dict(
            active=active, clock=self.clock, rts=self.rts,
            qps=self.qps, outst=self.outst, admit_ctr=self.admit_ctr,
            res_cnt=self.res_cnt, pref_cnt=self.pref_cnt,
            qcnt=self.qcnt, q=q_c, res_gid=res_gid_c,
            s_state=self.s_state.astype(np.int64),
            s_prompt=self.s_prompt, s_dtotal=self.s_dtotal,
            s_prefilled=self.s_prefilled, s_decoded=self.s_decoded,
            s_admit=self.s_admit, s_first=self.s_first,
            s_pfdone=self.s_pfdone, s_invd=self.s_invd,
            s_invt=self.s_invt, s_capat=self.s_capat,
            lane_ivv=self.lane_ivv,
            spike_cnt=np.zeros(L, np.int64), bk_s=self.bk_s,
            bk_t=self.bk_t, d_lane=np.zeros((L, SPAN_BUCKETS)),
            a_prefilled=_pad(self.prefilled),
            a_decoded=_pad(self.decoded),
            a_admit_seq=_pad(self.admit_seq), a_phase=_pad(
                self.phase).astype(np.int64),
            a_preempts=_pad(self.preempts),
            a_first_tok=_pad(self.first_tok),
            a_prefill_done=_pad(self.prefill_done),
            a_finished=_pad(self.finished), a_nemit=_pad(self.nemit),
            done_round=np.full(C_pad, -1, np.int64),
            done_col=np.zeros(C_pad, np.int64),
            round_no=np.int64(0))
        with enable_x64():
            out = _run_kernel(ro, carry)
        out = {k: np.asarray(v) for k, v in out.items()}
        self._writeback(out, cand, C, done)

    def _writeback(self, out, cand, C, done):
        L, Q = self._L, self._Q
        for name in ("clock", "rts", "qps", "outst", "admit_ctr",
                     "res_cnt", "pref_cnt", "qcnt", "lane_ivv",
                     "bk_s", "bk_t", "s_prompt", "s_dtotal",
                     "s_prefilled", "s_decoded", "s_admit", "s_first",
                     "s_pfdone", "s_invd", "s_invt", "s_capat"):
            getattr(self, name)[...] = out[name]
        self.s_state[...] = out["s_state"].astype(np.int8)
        # un-remap compact gids; queues come back logically ordered
        rc = out["res_gid"]
        self.res_gid[...] = np.where(rc >= 0, cand[np.maximum(rc, 0)],
                                     -1)
        qc = out["q"]
        qvalid = np.arange(Q) < out["qcnt"][:, None]
        self.q_gid[...] = np.where(qvalid & (qc >= 0),
                                   cand[np.maximum(qc, 0)], -1)
        self.qhead[:] = 0
        for src, dst in (("a_prefilled", "prefilled"),
                         ("a_decoded", "decoded"),
                         ("a_admit_seq", "admit_seq"),
                         ("a_preempts", "preempts"),
                         ("a_first_tok", "first_tok"),
                         ("a_prefill_done", "prefill_done"),
                         ("a_finished", "finished"),
                         ("a_nemit", "nemit")):
            getattr(self, dst)[cand] = out[src][:C]
        self.phase[cand] = out["a_phase"][:C].astype(np.int8)
        # python-int gates for the (possibly interleaved) numpy path
        self._tot_q = int(out["qcnt"].sum())
        self._tot_pref = int(out["pref_cnt"].sum())
        self._tot_dec = int((self.s_state == SS_DECODE).sum())
        self._next_fin = 0
        occ = (self.res_gid >= 0).any(0)
        self._hw = (int(np.flatnonzero(occ).max()) + 1 if occ.any()
                    else 0)
        # spikes: counts only (placeholder values; see module doc)
        for lane in np.flatnonzero(out["spike_cnt"]):
            self.spikes[int(lane)].extend(
                [float("nan")] * int(out["spike_cnt"][lane]))
        # span reward buckets: fold per-lane rows into the flat
        # per-episode tick vector (col 0 is the discard bucket)
        if self._span is not None:
            _, lane_off, lane_k, d_flat = self._span
            cols = np.arange(1, SPAN_BUCKETS)
            mask = cols[None, :] <= lane_k[:, None]
            idx = lane_off[:, None] + cols[None, :]
            np.add.at(d_flat, idx[mask], out["d_lane"][:, 1:][mask])
        # completions, replayed in the vec backend's order: round-
        # major, then lane, then slot column within a lane
        new = np.flatnonzero(out["done_round"][:C] >= 0)
        if new.size:
            gids = cand[new]
            order = np.lexsort((out["done_col"][new],
                                self.lane[gids],
                                out["done_round"][new]))
            for j in order:
                gid = int(gids[j])
                self._sync_done(gid)
                done[int(self.lane_ep[self.lane[gid]])].append(gid)
