"""Double DQN in pure JAX (paper §A.9.3): MLP (state,64),(64,64),(64,m+1),
ReLU, replay buffer, target network, masked epsilon-greedy."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DQNConfig:
    state_dim: int
    n_actions: int
    hidden: Tuple[int, ...] = (64, 64)
    gamma: float = 0.99
    lr: float = 1e-3
    batch_size: int = 512
    buffer_size: int = 200_000
    tau: float = 0.005              # polyak target averaging per learn step
    huber_delta: float = 1.0
    # average-reward centering: the routing MDP carries a large
    # action-independent per-step backlog penalty; centering rewards by a
    # running mean (differential Q-learning) removes the constant component
    # so the TD signal is dominated by action ADVANTAGES.
    center_rewards: bool = True
    center_beta: float = 0.005
    # q_arch "mlp": the paper's fixed-m MLP (27ish,64),(64,64),(64,m+1).
    # q_arch "decomposed": beyond-paper permutation-equivariant network --
    # a shared trunk scores each instance from (instance block, router
    # block); defer is scored from the pooled embedding.  Equivariance
    # removes the all-to-one-instance greedy degeneracy and lets m change
    # at runtime (elastic scaling).
    q_arch: str = "mlp"
    inst_dims: int = 0
    router_dims: int = 0
    # prioritized experience replay (Schaul et al. 2015), proportional
    # variant.  Default OFF: uniform sampling with unit IS weights (the
    # packed row always carries a weight column, so both modes share one
    # compiled train_batch).  Priorities are |TD error| + per_eps; new
    # transitions enter at the current max priority.
    prioritized: bool = False
    per_alpha: float = 0.6          # priority exponent
    per_beta: float = 0.4           # IS-correction exponent (fixed)
    per_eps: float = 1e-3


def init_mlp(key, dims) -> Dict:
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k1, (a, b)) * np.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp(params: Dict, x: jax.Array) -> jax.Array:
    n = len(params) // 2
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_q(key, cfg: DQNConfig) -> Dict:
    if cfg.q_arch == "mlp":
        dims = (cfg.state_dim,) + cfg.hidden + (cfg.n_actions,)
        return init_mlp(key, dims)
    k1, k2, k3 = jax.random.split(key, 3)
    h = cfg.hidden[0]
    return {
        "trunk": init_mlp(k1, (cfg.inst_dims + cfg.router_dims, h, h)),
        "route_head": init_mlp(k2, (h, 1)),
        "defer_head": init_mlp(k3, (h + cfg.router_dims, h, 1)),
    }


def apply_q(cfg: DQNConfig, params: Dict, x: jax.Array) -> jax.Array:
    """x [batch, state_dim] -> q [batch, n_actions] (last action = defer)."""
    if cfg.q_arch == "mlp":
        return mlp(params, x)
    b = x.shape[0]
    n_inst = (x.shape[-1] - cfg.router_dims) // cfg.inst_dims
    inst = x[:, :n_inst * cfg.inst_dims].reshape(b, n_inst, cfg.inst_dims)
    router = x[:, n_inst * cfg.inst_dims:]
    router_b = jnp.broadcast_to(router[:, None],
                                (b, n_inst, cfg.router_dims))
    h = mlp(params["trunk"], jnp.concatenate([inst, router_b], -1))
    h = jax.nn.relu(h)
    q_route = mlp(params["route_head"], h)[..., 0]        # [b, n_inst]
    pooled = jnp.mean(h, axis=1)
    q_defer = mlp(params["defer_head"],
                  jnp.concatenate([pooled, router], -1))  # [b,1]
    return jnp.concatenate([q_route, q_defer], axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def q_values(cfg: DQNConfig, params: Dict, state: jax.Array) -> jax.Array:
    return apply_q(cfg, params, state)


def _huber(x, delta):
    a = jnp.abs(x)
    return jnp.where(a <= delta, 0.5 * x * x, delta * (a - 0.5 * delta))


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_batch(cfg: DQNConfig, params: Dict, opt: Dict, target: Dict,
                batch: Dict) -> Tuple[Dict, Dict, Dict, jax.Array]:
    """One Adam step on the double-DQN TD loss + fused polyak target
    update (a single dispatch; the unjitted per-leaf tree.map used to
    dominate learn() wall time).

    Deliberately NOT donating params/opt/target: donated dispatch blocks
    until the donated input futures materialize, which serializes
    chained learn steps and defeats the batched runner's async overlap;
    the Q network is ~100 KB, so the copies are free by comparison.

    ``batch`` is one packed [B, 2*state_dim + 4 + n_actions] float32
    array ([s | s2 | a | r | done | mask2 | w]) so learn() pays a single
    host->device transfer instead of seven.  ``w`` is the prioritized
    replay importance weight (1.0 under uniform sampling); the returned
    ``td_abs`` feeds the priority update."""
    d = cfg.state_dim
    s = batch[:, :d]
    s2 = batch[:, d:2 * d]
    a = batch[:, 2 * d].astype(jnp.int32)
    r = batch[:, 2 * d + 1]
    done = batch[:, 2 * d + 2]
    mask2 = batch[:, 2 * d + 3:2 * d + 3 + cfg.n_actions] > 0.5
    w = batch[:, 2 * d + 3 + cfg.n_actions]

    def loss_fn(p):
        q = apply_q(cfg, p, s)
        q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
        q2_online = apply_q(cfg, p, s2)
        q2_online = jnp.where(mask2, q2_online, -1e9)
        a_star = jnp.argmax(q2_online, axis=1)
        q2_target = apply_q(cfg, target, s2)
        q2 = jnp.take_along_axis(q2_target, a_star[:, None], axis=1)[:, 0]
        y = r + cfg.gamma * (1.0 - done) * q2
        td = q_sa - jax.lax.stop_gradient(y)
        return jnp.mean(w * _huber(td, cfg.huber_delta)), jnp.abs(td)

    (loss, td_abs), grads = jax.value_and_grad(loss_fn,
                                               has_aux=True)(params)
    # inline Adam (pytree-generic)
    step = opt["step"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         opt["v"], grads)
    new_p = jax.tree.map(
        lambda p, m, v: p - cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, new_m, new_v)
    new_target = jax.tree.map(
        lambda t, p: (1.0 - cfg.tau) * t + cfg.tau * p, target, new_p)
    return (new_p, {"m": new_m, "v": new_v, "step": step}, new_target,
            loss, td_abs)


class ReplayBuffer:
    """Ring buffer with PACKED rows [s | s2 | a | r | done | mask2 | w]:
    one contiguous float32 matrix, so sampling is a single gather and
    the learner a single host->device transfer.  The trailing column is
    the importance weight consumed by the weighted TD loss -- 1.0 at
    insert; prioritized sampling overwrites it in the sampled COPY, so
    the stored rows stay weight-neutral."""

    def __init__(self, cfg: DQNConfig):
        n, d, a = cfg.buffer_size, cfg.state_dim, cfg.n_actions
        self.d = d
        self.data = np.zeros((n, 2 * d + 4 + a), np.float32)
        self.prio = np.zeros((n,), np.float64)
        # per-slot write sequence: a deferred priority update for a slot
        # the ring has since overwritten must be dropped, or the fresh
        # transition loses its max-priority first-replay guarantee
        self.write_seq = np.zeros((n,), np.int64)
        self.seq = 0
        self.max_prio = 1.0
        self.size = 0
        self.ptr = 0
        self.cap = n

    def add(self, s, a, r, s2, done, mask2):
        row = self.data[self.ptr]
        d = self.d
        row[:d] = s
        row[d:2 * d] = s2
        row[2 * d] = a
        row[2 * d + 1] = r
        row[2 * d + 2] = done
        row[2 * d + 3:-1] = mask2
        row[-1] = 1.0
        # new experience enters at max priority so it is seen at least
        # once before its TD error is known (Schaul et al. 2015)
        self.prio[self.ptr] = self.max_prio
        self.seq += 1
        self.write_seq[self.ptr] = self.seq
        self.ptr = (self.ptr + 1) % self.cap
        self.size = min(self.size + 1, self.cap)

    def add_rows(self, rows: np.ndarray):
        """Bulk-insert pre-packed rows (layout exactly as ``add``;
        the batched trainer packs them on device, see
        ``batched_rl._observe_packed``).  Equivalent to n sequential
        ``add`` calls: priorities only move in ``update_priorities``,
        so every row enters at the same ``max_prio``, and the ring
        pointer / write sequence advance row by row."""
        rows = np.asarray(rows, np.float32)
        n = len(rows)
        if n == 0:
            return
        if n > self.cap:          # only the last ``cap`` rows survive
            rows = rows[-self.cap:]
            self.seq += n - self.cap
            n = self.cap
        idx = (self.ptr + np.arange(n)) % self.cap
        self.data[idx] = rows
        self.prio[idx] = self.max_prio
        self.write_seq[idx] = self.seq + 1 + np.arange(n)
        self.seq += n
        self.ptr = int((self.ptr + n) % self.cap)
        self.size = min(self.size + n, self.cap)

    def sample(self, rng: np.random.Generator, batch: int) -> np.ndarray:
        idx = rng.integers(0, self.size, size=batch)
        return self.data[idx]

    def sample_prioritized(self, rng: np.random.Generator, batch: int,
                           alpha: float, beta: float
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """Proportional PER draw; returns (rows, idx) with the rows'
        weight column set to the normalized IS correction.

        O(size) per draw (powered priorities + weighted choice): ~1 ms
        at the 200k default, paid every learn_every_rounds -- small
        next to the gradient step.  A sum-tree would make it
        O(batch log n) if the buffer ever grows past ~1M."""
        p = self.prio[:self.size] ** alpha
        p /= p.sum()
        idx = rng.choice(self.size, size=batch, p=p)
        rows = self.data[idx]                     # fancy index = copy
        w = (self.size * p[idx]) ** -beta
        rows[:, -1] = w / w.max()
        return rows, idx

    def update_priorities(self, idx: np.ndarray, td_abs: np.ndarray,
                          eps: float = 1e-3,
                          expect_seq: Optional[np.ndarray] = None):
        """Set |TD|-based priorities.  ``expect_seq`` (the slots'
        ``write_seq`` captured at sample time) drops updates for slots
        the ring has overwritten since."""
        idx = np.asarray(idx)
        pr = np.abs(np.asarray(td_abs, np.float64)) + eps
        if expect_seq is not None:
            live = self.write_seq[idx] == expect_seq
            idx, pr = idx[live], pr[live]
            if idx.size == 0:
                return
        self.prio[idx] = pr
        self.max_prio = max(self.max_prio, float(pr.max()))


class DQNAgent:
    def __init__(self, cfg: DQNConfig, seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.params = init_q(key, cfg)
        self.target = jax.tree.map(jnp.copy, self.params)
        self.opt = {"m": jax.tree.map(jnp.zeros_like, self.params),
                    "v": jax.tree.map(jnp.zeros_like, self.params),
                    "step": jnp.zeros((), jnp.int32)}
        self.buffer = ReplayBuffer(cfg)
        self.rng = np.random.default_rng(seed)
        self.steps = 0
        self.r_mean = 0.0
        self._r_init = False
        self._pending_prio = None      # (idx, td device array) to apply
        # last learn step's loss/|TD| as DEVICE arrays: stashing them
        # costs nothing on the async learner path; telemetry() pays the
        # sync only when somebody actually reads them
        self.last_loss = None
        self.last_td = None

    def act(self, state: np.ndarray, mask: np.ndarray,
            epsilon: float = 0.0,
            prior: Optional[np.ndarray] = None,
            q_squash: float = 0.0) -> int:
        """Masked epsilon-greedy; ``prior`` is an optional per-action bonus
        added to Q at selection time (decision-time guidance).  q_squash>0
        bounds Q's influence to +-q_squash (advantages tanh-squashed), so a
        strong prior cannot be overruled by unbounded value noise."""
        valid = np.flatnonzero(mask)
        if epsilon > 0 and self.rng.random() < epsilon:
            return int(self.rng.choice(valid))
        a = self.act_batch(state[None], mask[None],
                           prior=None if prior is None else prior[None],
                           q_squash=q_squash)
        return int(a[0])

    def act_batch(self, states: np.ndarray, masks: np.ndarray,
                  epsilon: Optional[np.ndarray] = None,
                  prior: Optional[np.ndarray] = None,
                  q_squash: float = 0.0) -> np.ndarray:
        """Vectorized ``act`` over a batch of independent episode states
        ([B, state_dim] -> [B] actions): ONE jitted Q dispatch for the
        whole batch instead of one per episode -- the core amortization of
        the batched multi-episode runner.  ``epsilon`` is per-episode (the
        batched runner mixes episodes at different schedule points)."""
        q = np.asarray(q_values(self.cfg, self.params,
                                jnp.asarray(states)), dtype=np.float64)
        if q_squash > 0:
            qm = np.where(masks, q, -np.inf)
            ref = np.max(qm, axis=1)
            ref = np.where(np.isfinite(ref), ref, 0.0)
            q = q_squash * np.tanh(q - ref[:, None])
        if prior is not None:
            q = q + prior
        q[~masks] = -np.inf
        acts = np.argmax(q, axis=1).astype(np.int64)
        if epsilon is not None and np.any(epsilon > 0):
            explore = self.rng.random(len(acts)) < epsilon
            for i in np.flatnonzero(explore):
                acts[i] = int(self.rng.choice(np.flatnonzero(masks[i])))
        return acts

    def observe(self, s, a, r, s2, done, mask2):
        if self.cfg.center_rewards:
            if not self._r_init:
                self.r_mean, self._r_init = float(r), True
            else:
                self.r_mean += self.cfg.center_beta * (r - self.r_mean)
            r = r - self.r_mean
        self.buffer.add(s, a, r, s2, done, mask2)

    def _resolve_priorities(self):
        """Apply the TD-error priorities of the previous prioritized
        step.  Deferred one learn call so the async-dispatched gradient
        step is (almost always) already materialized when we read it
        back -- priority updates then cost no synchronization."""
        if self._pending_prio is None:
            return
        idx, td, stamps = self._pending_prio
        self._pending_prio = None
        self.buffer.update_priorities(idx, np.asarray(td),
                                      eps=self.cfg.per_eps,
                                      expect_seq=stamps)

    def learn(self, sync: bool = True) -> Optional[float]:
        """One gradient step.  ``sync=False`` skips the loss read-back so
        the jitted update is dispatched asynchronously: on CPU the XLA
        gradient computation then runs on a worker thread, overlapping
        the caller's Python (the batched runner steps its simulators
        while the learner crunches; the next q_values call blocks until
        the new params are ready)."""
        if self.buffer.size < self.cfg.batch_size:
            return None
        self._resolve_priorities()
        if self.cfg.prioritized:
            rows, idx = self.buffer.sample_prioritized(
                self.rng, self.cfg.batch_size,
                self.cfg.per_alpha, self.cfg.per_beta)
        else:
            rows, idx = self.buffer.sample(self.rng,
                                           self.cfg.batch_size), None
        batch = jnp.asarray(rows)
        self.params, self.opt, self.target, loss, td_abs = train_batch(
            self.cfg, self.params, self.opt, self.target, batch)
        self.last_loss = loss
        self.last_td = td_abs
        if idx is not None:
            self._pending_prio = (idx, td_abs,
                                  self.buffer.write_seq[idx].copy())
            if sync:
                self._resolve_priorities()
        self.steps += 1
        return float(loss) if sync else None

    def telemetry(self) -> Dict[str, float]:
        """Training telemetry snapshot for the metrics registry: last
        TD loss / |TD| stats, replay occupancy, priority distribution.
        Reading the stashed device arrays synchronizes with the (maybe
        async) learner -- call between learn bursts, not inside them."""
        out: Dict[str, float] = {
            "learn_steps": float(self.steps),
            "replay_size": float(self.buffer.size),
            "reward_mean": float(self.r_mean),
        }
        if self.last_loss is not None:
            out["loss"] = float(self.last_loss)
            td = np.asarray(self.last_td)
            out["td_abs_mean"] = float(td.mean())
            out["td_abs_max"] = float(td.max())
        if self.cfg.prioritized and self.buffer.size:
            pr = self.buffer.prio[:self.buffer.size]
            out["replay_prio_mean"] = float(pr.mean())
            out["replay_prio_max"] = float(self.buffer.max_prio)
        return out

    # checkpointable state (router fault tolerance)
    def state_dict(self):
        return {"params": self.params, "target": self.target,
                "opt": self.opt}

    def load_state_dict(self, st):
        self.params, self.target, self.opt = (st["params"], st["target"],
                                              st["opt"])

    # FULL learner state: everything a mid-stream resume needs to
    # continue bit-exactly -- networks + optimizer + replay-buffer
    # contents + reward-centering EMA + the numpy RNG.  The array-valued
    # parts go in the tree (checksummed leaves); scalars and the 128-bit
    # PCG64 state ride in ``extra`` (JSON keeps the big ints exact,
    # msgpack caps at 64 bits).
    def full_state(self) -> Tuple[Dict, Dict]:
        import json
        buf = self.buffer
        tree = {"params": self.params, "target": self.target,
                "opt": self.opt,
                "replay": {"data": buf.data, "prio": buf.prio,
                           "write_seq": buf.write_seq}}
        extra = {"replay_ptr": buf.ptr, "replay_size": buf.size,
                 "replay_seq": buf.seq,
                 "replay_max_prio": buf.max_prio,
                 "steps": self.steps, "r_mean": self.r_mean,
                 "r_init": bool(self._r_init),
                 "rng_state": json.dumps(self.rng.bit_generator.state)}
        return tree, extra

    def load_full_state(self, tree: Dict, extra: Dict):
        import json
        self.load_state_dict(tree)
        buf = self.buffer
        rp = tree["replay"]
        # copy: deserialize hands out read-only np.frombuffer views
        buf.data = np.array(rp["data"], np.float32)
        buf.prio = np.array(rp["prio"], np.float64)
        buf.write_seq = np.array(rp["write_seq"], np.int64)
        buf.ptr = int(extra["replay_ptr"])
        buf.size = int(extra["replay_size"])
        buf.seq = int(extra["replay_seq"])
        buf.max_prio = float(extra["replay_max_prio"])
        self.steps = int(extra["steps"])
        self.r_mean = float(extra["r_mean"])
        self._r_init = bool(extra["r_init"])
        self.rng.bit_generator.state = json.loads(extra["rng_state"])
        self._pending_prio = None      # sampled-slot stamps are stale
