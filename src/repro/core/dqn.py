"""Double DQN in pure JAX (paper §A.9.3): MLP (state,64),(64,64),(64,m+1),
ReLU, replay buffer, target network, masked epsilon-greedy."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DQNConfig:
    state_dim: int
    n_actions: int
    hidden: Tuple[int, ...] = (64, 64)
    gamma: float = 0.99
    lr: float = 1e-3
    batch_size: int = 512
    buffer_size: int = 200_000
    tau: float = 0.005              # polyak target averaging per learn step
    huber_delta: float = 1.0
    # average-reward centering: the routing MDP carries a large
    # action-independent per-step backlog penalty; centering rewards by a
    # running mean (differential Q-learning) removes the constant component
    # so the TD signal is dominated by action ADVANTAGES.
    center_rewards: bool = True
    center_beta: float = 0.005
    # q_arch "mlp": the paper's fixed-m MLP (27ish,64),(64,64),(64,m+1).
    # q_arch "decomposed": beyond-paper permutation-equivariant network --
    # a shared trunk scores each instance from (instance block, router
    # block); defer is scored from the pooled embedding.  Equivariance
    # removes the all-to-one-instance greedy degeneracy and lets m change
    # at runtime (elastic scaling).
    q_arch: str = "mlp"
    inst_dims: int = 0
    router_dims: int = 0


def init_mlp(key, dims) -> Dict:
    params = {}
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        key, k1 = jax.random.split(key)
        params[f"w{i}"] = jax.random.normal(k1, (a, b)) * np.sqrt(2.0 / a)
        params[f"b{i}"] = jnp.zeros((b,))
    return params


def mlp(params: Dict, x: jax.Array) -> jax.Array:
    n = len(params) // 2
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_q(key, cfg: DQNConfig) -> Dict:
    if cfg.q_arch == "mlp":
        dims = (cfg.state_dim,) + cfg.hidden + (cfg.n_actions,)
        return init_mlp(key, dims)
    k1, k2, k3 = jax.random.split(key, 3)
    h = cfg.hidden[0]
    return {
        "trunk": init_mlp(k1, (cfg.inst_dims + cfg.router_dims, h, h)),
        "route_head": init_mlp(k2, (h, 1)),
        "defer_head": init_mlp(k3, (h + cfg.router_dims, h, 1)),
    }


def apply_q(cfg: DQNConfig, params: Dict, x: jax.Array) -> jax.Array:
    """x [batch, state_dim] -> q [batch, n_actions] (last action = defer)."""
    if cfg.q_arch == "mlp":
        return mlp(params, x)
    b = x.shape[0]
    n_inst = (x.shape[-1] - cfg.router_dims) // cfg.inst_dims
    inst = x[:, :n_inst * cfg.inst_dims].reshape(b, n_inst, cfg.inst_dims)
    router = x[:, n_inst * cfg.inst_dims:]
    router_b = jnp.broadcast_to(router[:, None],
                                (b, n_inst, cfg.router_dims))
    h = mlp(params["trunk"], jnp.concatenate([inst, router_b], -1))
    h = jax.nn.relu(h)
    q_route = mlp(params["route_head"], h)[..., 0]        # [b, n_inst]
    pooled = jnp.mean(h, axis=1)
    q_defer = mlp(params["defer_head"],
                  jnp.concatenate([pooled, router], -1))  # [b,1]
    return jnp.concatenate([q_route, q_defer], axis=-1)


@functools.partial(jax.jit, static_argnames=("cfg",))
def q_values(cfg: DQNConfig, params: Dict, state: jax.Array) -> jax.Array:
    return apply_q(cfg, params, state)


def _huber(x, delta):
    a = jnp.abs(x)
    return jnp.where(a <= delta, 0.5 * x * x, delta * (a - 0.5 * delta))


@functools.partial(jax.jit, static_argnames=("cfg",), donate_argnums=(1, 2))
def train_batch(cfg: DQNConfig, params: Dict, opt: Dict, target: Dict,
                batch: Dict) -> Tuple[Dict, Dict, jax.Array]:
    """One Adam step on the double-DQN TD loss."""
    s, a, r, s2, done, mask2 = (batch["s"], batch["a"], batch["r"],
                                batch["s2"], batch["done"], batch["mask2"])

    def loss_fn(p):
        q = apply_q(cfg, p, s)
        q_sa = jnp.take_along_axis(q, a[:, None], axis=1)[:, 0]
        q2_online = apply_q(cfg, p, s2)
        q2_online = jnp.where(mask2, q2_online, -1e9)
        a_star = jnp.argmax(q2_online, axis=1)
        q2_target = apply_q(cfg, target, s2)
        q2 = jnp.take_along_axis(q2_target, a_star[:, None], axis=1)[:, 0]
        y = r + cfg.gamma * (1.0 - done) * q2
        return jnp.mean(_huber(q_sa - jax.lax.stop_gradient(y),
                               cfg.huber_delta))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    # inline Adam (pytree-generic)
    step = opt["step"] + 1
    b1, b2, eps = 0.9, 0.999, 1e-8
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         opt["m"], grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         opt["v"], grads)
    new_p = jax.tree.map(
        lambda p, m, v: p - cfg.lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps),
        params, new_m, new_v)
    return new_p, {"m": new_m, "v": new_v, "step": step}, loss


class ReplayBuffer:
    def __init__(self, cfg: DQNConfig):
        n, d, a = cfg.buffer_size, cfg.state_dim, cfg.n_actions
        self.s = np.zeros((n, d), np.float32)
        self.a = np.zeros((n,), np.int32)
        self.r = np.zeros((n,), np.float32)
        self.s2 = np.zeros((n, d), np.float32)
        self.done = np.zeros((n,), np.float32)
        self.mask2 = np.zeros((n, a), bool)
        self.size = 0
        self.ptr = 0
        self.cap = n

    def add(self, s, a, r, s2, done, mask2):
        i = self.ptr
        self.s[i], self.a[i], self.r[i] = s, a, r
        self.s2[i], self.done[i], self.mask2[i] = s2, done, mask2
        self.ptr = (i + 1) % self.cap
        self.size = min(self.size + 1, self.cap)

    def sample(self, rng: np.random.Generator, batch: int) -> Dict:
        idx = rng.integers(0, self.size, size=batch)
        return {"s": self.s[idx], "a": self.a[idx], "r": self.r[idx],
                "s2": self.s2[idx], "done": self.done[idx],
                "mask2": self.mask2[idx]}


class DQNAgent:
    def __init__(self, cfg: DQNConfig, seed: int = 0):
        self.cfg = cfg
        key = jax.random.PRNGKey(seed)
        self.params = init_q(key, cfg)
        self.target = jax.tree.map(jnp.copy, self.params)
        self.opt = {"m": jax.tree.map(jnp.zeros_like, self.params),
                    "v": jax.tree.map(jnp.zeros_like, self.params),
                    "step": jnp.zeros((), jnp.int32)}
        self.buffer = ReplayBuffer(cfg)
        self.rng = np.random.default_rng(seed)
        self.steps = 0
        self.r_mean = 0.0
        self._r_init = False

    def act(self, state: np.ndarray, mask: np.ndarray,
            epsilon: float = 0.0,
            prior: Optional[np.ndarray] = None,
            q_squash: float = 0.0) -> int:
        """Masked epsilon-greedy; ``prior`` is an optional per-action bonus
        added to Q at selection time (decision-time guidance).  q_squash>0
        bounds Q's influence to +-q_squash (advantages tanh-squashed), so a
        strong prior cannot be overruled by unbounded value noise."""
        valid = np.flatnonzero(mask)
        if epsilon > 0 and self.rng.random() < epsilon:
            return int(self.rng.choice(valid))
        q = np.array(q_values(self.cfg, self.params,
                              jnp.asarray(state[None])))[0]
        if q_squash > 0:
            ref = np.max(q[mask]) if mask.any() else 0.0
            q = q_squash * np.tanh(q - ref)
        if prior is not None:
            q = q + prior
        q[~mask] = -np.inf
        return int(np.argmax(q))

    def observe(self, s, a, r, s2, done, mask2):
        if self.cfg.center_rewards:
            if not self._r_init:
                self.r_mean, self._r_init = float(r), True
            else:
                self.r_mean += self.cfg.center_beta * (r - self.r_mean)
            r = r - self.r_mean
        self.buffer.add(s, a, r, s2, done, mask2)

    def learn(self) -> Optional[float]:
        if self.buffer.size < self.cfg.batch_size:
            return None
        batch = {k: jnp.asarray(v) for k, v in
                 self.buffer.sample(self.rng, self.cfg.batch_size).items()}
        self.params, self.opt, loss = train_batch(
            self.cfg, self.params, self.opt, self.target, batch)
        self.steps += 1
        tau = self.cfg.tau
        self.target = jax.tree.map(
            lambda t, p: (1.0 - tau) * t + tau * p, self.target,
            self.params)
        return float(loss)

    # checkpointable state (router fault tolerance)
    def state_dict(self):
        return {"params": self.params, "target": self.target,
                "opt": self.opt}

    def load_state_dict(self, st):
        self.params, self.target, self.opt = (st["params"], st["target"],
                                              st["opt"])
