"""Synthetic workload matching the paper's 5-task mixture (Table 1) plus a
production-trace-style generator (§A.12).

Table 1 statistics reproduced (mean prompt / mean decode tokens, share):
  books   translation       29.09 /  61.76   (7351 samples)
  eli5    qna               29.83 / 334.40   (6988)
  imdb    sentiment        211.54 / 142.53   (6564)
  squad   in-context qna   125.16 / 220.02   (7122)
  wnut    entity recogn.    26.41 /  64.10   (3304)

Prompt/decode lengths are lognormal with task-specific parameters tuned to
these means; prompts are capped at 1000 tokens (§A.4).  Each sample also
carries a synthetic token sequence whose *content* statistically encodes the
task (tasks use distinct vocabulary bands) so that a content-only classifier
can recover the task with ~94% accuracy -- mirroring §A.7 -- while the
decode length depends on the task AND latent per-request factors, so that
the task hint materially improves bucket prediction (§5.1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import (A100_LLAMA31_8B, V100_LLAMA2_7B,
                                 HardwareProfile)
from repro.serving.request import Request

MAX_PROMPT = 1000

TASKS = ("translation", "qna", "sentiment", "in_context_qna", "entity")

# task -> (prompt lognorm (mu, sigma), decode lognorm (mu, sigma), weight)
_SPEC = {
    "translation":    ((3.20, 0.55), (3.85, 0.70), 7351),
    "qna":            ((3.25, 0.50), (5.45, 0.85), 6988),
    "sentiment":      ((5.20, 0.45), (4.70, 0.75), 6564),
    "in_context_qna": ((4.70, 0.50), (5.15, 0.70), 7122),
    "entity":         ((3.10, 0.55), (3.90, 0.65), 3304),
}

# synthetic vocabulary: tasks draw 70% of tokens from a private band
VOCAB = 8192
_BAND = 1024
_COMMON = 3 * _BAND        # tokens [0, 3072) are shared filler


def _lognormal_int(rng, mu, sigma, lo, hi, size):
    x = rng.lognormal(mu, sigma, size=size)
    return np.clip(x, lo, hi).astype(np.int64)


@dataclass
class Sample:
    task: str
    task_id: int
    prompt_tokens: int
    decode_tokens: int
    token_ids: np.ndarray          # synthetic prompt content (len <= 64)


def generate(n: int, seed: int = 0,
             tasks: Optional[Sequence[str]] = None) -> List[Sample]:
    rng = np.random.default_rng(seed)
    tasks = tuple(tasks or TASKS)
    weights = np.array([_SPEC[t][2] for t in tasks], float)
    weights /= weights.sum()
    choice = rng.choice(len(tasks), size=n, p=weights)
    out: List[Sample] = []
    for i in range(n):
        t = tasks[choice[i]]
        (pmu, psig), (dmu, dsig), _ = _SPEC[t]
        p = int(_lognormal_int(rng, pmu, psig, 4, MAX_PROMPT, None))
        # decode depends on the task and (weakly) on prompt length, plus a
        # latent factor shared with the content -- predictable with task
        # hint, much harder without.
        latent = rng.normal(0, 0.25)
        # cap so p + d stays below the V100 KV pool (requests larger than
        # the pool can never be served -- vLLM would reject them)
        d = int(np.clip(np.exp(dmu + dsig * (0.55 * rng.normal() + latent)
                               + 0.05 * np.log(max(p, 1))),
                        1, 2800))
        tid = TASKS.index(t)
        band_lo = _COMMON + tid * _BAND
        n_tok = min(48, max(6, p // 8))
        private = rng.integers(band_lo, band_lo + _BAND, size=n_tok)
        common = rng.integers(0, _COMMON, size=n_tok)
        # weak content->task signal (the paper's DistilBERT recovers the
        # task from content at 93.79%, not perfectly -- §A.7)
        mask = rng.random(n_tok) < 0.30
        toks = np.where(mask, private, common).astype(np.int32)
        # content carries the latent factor through token parity (a weak,
        # learnable signal): bias low/high halves of the band
        shift = int(latent > 0)
        toks = np.where(mask, band_lo + ((toks - band_lo)
                                         % (_BAND // 2)) + shift
                        * (_BAND // 2), toks).astype(np.int32)
        out.append(Sample(t, tid, p, d, toks))
    return out


def to_requests(samples: Sequence[Sample], rate: float, seed: int = 0,
                ) -> List[Request]:
    """Poisson arrivals at ``rate`` req/s."""
    rng = np.random.default_rng(seed + 17)
    gaps = rng.exponential(1.0 / rate, size=len(samples))
    t = np.cumsum(gaps)
    reqs = []
    for s, at in zip(samples, t):
        reqs.append(Request(prompt_tokens=s.prompt_tokens,
                            decode_tokens=s.decode_tokens,
                            arrival=float(at), task=s.task))
    return reqs


def table1_stats(samples: Sequence[Sample], profile) -> dict:
    """Per-task mean prompt/decode and heavy-decode share (Table 1)."""
    rows = {}
    for t in TASKS:
        sub = [s for s in samples if s.task == t]
        if not sub:
            continue
        rows[t] = {
            "n": len(sub),
            "prompt_mean": float(np.mean([s.prompt_tokens for s in sub])),
            "decode_mean": float(np.mean([s.decode_tokens for s in sub])),
            "heavy_decode": float(np.mean(
                [profile.decode_is_heavy(s.decode_tokens) for s in sub])),
        }
    return rows


# -- production-trace-style workload (§A.12) --------------------------------

TRACE_APPS = ("summarize", "chat", "search", "autocomplete")
# long prompts, short decodes (trace: mean prompt 5526, mean decode 113)
_TRACE_SPEC = {
    "summarize":    ((8.65, 0.40), (4.20, 0.50), 0.35),
    "chat":         ((7.20, 0.60), (5.00, 0.60), 0.20),
    "search":       ((8.40, 0.45), (3.00, 0.55), 0.30),
    "autocomplete": ((7.80, 0.50), (2.20, 0.50), 0.15),
}


def arrival_times(n: int, rate: float, pattern: str = "poisson",
                  seed: int = 0, burst_factor: float = 6.0,
                  burst_persistence: float = 0.96,
                  period: float = 240.0, depth: float = 0.8) -> np.ndarray:
    """Arrival timestamps for ``n`` requests at mean rate ``rate`` req/s.

    poisson -- homogeneous Poisson (the paper's setup).
    bursty  -- two-state Markov-modulated Poisson: an ON state at
               ``burst_factor`` x base intensity and a quiet OFF state,
               state re-drawn per arrival with ``burst_persistence``
               (traffic spikes like Fig. 5's incident windows).
    diurnal -- inhomogeneous Poisson with sinusoidal intensity
               rate(t) = rate * (1 + depth * sin(2 pi t / period)),
               sampled by thinning (day/night load swing, compressed to
               an episode-sized ``period``).
    """
    rng = np.random.default_rng(seed + 23)
    if pattern == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, size=n))
    if pattern == "bursty":
        # ~half the arrivals occur in ON bursts at burst_factor x the
        # nominal intensity; the OFF intensity is solved so the realized
        # long-run rate (the HARMONIC mean over per-arrival states) is
        # ~rate:  0.5*(1/r_on + 1/r_off) = 1/rate.
        r_on = burst_factor * rate
        r_off = burst_factor * rate / (2.0 * burst_factor - 1.0)
        out = np.empty(n)
        t, on = 0.0, bool(rng.random() < 0.5)
        for i in range(n):
            t += rng.exponential(1.0 / (r_on if on else r_off))
            out[i] = t
            if rng.random() > burst_persistence:
                on = not on
        return out
    if pattern == "diurnal":
        r_max = rate * (1.0 + depth)
        out = np.empty(n)
        t, i = 0.0, 0
        while i < n:
            t += rng.exponential(1.0 / r_max)
            r_t = rate * (1.0 + depth * np.sin(2 * np.pi * t / period))
            if rng.random() * r_max < r_t:
                out[i] = t
                i += 1
        return out
    raise ValueError(f"unknown arrival pattern: {pattern}")


# -- heterogeneous multi-episode scenarios (batched RL training) -------------

ARRIVAL_PATTERNS = ("poisson", "bursty", "diurnal")
PROFILE_POOL = (V100_LLAMA2_7B, A100_LLAMA31_8B)


@dataclass
class Scenario:
    """One training episode: a request stream plus the cluster shape it
    runs on (per-instance hardware profiles -- mixed generations
    allowed).  ``samples`` (when kept) aligns 1:1 with ``requests`` and
    carries the synthetic prompt content the length predictor consumes
    (oracle-free routing)."""
    requests: List[Request]
    profiles: Tuple[HardwareProfile, ...]
    name: str = "scenario"
    pattern: str = "poisson"
    rate: float = 0.0
    seed: int = 0
    meta: dict = field(default_factory=dict)
    samples: Optional[List[Sample]] = None

    @property
    def m(self) -> int:
        return len(self.profiles)

    @classmethod
    def homogeneous(cls, profile: HardwareProfile, m: int,
                    requests: Sequence[Request], **kw) -> "Scenario":
        return cls(requests=list(requests), profiles=(profile,) * m, **kw)


def make_scenario(seed: int,
                  profile_pool: Sequence[HardwareProfile] = PROFILE_POOL,
                  n_requests: int = 200,
                  m_range: Tuple[int, int] = (2, 6),
                  rate_per_speed: Tuple[float, float] = (3.5, 6.5),
                  patterns: Sequence[str] = ARRIVAL_PATTERNS,
                  hetero_prob: float = 0.5,
                  profiles: Optional[Sequence[HardwareProfile]] = None
                  ) -> Scenario:
    """Sample one heterogeneous-cluster episode.

    Cluster width, hardware mix, arrival pattern, task mix, and load are
    all drawn from ``seed`` (deterministic).  The arrival rate scales
    with the sampled cluster's aggregate decode speed so that every
    episode is loaded-but-serviceable, and decode lengths are clipped so
    every request fits the smallest sampled KV pool (unserviceable
    requests would never complete).

    ``profiles`` pins the exact cluster (width and per-instance
    hardware) instead of sampling it -- e.g. a mix of engine-calibrated
    and synthetic profiles (``core.calibrate``) so the trained agent
    sees real hardware among the synthetic draws; arrivals and the task
    mix still vary with ``seed``."""
    rng = np.random.default_rng(seed)
    if profiles is not None:
        profiles = tuple(profiles)
        m = len(profiles)
    else:
        m = int(rng.integers(m_range[0], m_range[1] + 1))
        pool = list(profile_pool)
        if len(pool) > 1 and rng.random() < hetero_prob:
            profiles = tuple(pool[i]
                             for i in rng.integers(0, len(pool), m))
        else:
            profiles = (pool[int(rng.integers(0, len(pool)))],) * m
    pattern = str(patterns[int(rng.integers(0, len(patterns)))])
    # aggregate service speed relative to the V100 reference
    speed = sum(V100_LLAMA2_7B.t_decode_base / p.t_decode_base
                for p in profiles)
    rate = float(rng.uniform(*rate_per_speed)) * speed
    # workload mix: full 5-task mixture or a random >=2-task slice
    if rng.random() < 0.5:
        tasks = None
    else:
        k = int(rng.integers(2, len(TASKS) + 1))
        tasks = tuple(TASKS[i] for i in rng.permutation(len(TASKS))[:k])
    samples = generate(n_requests, seed=seed + 1, tasks=tasks)
    times = arrival_times(n_requests, rate, pattern, seed=seed + 2)
    cap = min(p.capacity_tokens for p in profiles)
    budget = int(cap * 0.95)
    reqs = []
    for s, at in zip(samples, times):
        d = min(s.decode_tokens, max(budget - s.prompt_tokens, 1))
        reqs.append(Request(prompt_tokens=s.prompt_tokens, decode_tokens=d,
                            arrival=float(at), task=s.task))
    return Scenario(requests=reqs, profiles=profiles,
                    name=f"scn{seed}-{pattern}-m{m}", pattern=pattern,
                    rate=rate, seed=seed,
                    meta={"tasks": tasks or TASKS, "speed": speed},
                    samples=samples)


# tenant -> (traffic share, task mix or None for the full mixture);
# the default gateway mix: a latency-sensitive chat tenant, a heavy
# summarization-style tenant, and a long-tail tenant on the full mixture
DEFAULT_TENANTS = {
    "chat": (0.45, ("qna", "translation")),
    "batch": (0.30, ("sentiment", "in_context_qna")),
    "misc": (0.25, None),
}


@dataclass(frozen=True)
class SessionConfig:
    """Conversation-session shape for ``make_tenant_scenario``.

    All lengths are in prefix-cache blocks of ``block`` tokens so a
    turn's prompt is exactly the hash chain it claims to cover: turn k
    re-sends the whole context so far (system prompt + prior turns +
    new user input) and its reply extends the chain the NEXT turn's
    prompt starts from.  Sessions of one tenant share the tenant's
    system-prompt blocks, so even first turns can hit a warm cache."""
    block: int = 32                        # tokens per hash block
    turns: Tuple[int, int] = (2, 5)        # turns per session (incl.)
    think_time: float = 2.0                # mean gap after a reply (s)
    sys_blocks: int = 2                    # shared system prompt
    user_blocks: Tuple[int, int] = (1, 3)  # new user input per turn
    reply_blocks: Tuple[int, int] = (1, 3)  # assistant reply per turn
    max_blocks: int = 24                   # session context cap


def _session_requests(rng, sc: SessionConfig, names, assign, starts,
                      pools, budget_blocks: int,
                      ref: HardwareProfile, seed: int):
    """Grow every session turn by turn -> (requests, samples), arrival
    order not yet established (follow-ups interleave across sessions)."""
    B = sc.block
    reqs: List[Request] = []
    samples: List[Sample] = []
    for sid, (k, t0) in enumerate(zip(assign, starts)):
        tname = names[int(k)]
        n_turns = int(rng.integers(sc.turns[0], sc.turns[1] + 1))
        # tenant-shared system prefix, then session-private blocks
        chain: list = [("sys", tname, j) for j in range(sc.sys_blocks)]
        p_blocks = sc.sys_blocks + int(
            rng.integers(sc.user_blocks[0], sc.user_blocks[1] + 1))
        t = float(t0)
        for _turn in range(n_turns):
            d_blocks = int(rng.integers(sc.reply_blocks[0],
                                        sc.reply_blocks[1] + 1))
            if p_blocks + d_blocks > budget_blocks:
                break               # context would outgrow the KV pool
            while len(chain) < p_blocks + d_blocks:
                chain.append((seed, sid, len(chain)))
            s = pools[tname].pop()
            s.prompt_tokens = p_blocks * B
            s.decode_tokens = d_blocks * B
            reqs.append(Request(
                prompt_tokens=p_blocks * B, decode_tokens=d_blocks * B,
                arrival=t, task=s.task, tenant=tname,
                prefix_hashes=tuple(chain[:p_blocks]),
                full_hashes=tuple(chain[:p_blocks + d_blocks])))
            samples.append(s)
            # the follow-up arrives after the reply streams back plus a
            # think-time gap (open loop: an estimate, not the realized
            # completion time, so arrivals stay policy-independent)
            t += ref.request_time(p_blocks * B, d_blocks * B) \
                + float(rng.exponential(sc.think_time))
            p_blocks = p_blocks + d_blocks + int(
                rng.integers(sc.user_blocks[0], sc.user_blocks[1] + 1))
    return reqs, samples


def make_tenant_scenario(seed: int,
                         tenants: Optional[dict] = None,
                         n_requests: int = 400,
                         rate: float = 16.0,
                         pattern: str = "bursty",
                         profiles: Sequence[HardwareProfile] = (
                             V100_LLAMA2_7B,) * 4,
                         sessions: Optional[SessionConfig] = None,
                         **arrival_kw) -> Scenario:
    """Multi-tenant open-loop arrival stream for the serving gateway.

    Each tenant gets a traffic share and its own task mix (Table-1 task
    subsets -- tenants with different prompt/decode shapes are what make
    per-tenant SLO breakdowns interesting); arrivals follow one shared
    poisson/bursty/diurnal process.  Requests carry ``tenant`` labels
    and the scenario keeps ``samples`` so the learned length predictor
    (not the oracle) can sit in the routing loop.

    With ``sessions`` set, the stream is made of multi-turn
    conversations instead of independent queries: each follow-up's
    prompt extends the prior turn's full context (prompt + reply), every
    request carries the per-block ``prefix_hashes`` / ``full_hashes``
    chains the prefix-cache model consumes, and sessions of one tenant
    share that tenant's system-prompt blocks.  The arrival process
    drives session STARTS (at rate / mean-turns so the realized request
    rate stays ~``rate``); follow-ups land after an estimated reply
    stream plus an exponential think-time gap."""
    tenants = dict(tenants or DEFAULT_TENANTS)
    if sessions is not None:
        profiles = tuple(profiles)
        rng = np.random.default_rng(seed)
        names = sorted(tenants)
        w = np.array([tenants[t][0] for t in names], float)
        w /= w.sum()
        mean_turns = (sessions.turns[0] + sessions.turns[1]) / 2.0
        n_sessions = max(int(np.ceil(n_requests / mean_turns)), 1)
        starts = arrival_times(n_sessions, rate / mean_turns, pattern,
                               seed=seed + 3, **arrival_kw)
        assign = rng.choice(len(names), size=n_sessions, p=w)
        # one content sample per potential turn, per tenant task mix
        pools = {}
        for k, t in enumerate(names):
            count = (int(np.sum(assign == k)) * sessions.turns[1]
                     + 1)
            pools[t] = list(reversed(generate(
                count, seed=seed + 101 * (k + 1), tasks=tenants[t][1])))
        budget_blocks = min(
            int(min(p.capacity_tokens for p in profiles) * 0.95)
            // sessions.block, sessions.max_blocks)
        reqs, samples = _session_requests(
            rng, sessions, names, assign, starts, pools, budget_blocks,
            profiles[0], seed)
        order = np.argsort([r.arrival for r in reqs], kind="stable")
        reqs = [reqs[int(i)] for i in order[:n_requests]]
        samples = [samples[int(i)] for i in order[:n_requests]]
        return Scenario(requests=reqs, profiles=profiles,
                        name=f"sessions{seed}-{pattern}",
                        pattern=pattern, rate=rate, seed=seed,
                        meta={"tenants": {t: tenants[t][0]
                                          for t in names},
                              "sessions": n_sessions,
                              "block": sessions.block},
                        samples=samples)
    profiles = tuple(profiles)
    rng = np.random.default_rng(seed)
    names = sorted(tenants)
    w = np.array([tenants[t][0] for t in names], float)
    w /= w.sum()
    assign = rng.choice(len(names), size=n_requests, p=w)
    # per-tenant sample pools drawn with that tenant's task mix
    pools = {}
    for k, t in enumerate(names):
        count = int(np.sum(assign == k))
        pools[t] = list(reversed(generate(
            count, seed=seed + 101 * (k + 1), tasks=tenants[t][1])))
    times = arrival_times(n_requests, rate, pattern, seed=seed + 3,
                          **arrival_kw)
    budget = int(min(p.capacity_tokens for p in profiles) * 0.95)
    reqs: List[Request] = []
    samples: List[Sample] = []
    for k, at in zip(assign, times):
        t = names[k]
        s = pools[t].pop()
        d = min(s.decode_tokens, max(budget - s.prompt_tokens, 1))
        reqs.append(Request(prompt_tokens=s.prompt_tokens,
                            decode_tokens=d, arrival=float(at),
                            task=s.task, tenant=t))
        samples.append(s)
    return Scenario(requests=reqs, profiles=profiles,
                    name=f"tenants{seed}-{pattern}", pattern=pattern,
                    rate=rate, seed=seed,
                    meta={"tenants": {t: tenants[t][0] for t in names}},
                    samples=samples)


def scenario_stream(base_seed: int = 0, **kw) -> Callable[[int], Scenario]:
    """Deterministic episode-index -> Scenario mapping for the batched
    trainer (each episode a fresh draw; same base_seed -> same stream)."""
    def fn(ep: int) -> Scenario:
        return make_scenario(base_seed + 7919 * ep + 13, **kw)
    return fn


# -- nonstationary drift scenarios (online / continual learning) -------------

# pre-flip: chat-dominated short-prompt traffic
DRIFT_PRE_TENANTS = {
    "chat": (0.70, ("qna", "translation")),
    "batch": (0.20, ("sentiment", "in_context_qna")),
    "misc": (0.10, None),
}
# post-flip: the chat tenant collapses, a NEW ingest tenant (tenant
# churn) floods heavy long-prompt analytics work
DRIFT_POST_TENANTS = {
    "batch": (0.55, ("sentiment", "in_context_qna")),
    "ingest": (0.35, ("in_context_qna", "sentiment")),
    "misc": (0.10, ("qna",)),
}


def make_drift_scenario(seed: int,
                        n_requests: int = 600,
                        rate: float = 16.0,
                        flip_frac: float = 0.5,
                        pattern: str = "poisson",
                        profiles: Sequence[HardwareProfile] = (
                            V100_LLAMA2_7B,) * 4,
                        pre_tenants: Optional[dict] = None,
                        post_tenants: Optional[dict] = None,
                        chaos: object = "auto",
                        straggler_instance: int = 0,
                        straggler_factor: float = 4.0,
                        crash_instance: Optional[int] = 1,
                        restart_after: float = 12.0,
                        **arrival_kw) -> Scenario:
    """Nonstationarity stress scenario: ONE arrival stream whose
    generating distribution flips mid-flight.

    At request ``int(n_requests * flip_frac)`` the tenant mix switches
    from ``pre_tenants`` (chat-dominated, short prompts) to
    ``post_tenants`` (a new heavy ``ingest`` tenant -- workload-mix flip
    AND tenant churn in one event).  With ``chaos="auto"`` the flip also
    carries infrastructure drift, built on the existing fault-injection
    hooks: a persistent straggler (``straggler_factor`` x slower decode)
    on one instance from the flip onward, and a crash/restart on
    another shortly after.  Pass ``chaos=None`` for a pure workload
    flip, or an explicit ``FaultSchedule``.

    Everything is drawn from ``seed`` (deterministic: same seed, same
    stream, same faults).  ``meta`` carries ``flip_time`` /
    ``flip_index`` so benchmarks can score pre- and post-flip windows
    separately, and the schedule under ``meta["chaos"]`` is what
    ``GatewayConfig(chaos=...)`` consumes.  A frozen offline policy
    trained on the pre-flip mix provably degrades here; an online
    learner adapts (benchmarks/bench_online_drift.py gates exactly
    that)."""
    profiles = tuple(profiles)
    rng = np.random.default_rng(seed)
    times = arrival_times(n_requests, rate, pattern, seed=seed + 3,
                          **arrival_kw)
    n_pre = int(np.clip(int(n_requests * flip_frac), 0, n_requests))
    flip_time = float(times[n_pre]) if n_pre < n_requests \
        else float(times[-1])
    budget = int(min(p.capacity_tokens for p in profiles) * 0.95)
    segments = ((dict(pre_tenants or DRIFT_PRE_TENANTS), 0, n_pre),
                (dict(post_tenants or DRIFT_POST_TENANTS), n_pre,
                 n_requests))
    reqs: List[Request] = []
    samples: List[Sample] = []
    for si, (tenants, lo, hi) in enumerate(segments):
        if hi <= lo:
            continue
        names = sorted(tenants)
        w = np.array([tenants[t][0] for t in names], float)
        w /= w.sum()
        assign = rng.choice(len(names), size=hi - lo, p=w)
        pools = {}
        for k, t in enumerate(names):
            count = int(np.sum(assign == k))
            pools[t] = list(reversed(generate(
                count, seed=seed + 1009 * si + 101 * (k + 1),
                tasks=tenants[t][1])))
        for k, at in zip(assign, times[lo:hi]):
            t = names[k]
            s = pools[t].pop()
            d = min(s.decode_tokens, max(budget - s.prompt_tokens, 1))
            reqs.append(Request(prompt_tokens=s.prompt_tokens,
                                decode_tokens=d, arrival=float(at),
                                task=s.task, tenant=t))
            samples.append(s)
    schedule = chaos
    if chaos == "auto":
        # deferred import: core must stay importable without serving
        from repro.serving.chaos import Crash, FaultSchedule, Straggler
        horizon = float(times[-1]) + 120.0
        stragglers = (Straggler(flip_time, horizon,
                                straggler_instance % len(profiles),
                                straggler_factor),)
        crashes = ()
        if crash_instance is not None:
            crashes = (Crash(flip_time + 0.1 * (horizon - flip_time),
                             crash_instance % len(profiles),
                             restart_after),)
        schedule = FaultSchedule(crashes=crashes, stragglers=stragglers)
    return Scenario(requests=reqs, profiles=profiles,
                    name=f"drift{seed}-{pattern}", pattern=pattern,
                    rate=rate, seed=seed,
                    meta={"flip_time": flip_time, "flip_index": n_pre,
                          "chaos": schedule,
                          "pre_tenants": sorted(segments[0][0]),
                          "post_tenants": sorted(segments[1][0])},
                    samples=samples)


def generate_trace(n: int, seed: int = 0) -> List[Sample]:
    rng = np.random.default_rng(seed)
    apps = list(_TRACE_SPEC)
    w = np.array([_TRACE_SPEC[a][2] for a in apps])
    w /= w.sum()
    choice = rng.choice(len(apps), size=n, p=w)
    out = []
    for i in range(n):
        a = apps[choice[i]]
        (pmu, psig), (dmu, dsig), _ = _TRACE_SPEC[a]
        p = int(_lognormal_int(rng, pmu, psig, 16, 16384, None))
        d = int(_lognormal_int(rng, dmu, dsig, 1, 2048, None))
        out.append(Sample(a, apps.index(a), p, d,
                          np.zeros((1,), np.int32)))   # no content available
    return out
