"""Workload impact estimator (paper §5.2, Eq. 1-2).

Models the latency impact of adding an incoming request (p_i prompt tokens,
d_i estimated decode tokens) to instance m that already serves n requests
with (p_j, d_j) context tokens:

  Eq.(1)  T_p   = grad1 * (p_i^2 + sum_j (p_j + d_j))
          r_p   = 1                if T_p <= eps
                  1 - T_p / eps    otherwise
  Eq.(2)  r_d   = -grad2 * (sum_j (p_j + d_j) + p_i + d_i)

  r_mixing = alpha * r_p + (1 - alpha) * r_d

Note on Eq.(2): the paper's rendering reads ``-grad2 * sum_j(p_j+d_j) + p_i
+ d_i`` which is dimensionally inconsistent with the stated [-1, 1] range;
the intended grouping (confirmed by the range argument in §5.2) applies
grad2 to the whole token sum, which is what we implement.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.profiles import HardwareProfile


def prefill_impact(profile: HardwareProfile, p_i: int,
                   resident_tokens: float) -> float:
    """T_p of Eq.(1): estimated prompt-phase latency impact (seconds)."""
    return profile.grad1 * (float(p_i) ** 2 + resident_tokens)


def prefill_penalty(profile: HardwareProfile, p_i: int,
                    resident_tokens: float) -> float:
    """r_p of Eq.(1)."""
    t_p = prefill_impact(profile, p_i, resident_tokens)
    eps = profile.epsilon
    return 1.0 if t_p <= eps else 1.0 - t_p / eps


def decode_penalty(profile: HardwareProfile, p_i: int, d_i: int,
                   resident_tokens: float) -> float:
    """r_d of Eq.(2)."""
    return -profile.grad2 * (resident_tokens + p_i + d_i)


def r_mixing(profile: HardwareProfile, p_i: int, d_i: int,
             resident_tokens: float, alpha: float = 0.5) -> float:
    """Combined mixing penalty (higher is better)."""
    return (alpha * prefill_penalty(profile, p_i, resident_tokens)
            + (1 - alpha) * decode_penalty(profile, p_i, d_i,
                                           resident_tokens))


def mixing_per_instance(profile: HardwareProfile, p_i: int, d_i: int,
                        resident_token_sums: Sequence[float],
                        alpha: float = 0.5) -> np.ndarray:
    """r_mixing for routing the request to each instance."""
    return np.array([r_mixing(profile, p_i, d_i, s, alpha)
                     for s in resident_token_sums])


def mixing_vec(grad1, grad2, eps, p_i, d_i, s,
               alpha: float = 0.5) -> np.ndarray:
    """Vectorized ``r_mixing`` over per-lane calibration arrays
    (grad1/grad2/epsilon) -- the single implementation behind the
    vecsim fast paths in ``rl_router.mixing_scores`` and
    ``state.featurize_vec_many``.  Mirrors the scalar functions'
    association order on exact-integer token sums, so the produced
    floats are bit-identical to a per-instance ``r_mixing`` loop."""
    t_p = grad1 * (p_i ** 2 + s)
    r_p = np.where(t_p <= eps, 1.0, 1.0 - t_p / eps)
    r_d = -grad2 * (s + p_i + d_i)
    return alpha * r_p + (1 - alpha) * r_d


def mixing_heterogeneous(profiles: Sequence[HardwareProfile], p_i: int,
                         d_i: int, resident_token_sums: Sequence[float],
                         alpha: float = 0.5) -> np.ndarray:
    """r_mixing per instance with per-instance hardware profiles (mixed
    GPU generations behind one router): each instance's impact is judged
    against its own grad1/grad2 calibration."""
    return np.array([r_mixing(prof, p_i, d_i, s, alpha)
                     for prof, s in zip(profiles, resident_token_sums)])


def guidance_h(profile: HardwareProfile, p_i: int, d_i: int,
               resident_token_sums: Sequence[float], chosen: int,
               alpha: float = 0.5) -> float:
    """Eq.(4): h = r_mixing(chosen) - max_l r_mixing(l)  (<= 0; zero iff the
    chosen instance has the least mixing impact)."""
    scores = mixing_per_instance(profile, p_i, d_i, resident_token_sums,
                                 alpha)
    return float(scores[chosen] - scores.max())
