"""Unified simulator-backend registry.

Backend selection used to be a string scattered across
``Cluster(backend=)``, ``GatewayConfig.backend``,
``BatchedRLConfig.sim_backend`` and ``FidelityConfig.backends``; each
call site hard-coded its own dispatch, so adding a backend meant
touching all of them.  This module is now the single resolution point:

    from repro.core.backends import make_backend
    cluster = make_backend("jax").make_cluster(profile, 4)
    pool = make_backend("vec").make_pool(n_episodes=8)

``Cluster(backend=...)``, the gateway, the fidelity harness and the
batched trainer all resolve through it, so registering a backend once
(``@register_backend("name")``) makes it appear everywhere — the CLI
(``serve.py --backend``), fidelity's pairwise deltas, training configs.

Registered backends:

  * ``py``     — the per-instance Python reference stepper (the oracle).
  * ``vec``    — numpy structure-of-arrays pool, bit-exact vs ``py``.
  * ``jax``    — device-resident jitted round loop over the same SoA
                 layout (``core.jaxsim``); decision/clock bit-parity
                 with ``py``/``vec``, reward parity to the documented
                 summation-order tolerance (see docs/BACKENDS.md).
  * ``engine`` — real reduced-model engines behind
                 ``EngineClusterAdapter`` (needs constructed engines;
                 no pooled training form).

Pool-less backends raise ``ValueError`` from ``make_pool`` with a hint,
so the batched trainer's error messages stay actionable.
"""
from __future__ import annotations

from typing import Callable, Dict, Protocol, runtime_checkable


@runtime_checkable
class SimBackend(Protocol):
    """What a simulator backend must provide.

    ``make_cluster`` returns an object satisfying the Cluster protocol
    (enqueue/route/advance/collect, ``instances``, ``central``, ...);
    ``make_pool`` returns a multi-episode pool for the batched trainer
    (``VecSimPool``-shaped) or raises ``ValueError`` if the backend has
    no pooled form.
    """

    name: str

    def make_cluster(self, profile, n_instances: int, **kw): ...

    def make_pool(self, n_episodes: int, **kw): ...


_REGISTRY: Dict[str, Callable[[], "SimBackend"]] = {}


def register_backend(name: str):
    """Class decorator: ``@register_backend("vec")`` registers a
    zero-arg factory under ``name``.  Last registration wins (tests can
    shadow a backend)."""
    def deco(factory):
        _REGISTRY[name] = factory
        return factory
    return deco


def available_backends() -> tuple:
    return tuple(sorted(_REGISTRY))


def make_backend(name: str) -> "SimBackend":
    """Resolve a backend name to a fresh ``SimBackend`` instance."""
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown simulator backend {name!r}; "
            f"available: {', '.join(available_backends())}") from None
    return factory()


# -- built-in backends (lazy imports: the registry must be importable
# -- from simulator.py without a cycle) --------------------------------

@register_backend("py")
class PyBackend:
    """Per-instance Python reference stepper — the parity oracle."""

    name = "py"

    def make_cluster(self, profile, n_instances, **kw):
        from repro.core.simulator import Cluster
        kw.pop("backend", None)
        return Cluster(profile, n_instances, backend="py", **kw)

    def make_pool(self, n_episodes, **kw):
        raise ValueError(
            "the 'py' backend steps instances one object at a time and "
            "has no pooled form; use backend='vec' or 'jax' for the "
            "batched trainer")


@register_backend("vec")
class VecBackend:
    """Numpy structure-of-arrays pool (bit-exact vs 'py')."""

    name = "vec"

    def _pool_cls(self):
        from repro.core.vecsim import VecSimPool
        return VecSimPool

    def make_cluster(self, profile, n_instances, **kw):
        from repro.core.vecsim import VecCluster
        kw.pop("backend", None)
        kw.setdefault("pool", self._pool_cls()(1))
        return VecCluster(profile, n_instances, **kw)

    def make_pool(self, n_episodes, **kw):
        return self._pool_cls()(n_episodes, **kw)


@register_backend("jax")
class JaxBackend(VecBackend):
    """Device-resident jitted round loop over the vec SoA layout."""

    name = "jax"

    def _pool_cls(self):
        from repro.core.jaxsim import JaxSimPool
        return JaxSimPool


@register_backend("engine")
class EngineBackend:
    """Real reduced-model engines behind the cluster adapter."""

    name = "engine"

    def make_cluster(self, profile, n_instances, engines=None, **kw):
        if engines is None:
            raise ValueError(
                "the 'engine' backend wraps real LLM engines: pass "
                "engines=[LLMInstance, ...] (see serving.fidelity for "
                "construction from a model config) — it cannot be "
                "built from a hardware profile alone")
        from repro.serving.gateway import EngineClusterAdapter
        return EngineClusterAdapter(engines)

    def make_pool(self, n_episodes, **kw):
        raise ValueError(
            "the 'engine' backend has no pooled simulator form; "
            "train on 'vec' or 'jax' and evaluate on the engine")
