"""Pure-jnp oracle for single-token decode attention (GQA, length-masked)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         kv_len: jax.Array) -> jax.Array:
    """q [B,1,H,hd]; k/v [B,S,KV,hd]; kv_len [B] valid prefix lengths
    -> [B,1,H,hd]."""
    b, _, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg,
                        k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    mask = jnp.arange(s)[None, :] < kv_len[:, None]          # [B,S]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
