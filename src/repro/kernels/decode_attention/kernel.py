"""Decode attention Pallas TPU kernel: one query token vs a long KV cache.

TPU adaptation (vs a CUDA decode kernel that maps heads to warps): the
GQA q-head GROUP (g rows) x head_dim tile is the MXU's M x K operand and
the KV sequence is swept in (block_k x head_dim) VMEM tiles with an
online-softmax scratch carry -- the sweep is the memory-bound part and is
what the roofline's HBM term measures.  Valid-length masking comes from a
scalar-memory (SMEM) per-batch length, so padded cache tail blocks add
no numerical effect.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in jax 0.5
_CompilerParams = getattr(pltpu, 'CompilerParams',
                          getattr(pltpu, 'TPUCompilerParams', None))

NEG_INF = -1e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                *, block_k: int, sm_scale: float, n_kv: int,
                k_scale_ref=None, v_scale_ref=None):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)           # [g, hd]
    k = k_ref[0, 0].astype(jnp.float32)           # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)
    if k_scale_ref is not None:                   # int8 cache: in-VMEM
        k = k * k_scale_ref[0, 0].astype(jnp.float32)[:, None]
        v = v * v_scale_ref[0, 0].astype(jnp.float32)[:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    kv_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kv_pos < len_ref[0], s, NEG_INF)
    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def decode_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                            kv_len: jax.Array, *, block_k: int = 512,
                            k_scale: jax.Array = None,
                            v_scale: jax.Array = None,
                            interpret: bool = False) -> jax.Array:
    """q [B,1,H,hd]; k/v [B,S,KV,hd] (bf16, or int8 with per-token-head
    k_scale/v_scale [B,S,KV]); kv_len [B] -> [B,1,H,hd].

    int8 mode streams the quantized cache from HBM and dequantizes in
    VMEM -- the HBM traffic (the decode bottleneck) is halved."""
    b, _, h, hd = q.shape
    s, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_k = min(block_k, s)
    assert s % block_k == 0
    n_kv = s // block_k
    int8 = k_scale is not None
    # group queries by kv head: [B, KV, g, hd]
    qg = q.reshape(b, kvh, g, hd)
    kt = k.transpose(0, 2, 1, 3)                  # [B, KV, S, hd]
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, kvh, n_kv)
    kernel = functools.partial(_dec_kernel, block_k=block_k,
                               sm_scale=1.0 / (hd ** 0.5), n_kv=n_kv)
    in_specs = [
        pl.BlockSpec((1,), lambda bi, ki, ii: (bi,),
                     memory_space=pltpu.SMEM),
        pl.BlockSpec((1, 1, g, hd), lambda bi, ki, ii: (bi, ki, 0, 0)),
        pl.BlockSpec((1, 1, block_k, hd),
                     lambda bi, ki, ii: (bi, ki, ii, 0)),
        pl.BlockSpec((1, 1, block_k, hd),
                     lambda bi, ki, ii: (bi, ki, ii, 0)),
    ]
    args = [kv_len.astype(jnp.int32), qg, kt, vt]
    if int8:
        def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                    m_ref, l_ref, acc_ref):
            kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, k_scale_ref=ks_ref, v_scale_ref=vs_ref)
        scale_spec = pl.BlockSpec((1, 1, block_k),
                                  lambda bi, ki, ii: (bi, ki, ii))
        in_specs += [scale_spec, scale_spec]
        args += [k_scale.transpose(0, 2, 1), v_scale.transpose(0, 2, 1)]
        body = _kernel
        out_dtype = jnp.bfloat16
    else:
        body = kernel
        out_dtype = q.dtype
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, ki, ii: (bi, ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
    return out.reshape(b, 1, h, hd).astype(q.dtype)
