"""Jitted public wrapper for the decode-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import decode_attention_kernel
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, kv_len, block_k: int = 512,
                     interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return decode_attention_kernel(q, k, v, kv_len, block_k=block_k,
                                   interpret=interpret)


reference = decode_attention_ref
