"""FlashAttention-2 style Pallas TPU kernel (causal, GQA).

Tiling: grid (batch, q_head, q_blocks, kv_blocks) with the KV axis
innermost; the online-softmax state (m, l, acc) lives in VMEM scratch and
persists across the KV sweep of one (b, h, iq) cell.  Q/K/V blocks are
(block_q x head_dim) / (block_k x head_dim) VMEM tiles; head_dim and the
block sizes are kept multiples of 128 on the lane axis so the MXU sees
aligned operands.  GQA maps q-head h to kv-head h // group via the K/V
BlockSpec index maps -- no KV duplication in HBM or VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in jax 0.5
_CompilerParams = getattr(pltpu, 'CompilerParams',
                          getattr(pltpu, 'TPUCompilerParams', None))

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               block_q: int, block_k: int, sm_scale: float, causal: bool,
               q_offset: int, n_kv: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s = s * sm_scale                              # [bq, bk]
    if causal:
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + q_offset
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    m_ref[...] = m_cur
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv - 1)
    def _fin():
        denom = jnp.maximum(l_ref[...], 1e-20)[:, None]
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> [B,Sq,H,hd]."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    n_q, n_kv = sq // block_q, skv // block_k
    # layout: heads as leading grid dims -> [B,H,S,hd]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    grid = (b, h, n_q, n_kv)
    kernel = functools.partial(
        _fa_kernel, block_q=block_q, block_k=block_k,
        sm_scale=1.0 / (hd ** 0.5), causal=causal, q_offset=q_offset,
        n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
