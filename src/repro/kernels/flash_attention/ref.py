"""Pure-jnp oracle for the flash-attention kernel (GQA, causal)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True,
                        q_offset: int = 0) -> jax.Array:
    """q [B,Sq,H,hd]; k/v [B,Skv,KV,hd] -> [B,Sq,H,hd] (f32 math)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd).astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg,
                        k.astype(jnp.float32)) / jnp.sqrt(float(hd))
    if causal:
        q_pos = jnp.arange(sq)[:, None] + q_offset
        kv_pos = jnp.arange(k.shape[1])[None, :]
        mask = kv_pos <= q_pos
        scores = jnp.where(mask[None, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)
