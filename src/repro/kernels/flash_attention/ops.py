"""Jitted public wrapper for the flash-attention kernel.

On CPU (no TPU backend) the kernel runs in interpret mode when explicitly
requested; the model's default CPU path is the jnp reference.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return flash_attention_kernel(q, k, v, causal=causal,
                                  q_offset=q_offset, block_q=block_q,
                                  block_k=block_k, interpret=interpret)


reference = flash_attention_ref
