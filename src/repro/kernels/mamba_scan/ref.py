"""Pure-jnp oracle for the selective-scan kernel: sequential recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(xc, dt, b, c, a_log, d, h0):
    """xc,dt [B,S,di]; b,c [B,S,ds]; a_log [di,ds]; d [di]; h0 [B,di,ds]
    -> (y [B,S,di], h_final)."""
    a = -jnp.exp(a_log.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(dt_t[..., None] * a)             # [B,di,ds]
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], -1) + x_t * d
        return h, y

    xs = (xc.astype(jnp.float32).transpose(1, 0, 2),
          dt.astype(jnp.float32).transpose(1, 0, 2),
          b.astype(jnp.float32).transpose(1, 0, 2),
          c.astype(jnp.float32).transpose(1, 0, 2))
    h_final, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2).astype(xc.dtype), h_final
