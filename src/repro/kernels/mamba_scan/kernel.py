"""Selective-scan (Mamba-1) Pallas TPU kernel.

TPU adaptation: a CUDA selective-scan holds per-thread recurrence state in
registers; here the channel axis is blocked to the VPU lane width (128
multiples) and the [block_c x d_state] state tile lives in VMEM scratch,
persisting across the sequence-chunk sweep (grid innermost axis).  Within
a chunk the recurrence runs as a fori_loop over timesteps on VMEM tiles;
chunk x block_c tiles of x/dt stream from HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# renamed TPUCompilerParams -> CompilerParams in jax 0.5
_CompilerParams = getattr(pltpu, 'CompilerParams',
                          getattr(pltpu, 'TPUCompilerParams', None))


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref,
                 hout_ref, h_ref, *, chunk: int, n_chunks: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = -jnp.exp(a_ref[...].astype(jnp.float32))        # [bc, ds]
    d = d_ref[...].astype(jnp.float32)                  # [bc]

    def step(t, _):
        x_t = x_ref[0, t].astype(jnp.float32)           # [bc]
        dt_t = dt_ref[0, t].astype(jnp.float32)         # [bc]
        b_t = b_ref[0, t].astype(jnp.float32)           # [ds]
        c_t = c_ref[0, t].astype(jnp.float32)           # [ds]
        h = h_ref[...]
        h = jnp.exp(dt_t[:, None] * a) * h + \
            (dt_t * x_t)[:, None] * b_t[None, :]
        h_ref[...] = h
        y = jnp.sum(h * c_t[None, :], axis=1) + x_t * d
        y_ref[0, t] = y.astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(ic == n_chunks - 1)
    def _fin():
        hout_ref[0] = h_ref[...].astype(hout_ref.dtype)


def mamba_scan_kernel(xc, dt, b, c, a_log, d, h0=None, *, chunk: int = 256,
                      block_c: int = 128, interpret: bool = False):
    """xc,dt [B,S,di]; b,c [B,S,ds]; a_log [di,ds]; d [di]
    -> (y [B,S,di], h_final [B,di,ds]).  h0 must be zeros (cache handoff
    restarts use the decode path)."""
    bsz, s, di = xc.shape
    ds = b.shape[-1]
    chunk = min(chunk, s)
    block_c = min(block_c, di)
    assert s % chunk == 0 and di % block_c == 0
    n_chunks, n_cb = s // chunk, di // block_c
    grid = (bsz, n_cb, n_chunks)
    kernel = functools.partial(_scan_kernel, chunk=chunk,
                               n_chunks=n_chunks)
    y, h_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_c),
                         lambda bi, ci, ii: (bi, ii, ci)),
            pl.BlockSpec((1, chunk, block_c),
                         lambda bi, ci, ii: (bi, ii, ci)),
            pl.BlockSpec((1, chunk, ds), lambda bi, ci, ii: (bi, ii, 0)),
            pl.BlockSpec((1, chunk, ds), lambda bi, ci, ii: (bi, ii, 0)),
            pl.BlockSpec((block_c, ds), lambda bi, ci, ii: (ci, 0)),
            pl.BlockSpec((block_c,), lambda bi, ci, ii: (ci,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_c),
                         lambda bi, ci, ii: (bi, ii, ci)),
            pl.BlockSpec((1, block_c, ds), lambda bi, ci, ii: (bi, ci, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, di), xc.dtype),
            jax.ShapeDtypeStruct((bsz, di, ds), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_c, ds), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xc, dt, b, c, a_log, d)
    return y, h_final
