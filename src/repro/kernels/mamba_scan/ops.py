"""Jitted public wrapper for the selective-scan kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.mamba_scan.kernel import mamba_scan_kernel
from repro.kernels.mamba_scan.ref import mamba_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "block_c",
                                             "interpret"))
def mamba_scan(xc, dt, b, c, a_log, d, h0=None, chunk: int = 256,
               block_c: int = 128, interpret: bool = None):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return mamba_scan_kernel(xc, dt, b, c, a_log, d, h0, chunk=chunk,
                             block_c=block_c, interpret=interpret)


reference = mamba_scan_ref
