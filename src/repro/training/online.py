"""Online continual learning in the live gateway (Lodestar-style).

The offline trainers freeze a Q-head behind ``RLPolicy``; production
traffic drifts (diurnal mix shifts, tenant churn, instances failing and
recovering), exactly the regime where a frozen head degrades while the
heuristics stay merely mediocre.  ``OnlineTrainer`` closes the loop on
the gateway's OWN serving stream:

  * every routing decision is recorded with the same state/action/
    reward semantics as ``RoutingEnv`` (Eq. 3 backlog integral via the
    shared ``BacklogTracker``, completion bonus, SLA-watchdog penalty),
    assembled into truncated n-step Monte-Carlo returns by the shared
    ``NStepAssembler``, and bulk-inserted into the learner's
    ``ReplayBuffer`` through the packed ``add_rows`` path;
  * learner steps are dispatched asynchronously (``learn(sync=False)``,
    the ``batched_rl`` overlap trick) between arrival windows, so the
    XLA gradient step runs on a worker thread while the gateway ticks;
  * refreshed weights are published to the SERVING agent at a bounded
    cadence via ``RLPolicy.hot_swap`` -- one atomic reference store, so
    admission never pauses and readers never see a torn tree;
  * guided epsilon-exploration samples from a softmax over the
    r_mixing guidance bonus (never uniformly over bad placements);
  * a SAFE FALLBACK guardrail watches the Q-head's windowed divergence
    from the r_mixing yardstick and the windowed SLO attainment: past
    either threshold the gateway routes by the guidance argmax (the
    exact ``MixingImpactPolicy`` decision rule) for a cooldown while
    learning continues on the recorded stream -- worst case is
    impact-heuristic parity, never an unhinged Q-head.

With ``learn=False``, ``eps=0`` and the guardrail off, the decision
path is identical to a frozen ``RLPolicy`` (pinned by
tests/test_online.py), so the recorder can shadow any frozen deployment
at zero behavioral cost.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.core import rl_router as rl
from repro.core import state as state_lib
from repro.core.dqn import DQNAgent
from repro.core.rl_router import BacklogTracker, NStepAssembler
from repro.serving.policies import RLPolicy


@dataclass
class OnlineConfig:
    # -- learning loop --------------------------------------------------
    learn: bool = True              # False = pure shadow recorder
    learn_every: int = 4            # ticks between async learner steps
    publish_every: int = 25         # ticks between weight publishes
    flush_rows: int = 64            # pack-buffer size forcing add_rows
    # guided exploration: with prob eps a decision is sampled from a
    # softmax over the r_mixing guidance bonus (temperature
    # explore_temp) instead of the greedy Q pick
    eps: float = 0.05
    explore_temp: float = 0.05
    # reward-side guidance weight (RoutingEnv's guide_w).  0 by
    # default: continual adaptation must come from the latency signal,
    # not from agreeing with a heuristic that may be wrong under drift.
    guide_w: float = 0.0
    # -- safe-fallback guardrail ---------------------------------------
    guard: bool = True
    guard_window: int = 48          # decisions in the regret window
    guard_regret: float = 0.12      # mean r_mixing regret tripping it
    guard_slo: float = 0.0          # SLO attainment floor (0 = off)
    guard_min_slo_n: int = 24       # completions before SLO judging
    guard_cooldown: float = 20.0    # seconds routed by r_mixing per trip
    # -- persistence ----------------------------------------------------
    warm_start: Optional[str] = None   # checkpoint dir (full or params)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 0       # learner steps between saves (0=off)
    seed: int = 0


class OnlinePolicy(RLPolicy):
    """The gateway-facing shim: an ``RLPolicy`` (same serving agent,
    same ``hot_swap`` surface, same ``explain``) whose decisions and
    tick callbacks route through the trainer."""
    name = "online"

    def __init__(self, agent, router_cfg: rl.RouterConfig, trainer):
        super().__init__(agent, router_cfg)
        self.trainer = trainer

    def bind(self, gateway):
        self.trainer.bind(gateway)

    def on_pre_route(self, cluster):
        self.trainer.on_pre_route(cluster)

    def on_tick(self, cluster, done_now):
        self.trainer.on_tick(cluster, done_now)

    def on_forced(self, action: int):
        self.trainer.on_forced(action)

    def on_run_end(self):
        self.trainer.on_run_end()

    def route(self, cluster, req, d_hat: int) -> Optional[int]:
        return self.trainer.decide(cluster, req, d_hat)


class OnlineTrainer:
    """Streams the gateway's own (s, a, r, s') transitions into the
    replay buffer and keeps the served Q-head fresh.  Attach via
    ``trainer.policy`` (the gateway resolves ``bind`` / ``on_pre_route``
    / ``on_tick`` / ``on_forced`` / ``on_run_end`` by getattr)."""

    def __init__(self, router_cfg: rl.RouterConfig,
                 cfg: Optional[OnlineConfig] = None,
                 agent: Optional[DQNAgent] = None,
                 m: Optional[int] = None):
        self.rcfg = router_cfg
        self.cfg = cfg or OnlineConfig()
        self.m = m or router_cfg.n_instances
        # the LEARNER agent: owns the replay buffer, optimizer, RNG
        self.agent = agent or rl.make_agent(router_cfg, m=self.m)
        self.warm_started_step: Optional[int] = None
        if self.cfg.warm_start:
            from repro.training.checkpoint import restore_learner
            self.warm_started_step = restore_learner(self.cfg.warm_start,
                                                     self.agent)
        # the SERVING twin: decisions read only its published params;
        # it never observes or learns, so it shares the learner's
        # buffer storage instead of allocating its own
        self.serve_agent = DQNAgent(self.agent.cfg, seed=self.cfg.seed)
        self.serve_agent.buffer = self.agent.buffer
        self.policy = OnlinePolicy(self.serve_agent, router_cfg, self)
        self.policy.hot_swap(self.agent.params, self.agent.target)
        self.rng = np.random.default_rng(self.cfg.seed)
        self.asm = NStepAssembler(router_cfg.nstep, router_cfg.nstep_gamma)
        self.scale = (1.0 if router_cfg.potential_shaping
                      else router_cfg.reward_scale)
        # transition assembly state
        self._pending: Optional[tuple] = None     # (s, a) awaiting span r
        self._span_r = 0.0
        self._rows: list = []
        self._seen: set = set()
        self._bk: Optional[BacklogTracker] = None
        self._slo_fn = None
        self.gateway = None
        self._cluster = None
        self._tick = 0
        # guardrail state
        self.mode = "rl"
        self._until = 0.0
        self._regret: deque = deque(maxlen=self.cfg.guard_window)
        self._slo: deque = deque(maxlen=self.cfg.guard_window)
        # persistence
        self._mgr = None
        self._last_ckpt = 0
        self._pub_step = -1
        # counters
        self.decisions = 0
        self.explored = 0
        self.fallback_decisions = 0
        self.fallback_entries = 0
        self.transitions = 0
        self.publishes = 0
        self.forced = 0

    # -- gateway hooks --------------------------------------------------
    def bind(self, gateway):
        cluster = gateway.cluster
        if not getattr(cluster, "is_vec", False):
            insts = getattr(cluster, "instances", ())
            if not all(hasattr(i, "on_token") for i in insts):
                raise ValueError(
                    "OnlineTrainer needs the py or vec simulator "
                    "backend (the engine adapter fires no decode/"
                    "preempt events for the backlog reward)")
        self.gateway = gateway
        self._cluster = cluster
        est = gateway.length.estimate
        self._bk = BacklogTracker(cluster, cluster.profile,
                                  lambda r: max(int(est(r)), 1))
        self._slo_fn = gateway.cfg.slo.attained
        if self.cfg.checkpoint_dir and self.cfg.checkpoint_every:
            from repro.training.checkpoint import CheckpointManager
            self._mgr = CheckpointManager(self.cfg.checkpoint_dir)

    def on_pre_route(self, cluster):
        """Register every request newly enqueued this tick with the
        backlog tracker (runs after admission/retries/hedges, before
        routing -- everything new is still in ``cluster.central``).
        Re-entries (crash orphans, hedged re-dispatches) keep their
        original terms, exactly like RoutingEnv's persistent S/T
        entries."""
        seen = self._seen
        for r in cluster.central:
            if r.rid not in seen:
                seen.add(r.rid)
                self._bk.register(r)

    def on_forced(self, action: int):
        """The gateway's SLA watchdog overrode our defer: charge the
        deferring decision RoutingEnv's sla_penalty."""
        self._span_r -= self.rcfg.sla_penalty
        self.forced += 1

    def on_tick(self, cluster, done_now):
        """Per-tick reward accrual + the background learner cadence
        (between arrival windows, off the routing critical path)."""
        c = self.rcfg
        self._bk.note_finished(done_now)
        if c.potential_shaping:
            self._span_r += c.r_w_shaped * len(done_now)
        else:
            self._span_r += (self._bk.penalty() * c.dt
                             + c.r_w * len(done_now))
        if done_now and self._slo_fn is not None:
            for r in done_now:
                self._slo.append(1.0 if self._slo_fn(r) else 0.0)
        self._tick += 1
        if not self.cfg.learn:
            return
        flush_due = self._tick % self.cfg.learn_every == 0
        if self._rows and (flush_due
                           or len(self._rows) >= self.cfg.flush_rows):
            self.agent.buffer.add_rows(np.stack(self._rows))
            self._rows.clear()
        if flush_due:
            self.agent.learn(sync=False)
        if (self._tick % self.cfg.publish_every == 0
                and self.agent.steps > max(self._pub_step, 0)):
            self._publish()
        if (self._mgr is not None and self.agent.steps
                >= self._last_ckpt + self.cfg.checkpoint_every):
            self._last_ckpt = self.agent.steps
            tree, extra = self.agent.full_state()
            self._mgr.save(self.agent.steps, tree, extra)

    def on_run_end(self):
        """Stream over: close the last span, drain open n-step windows
        on the final state, flush rows, publish, checkpoint."""
        cluster = self._cluster
        s = self._featurize(cluster, self._head_dhat(cluster))
        mask = state_lib.action_mask(cluster)
        self._close_span(s, mask)
        for t in self.asm.drain():
            self._pack(t, s, mask)
        if self._rows:
            self.agent.buffer.add_rows(np.stack(self._rows))
            self._rows.clear()
        if self.cfg.learn:
            self._publish()
        if self._mgr is not None:
            tree, extra = self.agent.full_state()
            self._mgr.save(self.agent.steps, tree, extra, sync=True)
            self._mgr.close()
            self._mgr = None

    # -- the decision path ---------------------------------------------
    def decide(self, cluster, req, d_hat: int) -> Optional[int]:
        """One routing decision: RLPolicy-identical math (mask, scores,
        bonus, featurize, guided Q argmax), plus transition recording,
        guided exploration, and the guardrail."""
        rcfg = self.rcfg
        ccfg = self.cfg
        mask = state_lib.action_mask(cluster)
        w_sel = rcfg.guidance_floor if rcfg.variant == "guided" else 0.0
        scores = rl.mixing_scores(cluster, req, d_hat, rcfg.alpha,
                                  cache_weight=rcfg.cache_weight)
        bonus = rl.guidance_from_scores(cluster, req, d_hat, scores,
                                        rcfg.defer_prior_bias)
        decomposed = (self.serve_agent.cfg.q_arch == "decomposed"
                      or cluster.m + 1 == self.serve_agent.cfg.n_actions)
        if not decomposed:
            # fixed-m MLP on a resized cluster: guidance fallback, no
            # recording (the state no longer fits the network)
            b = np.where(mask, bonus, -np.inf)
            self.decisions += 1
            a = int(np.argmax(b))
            return a if a < cluster.m else None
        s = self._featurize(cluster, d_hat)
        self._close_span(s, mask)          # previous decision's span ends
        now = cluster.t
        if self.mode == "fallback" and now >= self._until:
            self.mode = "rl"               # cooldown over: re-probe Q
            self._regret.clear()
        if self.mode == "fallback":
            b = np.where(mask, bonus, -np.inf)
            a = int(np.argmax(b))
            self.fallback_decisions += 1
        else:
            explored = (ccfg.learn and ccfg.eps > 0
                        and self.rng.random() < ccfg.eps)
            if explored:
                a = self._sample_guided(bonus, mask)
                self.explored += 1
            else:
                prior = w_sel * bonus if w_sel else None
                a = int(self.serve_agent.act(
                    s, mask, epsilon=0.0, prior=prior,
                    q_squash=rcfg.q_squash if w_sel else 0.0))
            if ccfg.guard:
                fin = np.where(mask, bonus, -np.inf)
                gap = float(fin.max() - fin[a])
                self._regret.append(gap if np.isfinite(gap) else 0.0)
                self._check_guard(now)
        if ccfg.guide_w and a < cluster.m and np.isfinite(scores[a]):
            self._span_r += ccfg.guide_w * float(scores[a] - scores.max())
        self._pending = (s, a)
        self.decisions += 1
        return a if a < cluster.m else None

    def _sample_guided(self, bonus: np.ndarray, mask: np.ndarray) -> int:
        """Exploration draw ~ softmax(bonus / temp) over valid actions:
        biased toward good placements instead of uniform over bad
        ones."""
        valid = np.flatnonzero(mask)
        z = bonus[valid].astype(np.float64) \
            / max(self.cfg.explore_temp, 1e-6)
        z -= z.max()
        p = np.exp(z)
        p /= p.sum()
        return int(valid[self.rng.choice(len(valid), p=p)])

    def _check_guard(self, now: float):
        c = self.cfg
        trip = (len(self._regret) >= c.guard_window
                and float(np.mean(self._regret)) > c.guard_regret)
        if not trip and c.guard_slo > 0 \
                and len(self._slo) >= c.guard_min_slo_n:
            trip = float(np.mean(self._slo)) < c.guard_slo
        if trip:
            self.mode = "fallback"
            self._until = now + c.guard_cooldown
            self.fallback_entries += 1
            self._regret.clear()
            self._slo.clear()

    # -- transition assembly --------------------------------------------
    def _close_span(self, s2: np.ndarray, mask2: np.ndarray):
        """The span between the previous decision and this one is over:
        feed (s, a, span_reward) into the n-step assembler and pack any
        matured windows (``s2``/``mask2`` are the dead done=1.0
        bootstrap columns, mirroring the offline loop)."""
        if self._pending is None:
            self._span_r = 0.0
            return
        s0, a0 = self._pending
        r = self._span_r / self.scale
        self._pending = None
        self._span_r = 0.0
        if self.rcfg.nstep > 0:
            for t in self.asm.add(s0, a0, r):
                self._pack(t, s2, mask2)
        else:
            self._pack((s0, a0, r), s2, mask2, done=0.0)

    def _pack(self, t: tuple, s2: np.ndarray, mask2: np.ndarray,
              done: float = 1.0):
        """Replicate DQNAgent.observe (reward-centering EMA included --
        ``add_rows`` bypasses it) into a packed replay row."""
        s0, a0, r = t
        agent = self.agent
        if agent.cfg.center_rewards:
            if not agent._r_init:
                agent.r_mean, agent._r_init = float(r), True
            else:
                agent.r_mean += agent.cfg.center_beta * (r - agent.r_mean)
            r = r - agent.r_mean
        d = agent.cfg.state_dim
        row = np.empty(2 * d + 4 + agent.cfg.n_actions, np.float32)
        row[:d] = s0
        row[d:2 * d] = s2
        row[2 * d] = a0
        row[2 * d + 1] = r
        row[2 * d + 2] = done
        row[2 * d + 3:-1] = mask2
        row[-1] = 1.0
        self._rows.append(row)
        self.transitions += 1

    # -- helpers --------------------------------------------------------
    def _featurize(self, cluster, d_hat: int) -> np.ndarray:
        rcfg = self.rcfg
        return state_lib.featurize(
            cluster, cluster.profile, n_buckets=rcfg.n_buckets,
            include_impact=rcfg.include_impact_features,
            predict_decode=lambda r: d_hat, alpha=rcfg.alpha,
            include_hardware=rcfg.include_hardware_features,
            include_cache=rcfg.include_cache_features,
            include_health=rcfg.include_health_features)

    def _head_dhat(self, cluster) -> int:
        if self.gateway is not None and cluster.central:
            return max(int(self.gateway.length.estimate(
                cluster.central[0])), 1)
        return 1

    def _publish(self):
        self.policy.hot_swap(self.agent.params, self.agent.target)
        self.publishes += 1
        self._pub_step = self.agent.steps

    def telemetry(self) -> dict:
        out = self.agent.telemetry()
        out.update({
            "decisions": float(self.decisions),
            "explored": float(self.explored),
            "forced": float(self.forced),
            "fallback_decisions": float(self.fallback_decisions),
            "fallback_entries": float(self.fallback_entries),
            "transitions": float(self.transitions),
            "publishes": float(self.publishes),
            "mode": self.mode,
        })
        return out
