"""Train-step construction: grads, EP replica symmetrization, optional
int8 gradient compression, AdamW update.  The step is a single pjit-able
function (params/opt donated)."""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.models import model as model_lib
from repro.training import optimizer as opt_lib


def symmetrize_ep_grads(cfg: ModelConfig, grads):
    """Average gradients across EP replica slots.

    When E < n_ep_shards, routed expert weights are stored replicated
    (slot s holds expert s // R); the replicas receive different gradients
    (they saw different tokens) and must be re-synchronized.
    """
    if cfg.moe is None or cfg.moe.impl != "ep":
        return grads
    e = cfg.moe.n_experts

    def one(path, g):
        names = [str(getattr(k, "key", "")) for k in path]
        if "routed" not in names or names[-1] not in ("w_up", "w_down",
                                                      "w_gate"):
            return g
        ax = 1 if "layers" in names else 0
        e_store = g.shape[ax]
        if e_store == e:
            return g
        r = e_store // e
        shape = g.shape
        grouped = g.reshape(shape[:ax] + (e, r) + shape[ax + 1:])
        mean = jnp.mean(grouped, axis=ax + 1, keepdims=True)
        return jnp.broadcast_to(mean, grouped.shape).reshape(shape)

    return jax.tree_util.tree_map_with_path(one, grads)


def make_train_step(cfg: ModelConfig, opt_cfg: opt_lib.OptimizerConfig,
                    compress_grads: Optional[Callable] = None
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  ``compress_grads`` optionally maps the grad tree through a
    (quantize -> all-reduce -> dequantize) hook."""

    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            model_lib.loss_fn, has_aux=True)(params, cfg, batch)
        if compress_grads is not None:
            grads = compress_grads(grads)
        grads = symmetrize_ep_grads(cfg, grads)
        params, opt_state, metrics = opt_lib.update(
            opt_cfg, grads, opt_state, params)
        metrics.update({"loss": loss, **aux})
        return params, opt_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, data_shards: int = 0):
    from repro.models import params as params_lib
    params = params_lib.init_params(key, cfg, data_shards)
    return params, opt_lib.init(params)


# -- router-RL training entrypoint ------------------------------------------

def train_router(router_cfg, scenario_fn, n_episodes: int,
                 batched: bool = True, batch_cfg=None, agent=None,
                 predict_decode: Optional[Callable] = None,
                 length_predictor=None,
                 valid_fn: Optional[Callable] = None,
                 verbose: bool = False) -> Dict[str, Any]:
    """Unified entrypoint for training the routing policy (the system's
    other trainable component, next to the LM train step above).

    ``scenario_fn(ep)`` yields a `workload.Scenario` per episode.  The
    default path is the batched multi-episode runner
    (`core.batched_rl.train_batched`); ``batched=False`` falls back to
    the sequential paper-faithful loop, which requires every scenario to
    be homogeneous (one hardware profile, cfg.n_instances wide).

    ``length_predictor`` (a `core.predictor.BucketPredictor`) puts the
    LEARNED length estimate in the training loop: each scenario's
    requests are stamped with predictor d-hats (one batched jitted
    forward per episode) and the env's ``predict_decode`` reads the
    stamp -- the router trains on the same imperfect signal it serves
    with, instead of the oracle decode length.
    """
    from repro.core import batched_rl, rl_router

    if length_predictor is not None:
        from repro.core import predictor as pred_lib
        if predict_decode is not None:
            raise ValueError(
                "pass either predict_decode or length_predictor")
        scenario_fn = pred_lib.annotating_stream(scenario_fn,
                                                 length_predictor)
        predict_decode = pred_lib.predicted_decode
        if valid_fn is not None:
            inner_valid = valid_fn

            def valid_fn():
                scn = inner_valid()
                if scn.samples is not None:
                    pred_lib.annotate_requests(length_predictor,
                                               scn.requests, scn.samples)
                return scn

    if batched:
        return batched_rl.train_batched(
            router_cfg, scenario_fn, n_episodes, bcfg=batch_cfg,
            agent=agent, predict_decode=predict_decode,
            valid_fn=valid_fn, verbose=verbose)
    probe = scenario_fn(0)
    if len(set(probe.profiles)) != 1 or probe.m != router_cfg.n_instances:
        raise ValueError(
            "sequential trainer needs homogeneous scenarios of width "
            f"cfg.n_instances={router_cfg.n_instances}; got m={probe.m}")
    return rl_router.train(
        router_cfg, probe.profiles[0],
        lambda ep: scenario_fn(ep).requests, n_episodes, agent=agent,
        predict_decode=predict_decode,
        valid_fn=(lambda: valid_fn().requests) if valid_fn else None,
        verbose=verbose)
