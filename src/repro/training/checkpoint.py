"""Fault-tolerant checkpointing: msgpack + zstd pytrees, atomic rename,
per-leaf CRC32 integrity, async writer thread, latest-pointer restart.

Layout:
  <dir>/step_000042.ckpt      (zstd-compressed msgpack)
  <dir>/latest                (text file: "step_000042.ckpt")
"""
from __future__ import annotations

import os
import queue
import threading
import zlib
from typing import Any, Dict, Optional, Tuple

import msgpack
import numpy as np

try:
    import zstandard
except ImportError:          # optional dep: fall back to zlib compression
    zstandard = None

import jax


class _ZlibCompat:
    """Drop-in stand-in for the zstandard module when it is missing:
    checkpoints are zlib-compressed instead (larger/slower, same
    integrity guarantees).  Blobs are tagged so either build can read
    its own output."""

    class ZstdError(Exception):
        pass

    @staticmethod
    def compress(data: bytes) -> bytes:
        return b"ZLB0" + zlib.compress(data, level=6)

    @staticmethod
    def decompress(blob: bytes) -> bytes:
        if not blob.startswith(b"ZLB0"):
            raise _ZlibCompat.ZstdError(
                "zstd-compressed checkpoint but zstandard is not "
                "installed")
        return zlib.decompress(blob[4:])


def _compress(data: bytes) -> bytes:
    if zstandard is not None:
        return zstandard.ZstdCompressor(level=3).compress(data)
    return _ZlibCompat.compress(data)


def _decompress(blob: bytes) -> bytes:
    if zstandard is not None and not blob.startswith(b"ZLB0"):
        return zstandard.ZstdDecompressor().decompress(blob)
    return _ZlibCompat.decompress(blob)


def _decompress_error():
    return zstandard.ZstdError if zstandard is not None \
        else _ZlibCompat.ZstdError


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", "?"))) for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef(tree):
    return jax.tree_util.tree_structure(tree)


def serialize(tree, extra: Optional[Dict[str, Any]] = None) -> bytes:
    flat = _flatten(tree)
    payload = {"leaves": {}, "extra": extra or {}}
    for k, arr in flat.items():
        buf = arr.tobytes()
        payload["leaves"][k] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "crc": zlib.crc32(buf), "data": buf,
        }
    packed = msgpack.packb(payload, use_bin_type=True)
    return _compress(packed)


def deserialize(blob: bytes, like_tree) -> Tuple[Any, Dict[str, Any]]:
    packed = _decompress(blob)
    payload = msgpack.unpackb(packed, raw=False)
    leaves_by_key = {}
    for k, rec in payload["leaves"].items():
        buf = rec["data"]
        if zlib.crc32(buf) != rec["crc"]:
            raise IOError(f"checkpoint leaf {k!r} failed CRC check")
        leaves_by_key[k] = np.frombuffer(
            buf, dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
    flat_like = _flatten(like_tree)
    if set(flat_like) != set(leaves_by_key):
        missing = set(flat_like) ^ set(leaves_by_key)
        raise IOError(f"checkpoint tree mismatch: {sorted(missing)[:5]}")
    ordered = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(like_tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", "?"))) for k in path)
        arr = leaves_by_key[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise IOError(f"shape mismatch at {key}: {arr.shape} vs "
                          f"{leaf.shape}")
        ordered.append(arr)
    tree = jax.tree_util.tree_unflatten(_treedef(like_tree), ordered)
    return tree, payload["extra"]


class CheckpointManager:
    """Async, atomic checkpointing with restart support."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: queue.Queue = queue.Queue()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    # -- writing ----------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None,
             sync: bool = False):
        # device->host transfer happens on the caller thread (cheap, and
        # keeps the device free); compression+IO happen on the writer thread.
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree, extra))
        if sync:
            self.wait()

    def _worker(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, tree, extra = item
            try:
                self._write(step, tree, extra)
            except BaseException as e:       # surfaced on wait()
                self._err = e
            finally:
                self._q.task_done()

    def _write(self, step: int, tree, extra):
        name = f"step_{step:09d}.ckpt"
        blob = serialize(tree, {"step": step, **(extra or {})})
        tmp = os.path.join(self.dir, f".tmp.{name}")
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, os.path.join(self.dir, name))   # atomic
        ptr_tmp = os.path.join(self.dir, ".tmp.latest")
        with open(ptr_tmp, "w") as f:
            f.write(name)
        os.rename(ptr_tmp, os.path.join(self.dir, "latest"))
        self._gc()

    def _gc(self):
        ckpts = sorted(p for p in os.listdir(self.dir)
                       if p.startswith("step_"))
        for old in ckpts[:-self.keep]:
            os.unlink(os.path.join(self.dir, old))

    def wait(self):
        self._q.join()
        if self._err:
            err, self._err = self._err, None
            raise err

    def close(self):
        self.wait()
        self._q.put(None)
        self._thread.join(timeout=5)

    # -- restart ----------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "latest")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            name = f.read().strip()
        return int(name.split("_")[1].split(".")[0])

    def restore(self, like_tree) -> Optional[Tuple[Any, Dict]]:
        """Restore the newest intact checkpoint (falls back through older
        ones if the newest is corrupt -- crash-during-write tolerance)."""
        ckpts = sorted((p for p in os.listdir(self.dir)
                        if p.startswith("step_")), reverse=True)
        for name in ckpts:
            try:
                with open(os.path.join(self.dir, name), "rb") as f:
                    return deserialize(f.read(), like_tree)
            except (IOError, ValueError, msgpack.UnpackException,
                    zlib.error, _decompress_error()):
                continue
        return None


# -- full learner round-trips (online trainer warm-start / resume) --------

def save_learner(directory: str, step: int, agent, keep: int = 3):
    """Checkpoint the FULL learner state (Q + target + optimizer +
    replay-buffer contents + centering EMA + RNG) so an online trainer
    can resume mid-stream bit-exactly.  Synchronous: when this returns
    the checkpoint is durable."""
    tree, extra = agent.full_state()
    mgr = CheckpointManager(directory, keep=keep)
    try:
        mgr.save(step, tree, extra, sync=True)
    finally:
        mgr.close()


def restore_learner(directory: str, agent) -> Optional[int]:
    """Restore ``agent`` from ``directory``; returns the checkpoint step
    or None if nothing intact was found.

    Accepts two artifact flavors: a FULL learner checkpoint
    (``save_learner``) restores everything for exact mid-stream resume;
    a params-only ``state_dict`` artifact (the offline trainers'
    format) warm-starts just the networks + optimizer -- the replay
    buffer and RNG stay fresh."""
    if not os.path.isdir(directory):
        return None
    mgr = CheckpointManager(directory)
    try:
        full_like, _ = agent.full_state()
        out = mgr.restore(full_like)
        if out is not None:
            tree, extra = out
            agent.load_full_state(tree, extra)
            return int(extra.get("step", 0))
        out = mgr.restore(agent.state_dict())
        if out is not None:
            tree, extra = out
            agent.load_state_dict(tree)
            return int(extra.get("step", 0))
        return None
    finally:
        mgr.close()
