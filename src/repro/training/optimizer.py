"""Pure-JAX AdamW with gradient clipping and LR schedules (no optax)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: OptimizerConfig, grads, opt_state, params
           ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm > 0 else jnp.ones(())
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:   # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["mu"])
    flat_v = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
