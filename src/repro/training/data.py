"""Data pipeline: deterministic synthetic LM batches + background prefetch.

Stateless batch generation (batch = f(seed, step)) makes restarts exact: on
resume from step k the pipeline replays the same stream with no stored
iterator state.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np

from repro.common.config import ModelConfig


def synthetic_batch(cfg: ModelConfig, batch: int, seq: int, seed: int,
                    step: int) -> Dict[str, np.ndarray]:
    """Zipf-distributed token LM batch (labels = next token)."""
    rng = np.random.default_rng(np.uint64(seed) * np.uint64(1_000_003)
                                + np.uint64(step))
    out: Dict[str, np.ndarray] = {}
    if cfg.input_mode == "tokens":
        z = rng.zipf(1.3, size=(batch, seq + 1))
        toks = (z % cfg.vocab_size).astype(np.int32)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    else:
        emb = rng.standard_normal((batch, seq, cfg.d_model),
                                  dtype=np.float32)
        out["embeds"] = emb
        out["labels"] = (rng.integers(
            0, cfg.vocab_size, size=(batch, seq))).astype(np.int32)
    if cfg.vision_tokens:
        out["vision"] = rng.standard_normal(
            (batch, cfg.vision_tokens, cfg.vision_dim), dtype=np.float32)
    return out


class PrefetchLoader:
    """Background-thread prefetch of host batches (overlaps data generation
    with device compute; the same structure would wrap a real tokenized
    shard reader in production)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int,
                 start_step: int = 0, depth: int = 2):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = synthetic_batch(self.cfg, self.batch, self.seq, self.seed,
                                step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
