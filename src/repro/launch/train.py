"""Training launcher: auto-resuming train loop over any assigned arch.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b \
      --reduced --steps 100 --batch 8 --seq 64 --ckpt artifacts/train_ckpt

On the production mesh this module is launched per-host by the cluster
scheduler; the dry-run (repro.launch.dryrun) proves the full-scale
lowering.  On CPU use --reduced for a smoke-scale run.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.training import checkpoint as ckpt_lib
from repro.training import data as data_lib
from repro.training import optimizer as opt_lib
from repro.training.train_loop import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-2-7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"arch={cfg.name} params={cfg.count_params()/1e6:.2f}M")
    params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)
    start = 0
    mgr = ckpt_lib.CheckpointManager(args.ckpt) if args.ckpt else None
    if mgr is not None:
        restored = mgr.restore({"params": params, "opt": opt_state})
        if restored is not None:
            state, extra = restored
            params, opt_state = state["params"], state["opt"]
            start = extra["step"]
            print(f"resumed at step {start}")
    step_fn = jax.jit(make_train_step(cfg, opt_lib.OptimizerConfig(
        lr=args.lr, warmup_steps=20, total_steps=args.steps)))
    loader = data_lib.PrefetchLoader(cfg, args.batch, args.seq, seed=0,
                                     start_step=start)
    t0 = time.time()
    for i, (_, host_batch) in zip(range(start, args.steps), loader):
        batch = {k: jnp.asarray(v) for k, v in host_batch.items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        if (i + 1) % 20 == 0 or i + 1 == args.steps:
            print(f"step {i+1:5d} loss={float(m['loss']):.4f} "
                  f"({(i+1-start)/(time.time()-t0):.2f} it/s)", flush=True)
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt_state})
    loader.close()
    if mgr is not None:
        mgr.close()


if __name__ == "__main__":
    main()
