"""Step builders + input_specs for the dry-run / launchers.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input -- weak-type-correct, shardable, no device allocation.  The
shape kinds map to the lowered step:

  train    -> train_step(params, opt_state, batch)
  prefill  -> prefill_step(params, batch)       (builds the KV cache)
  decode   -> serve_step(params, cache, batch)  (one token vs a full cache)
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, ShapeConfig
from repro.models import model as model_lib
from repro.models import params as params_lib
from repro.training import optimizer as opt_lib
from repro.training.train_loop import make_train_step

I32 = jnp.int32


def _struct(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStructs for the step's data inputs."""
    b, s = shape.global_batch, shape.seq_len
    act_dtype = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        batch = {}
        if cfg.input_mode == "tokens":
            batch["tokens"] = _struct((b, s), I32)
        else:
            batch["embeds"] = _struct((b, s, cfg.d_model), act_dtype)
        batch["labels"] = _struct((b, s), I32)
        if cfg.vision_tokens:
            batch["vision"] = _struct((b, cfg.vision_tokens,
                                       cfg.vision_dim), act_dtype)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.input_mode == "tokens":
            batch["tokens"] = _struct((b, s), I32)
        else:
            batch["embeds"] = _struct((b, s, cfg.d_model), act_dtype)
        if cfg.vision_tokens:
            batch["vision"] = _struct((b, cfg.vision_tokens,
                                       cfg.vision_dim), act_dtype)
        return batch
    # decode: one new token against an S-long cache
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = _struct((b,), I32)
    else:
        batch["embeds"] = _struct((b, 1, cfg.d_model), act_dtype)
    return batch


def cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: model_lib.init_cache(cfg, shape.global_batch,
                                     shape.seq_len))


def param_specs(cfg: ModelConfig, data_shards: int):
    return params_lib.abstract_params(cfg, data_shards)


def opt_specs(cfg: ModelConfig, data_shards: int, optimizer: str = "adamw"):
    p = param_specs(cfg, data_shards)
    if optimizer == "adafactor":
        return jax.eval_shape(lambda: adafactor_init_abstract(p))
    return jax.eval_shape(
        lambda: {"mu": jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), p),
            "nu": jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), p),
            "step": jnp.zeros((), jnp.int32)})


# -- Adafactor (factored second moments; the memory-feasible optimizer for
#    the 314B-parameter cell on a 256-chip pod) ------------------------------

def adafactor_init(params):
    def one(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"v": jax.tree.map(one, params,
                              is_leaf=lambda x: hasattr(x, "ndim")),
            "step": jnp.zeros((), jnp.int32)}


def adafactor_init_abstract(params):
    return adafactor_init(params)


def adafactor_update(lr: float, grads, opt_state, params, eps: float = 1e-30,
                     clip: float = 1.0):
    step = opt_state["step"] + 1
    beta2 = 1.0 - jnp.power(step.astype(jnp.float32), -0.8)

    def one(g, st, p):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if p.ndim >= 2:
            vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
            vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)
                              [..., None], eps))
            upd = g / jnp.maximum(denom, 1e-12)
            new_st = {"vr": vr, "vc": vc}
        else:
            v = beta2 * st["v"] + (1 - beta2) * g2
            upd = g / jnp.sqrt(v + 1e-12)
            new_st = {"v": v}
        rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-12)
        upd = upd / jnp.maximum(1.0, rms / clip)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, new_st

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state["v"])
    out = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    return (treedef.unflatten([o[0] for o in out]),
            {"v": treedef.unflatten([o[1] for o in out]), "step": step})


# -- step functions -----------------------------------------------------------

def build_train_step(cfg: ModelConfig,
                     optimizer: str = "adamw") -> Callable:
    if optimizer == "adafactor":
        from repro.training.train_loop import symmetrize_ep_grads

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                model_lib.loss_fn, has_aux=True)(params, cfg, batch)
            grads = symmetrize_ep_grads(cfg, grads)
            params, opt_state = adafactor_update(1e-3, grads, opt_state,
                                                 params)
            return params, opt_state, {"loss": loss, **aux}
        return train_step
    return make_train_step(cfg, opt_lib.OptimizerConfig())


def build_prefill_step(cfg: ModelConfig, shape: ShapeConfig) -> Callable:
    def prefill_step(params, batch):
        logits, cache = model_lib.prefill(params, cfg,
                                          cache_len=shape.seq_len, **batch)
        return logits, cache
    return prefill_step


def build_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, cache = model_lib.decode_step(params, cfg, cache, **batch)
        return logits, cache
    return serve_step
