"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Target deployment mesh.

    single-pod: (16, 16) = 256 chips, axes ("data", "model")
    multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

    The "data" (x "pod") axis enumerates serving instances / DP+FSDP shards;
    "model" is the tensor-parallel axis inside an instance.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Generic helper for tests / small host-device meshes."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
