"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def _axis_kwargs(n_axes: int) -> dict:
    """axis_types only exists from jax 0.5; Auto is the default there, so
    on older jax we simply omit the argument."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Target deployment mesh.

    single-pod: (16, 16) = 256 chips, axes ("data", "model")
    multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model")

    The "data" (x "pod") axis enumerates serving instances / DP+FSDP shards;
    "model" is the tensor-parallel axis inside an instance.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Generic helper for tests / small host-device meshes."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_axis_kwargs(len(axes)))


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient: jax.set_mesh on jax>=0.5,
    the Mesh context manager (legacy ambient mesh) before that."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def mesh_axes(mesh) -> tuple:
    return tuple(mesh.axis_names)


def batch_axes(mesh) -> tuple:
    """Axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
