import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production meshes and record memory / cost / collective
analysis for the roofline report.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax

from repro.common.config import ModelConfig, ShapeConfig, shapes_for
from repro.configs import ASSIGNED, get_config
from repro.distributed import context as dist_ctx
from repro.distributed import sharding
from repro.launch import steps as steps_lib
from repro.launch import mesh as mesh_lib
from repro.launch.mesh import batch_axes, make_production_mesh

REPLICATED_OK = ("pos",)

# hardware constants (TPU v5e)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^=]*=\s*([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8, "pred": 1,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes(hlo_text: str):
    """Sum output-operand bytes of every collective op in the HLO."""
    totals = {}
    for m in _COLL_RE.finditer(hlo_text):
        op, dtype, dims = m.group(1), m.group(2), m.group(3)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES.get(dtype, 4)
        totals[op] = totals.get(op, 0) + b
    totals["total"] = sum(totals.values())
    return totals


def _optimizer_for(cfg: ModelConfig) -> str:
    # Adam moments in f32 do not fit the 314B cell on 256 chips; use the
    # factored optimizer there (standard production practice at this
    # scale-per-chip).
    return "adafactor" if cfg.count_params() > 1e11 else "adamw"


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool):
    data_shards = mesh.shape["data"]
    ctx = dist_ctx.ParallelContext(
        mesh=mesh, batch_axes=batch_axes(mesh), model_axis="model",
        ep_axes=("data",), seq_axis=None)
    mode = "train" if shape.kind == "train" else "serve"
    p_specs = steps_lib.param_specs(cfg, data_shards)
    p_shard = sharding.params_shardings(cfg, p_specs, mesh, mode)
    batch = steps_lib.input_specs(cfg, shape)
    b_shard = sharding.input_shardings(cfg, mesh, batch)
    with dist_ctx.use(ctx), mesh_lib.set_mesh(mesh):
        if shape.kind == "train":
            optname = _optimizer_for(cfg)
            step = steps_lib.build_train_step(cfg, optname)
            o_specs = steps_lib.opt_specs(cfg, data_shards, optname)
            o_shard = sharding.params_shardings(
                cfg, o_specs, mesh, mode)
            fn = jax.jit(step,
                         in_shardings=(p_shard, o_shard, b_shard),
                         donate_argnums=(0, 1))
            lowered = fn.lower(p_specs, o_specs, batch)
        elif shape.kind == "prefill":
            step = steps_lib.build_prefill_step(cfg, shape)
            fn = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = fn.lower(p_specs, batch)
        else:
            step = steps_lib.build_serve_step(cfg)
            c_specs = steps_lib.cache_specs(cfg, shape)
            seq_par = shape.global_batch < data_shards
            c_shard = sharding.cache_shardings(cfg, mesh, c_specs, seq_par)
            fn = jax.jit(step,
                         in_shardings=(p_shard, c_shard, b_shard),
                         donate_argnums=(1,))
            lowered = fn.lower(p_specs, c_specs, batch)
        compiled = lowered.compile()
    return lowered, compiled


def analyse(cfg: ModelConfig, shape: ShapeConfig, mesh, lowered, compiled,
            multi_pod: bool):
    n_chips = mesh.devices.size
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # cost_analysis() reports the PER-DEVICE SPMD program (verified against
    # an analytic sharded matmul), so roofline terms divide by per-chip
    # peak numbers, not by (chips x peak).
    flops = float(cost.get("flops", 0.0))
    bytes_hbm = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    n = cfg.count_params()
    n_active = cfg.count_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = {"train": 6, "prefill": 2, "decode": 2}[shape.kind]
    model_flops = mult * n_active * tokens
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_hbm / HBM_BW
    t_coll = coll["total"] / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    out = {
        "arch": cfg.name, "shape": shape.name,
        "mesh": "multi" if multi_pod else "single",
        "n_chips": int(n_chips),
        "params": int(n), "active_params": int(n_active),
        "argument_bytes_per_device": int(
            getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes_per_device": int(
            getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(
            getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes_per_device": int(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)),
        "hlo_flops_per_device": flops, "hlo_bytes_per_device": bytes_hbm,
        "collective_bytes": coll,
        "model_flops_global": model_flops,
        "useful_flop_ratio": (model_flops / n_chips) / flops
        if flops else 0.0,
        **terms,
        "dominant": dominant,
    }
    return out


def _shallow(cfg: ModelConfig, mult: int,
             shape: ShapeConfig) -> ModelConfig:
    n = cfg.period * mult + (1 if cfg.dense_first_layer else 0)
    changes = dict(n_layers=n, scan_unroll=True)
    if cfg.mamba is not None and shape.kind != "decode":
        # keep the unrolled chunk count bounded (compile time): the chunk
        # size doesn't change flops, only transient memory
        changes["mamba"] = dataclasses.replace(
            cfg.mamba, chunk=max(cfg.mamba.chunk, shape.seq_len // 8))
    return dataclasses.replace(cfg, **changes)


def _cost_of(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            collective_bytes(compiled.as_text()))


def extrapolate_costs(cfg: ModelConfig, shape: ShapeConfig, mesh,
                      multi_pod: bool):
    """XLA counts a while-loop body once regardless of trip count, so the
    scanned layer stack's flops/bytes/collectives are invisible to
    cost_analysis.  Compile the model at 1 and 2 periods with every scan
    unrolled, then extrapolate linearly to full depth."""
    f, b, c = [], [], []
    for mult in (1, 2):
        _, comp = lower_cell(_shallow(cfg, mult, shape), shape, mesh,
                             multi_pod)
        fi, bi, ci = _cost_of(comp)
        f.append(fi)
        b.append(bi)
        c.append(ci)
    n = cfg.n_periods
    flops = max(f[0] + (f[1] - f[0]) * (n - 1), f[1])
    bytes_ = max(b[0] + (b[1] - b[0]) * (n - 1), b[1])
    coll = {}
    for k in set(c[0]) | set(c[1]):
        v0, v1 = c[0].get(k, 0), c[1].get(k, 0)
        coll[k] = max(int(v0 + (v1 - v0) * (n - 1)), v1)
    return flops, bytes_, coll


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             verbose: bool = True, analysis: bool = True):
    cfg = get_config(arch)
    shape = {s.name: s for s in shapes_for(cfg)}.get(shape_name)
    if shape is None:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "skipped": "long_500k requires sub-quadratic attention"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered, compiled = lower_cell(cfg, shape, mesh, multi_pod)
    result = analyse(cfg, shape, mesh, lowered, compiled, multi_pod)
    if analysis:
        flops, bytes_, coll = extrapolate_costs(cfg, shape, mesh, multi_pod)
        n_chips = mesh.devices.size
        result.update({
            "hlo_flops_per_device": flops,
            "hlo_bytes_per_device": bytes_,
            "collective_bytes": coll,
            "compute_s": flops / PEAK_FLOPS,
            "memory_s": bytes_ / HBM_BW,
            "collective_s": coll["total"] / ICI_BW,
            "useful_flop_ratio": (result["model_flops_global"] / n_chips)
            / flops if flops else 0.0,
        })
        terms = {k: result[k] for k in ("compute_s", "memory_s",
                                        "collective_s")}
        result["dominant"] = max(terms, key=terms.get)
    result["compile_s"] = time.time() - t0
    if verbose:
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, list) else ca
        print({k: ca[k] for k in ("flops", "bytes accessed")
               if k in ca})
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape.name}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    if verbose:
        print(f"[{result['mesh']}] {arch} x {shape.name}: "
              f"peak/dev={result['peak_bytes_per_device']/2**30:.2f}GiB "
              f"compute={result['compute_s']*1e3:.2f}ms "
              f"memory={result['memory_s']*1e3:.2f}ms "
              f"coll={result['collective_s']*1e3:.2f}ms "
              f"dom={result['dominant']} "
              f"useful={result['useful_flop_ratio']:.2f} "
              f"({result['compile_s']:.0f}s compile)", flush=True)
    return result


ALL_SHAPE_NAMES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    archs = list(ASSIGNED) if args.all or not args.arch else [args.arch]
    shapes = ALL_SHAPE_NAMES if args.all or not args.shape \
        else [args.shape]
    failures = []
    for mp in meshes:
        sub = os.path.join(args.out, "multi" if mp else "single")
        for arch in archs:
            cfg = get_config(arch)
            valid = {s.name for s in shapes_for(cfg)}
            for sh in shapes:
                if sh not in valid:
                    if sh in ALL_SHAPE_NAMES:
                        print(f"[skip] {arch} x {sh}: requires "
                              "sub-quadratic attention", flush=True)
                    continue
                key = os.path.join(sub, f"{arch}__{sh}.json")
                if os.path.exists(key):
                    print(f"[cached] {arch} x {sh}", flush=True)
                    continue
                try:
                    # roofline analysis (extrapolation compiles) only on
                    # the single-pod mesh; multi-pod proves the "pod" axis
                    # shards and fits.
                    run_cell(arch, sh, mp, sub, analysis=not mp)
                except Exception as e:
                    traceback.print_exc()
                    failures.append((arch, sh, mp, repr(e)))
    if failures:
        print("FAILURES:")
        for f in failures:
            print("  ", f)
        sys.exit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
