"""Serving launcher: intelligent-router cluster over real (reduced) JAX
instances or the calibrated simulator.

  # online gateway: open-loop multi-tenant stream, pluggable policy,
  # learned length predictor in the loop, rolling SLO metrics
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --policy rl|mixing|jsq|rr --pattern bursty --queue-cap 64

  # same, with lifecycle tracing + metrics export (serving.obs):
  # trace.json opens in Perfetto, metrics.json/.prom is the scrape
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --trace trace.json --metrics-out metrics.json

  # gateway over real tiny engines on CPU
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --backend engine --policy mixing --requests 12

  # chaos drill: seeded crash/straggler schedule with gateway failover
  # (bounded-retry re-admission, circuit breaker, hedged re-dispatch)
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --chaos-seed 7 --chaos-crashes 1 --chaos-stragglers 1 \
      --failover --hedge-after 4.0

  # ONLINE continual learning: the gateway trains its own router on the
  # live stream (training.online) with the r_mixing safe-fallback
  # guardrail; --drift serves the nonstationary mix-flip scenario where
  # a frozen policy degrades.  --checkpoint warm-starts the learner.
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --online --guardrail 0.12 --drift --checkpoint ckpt_dir

  # calibrate a HardwareProfile from the real engine (core.calibrate):
  # sweep + fit, print diagnostics, write a committable JSON artifact.
  # --min-r2 makes a loose fit a non-zero exit (CI calibration-smoke).
  PYTHONPATH=src python -m repro.launch.serve --calibrate \
      --arch qwen3-0.6b --profile-json profile.json --min-r2 0.95

  # serve on a previously calibrated profile instead of the V100 default
  PYTHONPATH=src python -m repro.launch.serve --mode gateway \
      --profile-json profile.json

  # closed-loop simulator episode (legacy path)
  PYTHONPATH=src python -m repro.launch.serve --mode sim --requests 400

  # real tiny engines, impact-heuristic routing (legacy path)
  PYTHONPATH=src python -m repro.launch.serve --mode engine --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

# calibration times jitted kernels: single-threaded XLA keeps the sweep
# linear (multi-threaded CPU XLA switches parallelization strategy with
# size, which reads as piecewise cost steps).  Must be set before jax
# imports, so it is keyed off argv rather than the parsed args.
if "--calibrate" in sys.argv:
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_cpu_multi_thread_eigen=false "
        "intra_op_parallelism_threads=1")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import calibrate as cal
from repro.core import impact, rl_router as rl
from repro.core import workload as wl
from repro.core.cluster_manager import ManagedCluster, ManagedClusterConfig
from repro.core.predictor import quick_bucket_predictor
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.workload import generate, to_requests
from repro.models import params as params_lib
from repro.serving.engine import LLMInstance
from repro.serving.gateway import (EngineClusterAdapter, Gateway,
                                   GatewayConfig, MicroBatchPredictor)
from repro.serving.metrics import format_snapshot
from repro.serving.policies import (RLPolicy, make_gateway_policy,
                                    restore_rl_policy)
from repro.serving.request import Request, summarize
from repro.serving.scheduler import get_scheduler


def _router_cfg(args) -> rl.RouterConfig:
    # the online learner gets the health features: under chaos they
    # carry the straggler/degradation signal it adapts to
    return rl.RouterConfig(variant="guided", n_instances=args.instances,
                           q_arch="decomposed", seed=0,
                           explore_episodes=max(args.train_episodes - 3,
                                                1),
                           scheduler=args.scheduler,
                           chunked_prefill=args.chunked_prefill,
                           prefix_cache_tokens=args.prefix_cache,
                           prefix_block=args.prefix_block,
                           cache_weight=(0.5 if args.prefix_cache
                                         else 0.0),
                           include_cache_features=bool(
                               args.prefix_cache),
                           include_health_features=bool(
                               getattr(args, "online", False)))


def _train_quick_agent(args, cfg: rl.RouterConfig, profile=None):
    out = rl.train(cfg, profile or V100_LLAMA2_7B,
                   lambda ep: to_requests(generate(args.requests, seed=ep),
                                          rate=args.rate, seed=ep + 50),
                   n_episodes=args.train_episodes)
    return out["agent"]


def _base_profile(args):
    """The serving profile: a calibrated JSON artifact when given,
    else the paper's V100 calibration."""
    if args.profile_json and not args.calibrate:
        return cal.load_profile(args.profile_json)
    return V100_LLAMA2_7B


def run_calibrate(args) -> int:
    """--calibrate: sweep the reduced engine for --arch, fit a profile,
    print diagnostics, optionally write --profile-json.  Non-zero exit
    when the fit misses --min-r2 or the gradient sanity ordering (the
    CI calibration-smoke gate)."""
    cfg = get_config(args.arch).reduced()
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    res = cal.calibrate(cfg, params)
    print(cal.format_result(res))
    if args.profile_json:
        res.save(args.profile_json)
        print(f"wrote {args.profile_json}")
    failures = []
    if res.prefill_fit.r2 < args.min_r2:
        failures.append(f"prefill R^2 {res.prefill_fit.r2:.4f} "
                        f"< {args.min_r2}")
    if res.decode_fit.r2 < args.min_r2:
        failures.append(f"decode R^2 {res.decode_fit.r2:.4f} "
                        f"< {args.min_r2}")
    if not res.ok:
        failures.append("gradient sanity (grad1 > grad2 > 0) failed")
    for f in failures:
        print(f"CALIBRATION GATE: {f}")
    return 1 if failures else 0


def serve_sim(args):
    cfg = _router_cfg(args)
    base = _base_profile(args)
    agent = _train_quick_agent(args, cfg, base)
    mgr = ManagedCluster(ManagedClusterConfig(n_instances=args.instances),
                         cfg, base, agent)
    reqs = to_requests(generate(args.requests, seed=991), rate=args.rate,
                       seed=992)
    stats = mgr.serve(reqs)
    print(f"served n={stats['n']} e2e={stats['e2e_mean']:.2f}s "
          f"ttft={stats['ttft_mean']:.2f}s "
          f"preemptions={stats['preemptions']}")


def _tiny_engines(args, capacity: int = 400):
    cfg = get_config(args.arch).reduced()
    base = _base_profile(args)
    prof = dataclasses.replace(
        base, capacity_tokens=min(base.capacity_tokens, capacity))
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    return [LLMInstance(cfg, params, prof,
                        get_scheduler(args.scheduler), n_slots=4,
                        cache_len=128, instance_id=i,
                        prefix_cache_tokens=args.prefix_cache,
                        prefix_block=args.prefix_block)
            for i in range(args.instances)]


def _chaos_schedule(args):
    """--chaos-seed: build the seeded FaultSchedule for this run."""
    if args.chaos_seed is None:
        return None
    from repro.serving.chaos import FaultSchedule
    horizon = args.requests / max(args.rate, 1e-9)
    return FaultSchedule.random(
        seed=args.chaos_seed, m=args.instances, horizon=horizon,
        n_crashes=args.chaos_crashes,
        n_stragglers=args.chaos_stragglers,
        n_bursts=args.chaos_bursts)


def _resolve_backend(args) -> str:
    """Map the CLI flags onto one core.backends registry name.
    ``--backend`` names the backend directly ('sim' is the legacy
    alias for the default stepper); ``--sim-backend`` is the
    deprecated pre-registry spelling and wins when set."""
    import warnings
    backend = "py" if args.backend == "sim" else args.backend
    if args.sim_backend is not None:
        warnings.warn(
            "--sim-backend is deprecated; use --backend py|vec|jax "
            "(backends now resolve through the core.backends registry)",
            DeprecationWarning, stacklevel=2)
        if args.backend in ("sim", args.sim_backend):
            backend = args.sim_backend
    return backend


def serve_gateway(args):
    """Online gateway over the simulator (default) or real engines."""
    cfg = _router_cfg(args)
    chaos = _chaos_schedule(args)
    sim_backend = _resolve_backend(args)
    gcfg = GatewayConfig(queue_cap=args.queue_cap, on_full=args.on_full,
                         scheduler=args.scheduler,
                         chunked_prefill=args.chunked_prefill,
                         backend=sim_backend,
                         default_deadline_s=args.deadline,
                         prefix_cache_tokens=args.prefix_cache,
                         prefix_block=args.prefix_block,
                         attribution=bool(args.metrics_out),
                         chaos=chaos, failover=args.failover,
                         max_retries=args.max_retries,
                         hedge_after_s=args.hedge_after)
    recorder = None
    trainer = None
    if args.trace:
        from repro.serving import trace as trace_lib
        recorder = trace_lib.TraceRecorder(sample=args.trace_sample)
    if args.backend == "engine":
        if args.online:
            raise SystemExit("--online needs a simulator backend "
                             "(py/vec): the engine adapter fires no "
                             "decode events for the backlog reward)")
        # tiny real engines: short random prompts, oracle-free routing
        # via the mixing heuristic (no content for the predictor)
        engines = _tiny_engines(args)
        cluster = EngineClusterAdapter(engines)
        policy_name = args.policy
        if policy_name == "rl":
            if args.checkpoint:
                policy = restore_rl_policy(cfg, args.checkpoint,
                                           m=args.instances)
            else:
                print("WARNING: --backend engine --policy rl needs "
                      "--checkpoint (no simulator to train on); "
                      "falling back to the mixing policy")
                policy_name = "mixing"
                policy = make_gateway_policy(policy_name, cfg)
        else:
            policy = make_gateway_policy(policy_name, cfg)
        gw = Gateway(gcfg, None, policy, cluster=cluster,
                     trace=recorder)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt_tokens=int(rng.integers(10, 80)),
                        decode_tokens=int(rng.integers(5, 60)),
                        arrival=float(i) * 0.05, tenant="engine")
                for i in range(args.requests)]
        stats = gw.run(reqs)
    else:
        base = _base_profile(args)
        profiles = (base,) * args.instances
        sessions = (wl.SessionConfig(block=args.prefix_block)
                    if args.sessions else None)
        if args.drift:
            scn = wl.make_drift_scenario(seed=7,
                                         n_requests=args.requests,
                                         rate=args.rate,
                                         pattern=args.pattern,
                                         profiles=profiles)
            if chaos is None and scn.meta["chaos"] is not None:
                chaos = scn.meta["chaos"]
                gcfg = dataclasses.replace(gcfg, chaos=chaos,
                                           failover=True)
        else:
            scn = wl.make_tenant_scenario(seed=7,
                                          n_requests=args.requests,
                                          rate=args.rate,
                                          pattern=args.pattern,
                                          profiles=profiles,
                                          sessions=sessions)
        length = MicroBatchPredictor(quick_bucket_predictor(
            base, n_train=2000, epochs=2))
        if args.online:
            from repro.training.online import OnlineConfig, OnlineTrainer
            ocfg = OnlineConfig(eps=args.online_eps,
                                guard=args.guardrail > 0,
                                guard_regret=args.guardrail,
                                warm_start=args.checkpoint,
                                checkpoint_dir=args.save_learner,
                                checkpoint_every=(500 if args.save_learner
                                                  else 0))
            trainer = OnlineTrainer(cfg, ocfg, m=args.instances)
            policy = trainer.policy
        elif args.policy == "rl":
            if args.checkpoint:
                policy = restore_rl_policy(cfg, args.checkpoint,
                                           m=args.instances)
            else:
                policy = RLPolicy(
                    _train_quick_agent(args, cfg, base), cfg)
        else:
            policy = make_gateway_policy(args.policy, cfg)
        gw = Gateway(gcfg, profiles, policy, length=length,
                     trace=recorder)
        if chaos is not None and chaos.bursts:
            from repro.serving.chaos import inject_bursts
            reqs = inject_bursts(scn.requests, chaos,
                                 seed=args.chaos_seed)
            samples = list(scn.samples) + [None] * (
                len(reqs) - len(scn.requests))
            stats = gw.run(reqs, samples=samples)
        else:
            stats = gw.run(scn)
    print(f"policy={stats['policy']} served n={stats['n']} "
          f"admitted={stats['admitted']} shed={stats['shed']} "
          f"preemptions={stats['preemptions']}")
    if chaos is not None or args.failover:
        print(f"chaos: orphaned={stats['orphaned']} "
              f"retried={stats['retried']} hedged={stats['hedged']} "
              f"breaker_trips={stats.get('breaker_trips', 0)}")
    if trainer is not None:
        t = trainer.telemetry()
        print(f"online: decisions={int(t['decisions'])} "
              f"transitions={int(t['transitions'])} "
              f"learner_steps={trainer.agent.steps} "
              f"publishes={int(t['publishes'])} "
              f"explored={int(t['explored'])} "
              f"fallback_entries={int(t['fallback_entries'])} "
              f"mode={t['mode']}")
    print(format_snapshot(stats["snapshot"]))
    if args.trace or args.metrics_out:
        from repro.serving import obs
        if args.trace:
            doc = obs.write_trace(recorder, args.trace,
                                  title=f"gateway-{stats['policy']}")
            print(f"wrote {args.trace} "
                  f"({len(doc['traceEvents'])} trace events, "
                  f"{recorder.dropped} dropped)")
        if args.metrics_out:
            reg = obs.MetricsRegistry()
            reg.ingest_snapshot(stats["snapshot"])
            telemetry = getattr(getattr(gw.policy, "agent", None),
                                "telemetry", None)
            if telemetry is not None:
                reg.ingest_rl(telemetry())
            reg.save(args.metrics_out)
            print(f"wrote {args.metrics_out} ({len(reg)} metrics)")


def serve_engine(args):
    insts = _tiny_engines(args)
    prof = insts[0].profile
    rng = np.random.default_rng(0)
    reqs = [Request(prompt_tokens=int(rng.integers(10, 80)),
                    decode_tokens=int(rng.integers(5, 60)))
            for _ in range(args.requests)]
    for r in reqs:   # impact-heuristic routing (Eq. 1-2)
        scores = impact.mixing_per_instance(
            prof, r.prompt_tokens, r.decode_tokens,
            [i.resident_tokens() for i in insts])
        insts[int(np.argmax(scores))].submit(r)
        for inst in insts:
            inst.step()
    while sum(len(i.completed) for i in insts) < len(reqs):
        if not any(inst.queue or any(inst.slots) for inst in insts):
            break
        for inst in insts:
            inst.step()
    print(summarize(reqs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "engine", "gateway"),
                    default="sim")
    ap.add_argument("--backend",
                    choices=("sim", "py", "vec", "jax", "engine"),
                    default="sim",
                    help="gateway cluster backend: any name from the "
                    "core.backends registry ('py'/'vec'/'jax' pick the "
                    "simulator stepper, 'engine' runs tiny real "
                    "engines); 'sim' is the legacy alias for the "
                    "default simulator stepper")
    ap.add_argument("--policy", default="mixing",
                    choices=("rl", "mixing", "mixing+cache", "jsq",
                             "rr", "sticky"),
                    help="gateway routing policy")
    ap.add_argument("--pattern", default="bursty",
                    choices=("poisson", "bursty", "diurnal"))
    ap.add_argument("--queue-cap", type=int, default=0,
                    help="admission queue bound (0 = unbounded)")
    ap.add_argument("--sim-backend", choices=("py", "vec", "jax"),
                    default=None,
                    help="DEPRECATED alias: use --backend py|vec|jax "
                    "(backends now resolve through the core.backends "
                    "registry)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="client timeout in seconds (deferred requests "
                    "past it are cancelled)")
    ap.add_argument("--on-full", default="shed",
                    choices=("shed", "defer"))
    ap.add_argument("--prefix-cache", type=int, default=0,
                    help="per-instance prefix/KV cache budget in "
                    "tokens (0 = cache model off); enables the "
                    "cache-affinity policies and RL state feature")
    ap.add_argument("--prefix-block", type=int, default=32,
                    help="prefix-cache hash-block size in tokens")
    ap.add_argument("--sessions", action="store_true",
                    help="gateway: multi-turn conversation workload "
                    "(follow-up prompts extend prior turns; tenants "
                    "share system prompts) instead of independent "
                    "queries")
    ap.add_argument("--checkpoint", default=None,
                    help="router checkpoint dir for --policy rl (and "
                    "the warm-start source for --online)")
    ap.add_argument("--online", action="store_true",
                    help="gateway: continual learning on the live "
                    "stream (training.online) -- the router trains on "
                    "its own transitions between arrival windows and "
                    "hot-swaps refreshed weights without pausing "
                    "admission; implies the RL policy with health "
                    "features")
    ap.add_argument("--guardrail", type=float, default=0.12,
                    metavar="REGRET",
                    help="--online safe fallback: when the Q-head's "
                    "mean r_mixing regret over the guard window "
                    "exceeds this, route by r_mixing for a cooldown "
                    "while learning continues (0 = guardrail off)")
    ap.add_argument("--online-eps", type=float, default=0.05,
                    help="--online guided exploration rate (softmax "
                    "over the r_mixing guidance bonus)")
    ap.add_argument("--drift", action="store_true",
                    help="gateway: serve the nonstationary drift "
                    "scenario (mid-stream workload-mix flip + tenant "
                    "churn + straggler/crash chaos) instead of the "
                    "stationary tenant mix")
    ap.add_argument("--save-learner", default=None, metavar="DIR",
                    help="--online: periodically checkpoint the FULL "
                    "learner state (Q + target + optimizer + replay) "
                    "here for exact mid-stream resume")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="gateway: inject a seeded FaultSchedule "
                    "(serving.chaos) of crashes / stragglers / tenant "
                    "bursts into the run")
    ap.add_argument("--chaos-crashes", type=int, default=1,
                    help="crash+restart events in the schedule")
    ap.add_argument("--chaos-stragglers", type=int, default=1,
                    help="straggler slowdown windows in the schedule")
    ap.add_argument("--chaos-bursts", type=int, default=0,
                    help="correlated tenant-burst windows")
    ap.add_argument("--failover", action="store_true",
                    help="gateway failover: crash orphans re-enter "
                    "admission with bounded retries + backoff; health "
                    "tracker / circuit breaker filters candidates")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="failover retry budget per request")
    ap.add_argument("--hedge-after", type=float, default=None,
                    metavar="SECONDS",
                    help="hedged re-dispatch: withdraw a routed "
                    "request still tokenless after this long and "
                    "re-route it (None = off)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="gateway: record request lifecycle spans and "
                    "write a Chrome trace-event JSON (load in Perfetto "
                    "/ chrome://tracing)")
    ap.add_argument("--trace-sample", type=float, default=1.0,
                    help="head-sampling fraction of requests traced")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="gateway: write the metrics registry (SLO "
                    "snapshot + decision attribution + RL telemetry) "
                    "as JSON, or Prometheus text if PATH ends in "
                    ".prom")
    ap.add_argument("--calibrate", action="store_true",
                    help="sweep the reduced --arch engine and fit a "
                    "HardwareProfile (core.calibrate); prints fit "
                    "diagnostics and exits")
    ap.add_argument("--profile-json", default=None,
                    help="with --calibrate: write the calibrated "
                    "profile artifact here; otherwise: serve with the "
                    "profile loaded from this JSON instead of the "
                    "default V100 calibration")
    ap.add_argument("--min-r2", type=float, default=0.0,
                    help="with --calibrate: exit non-zero unless both "
                    "fits reach this R^2 (CI gate)")
    ap.add_argument("--arch", default="llama-2-7b")
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--scheduler", default="fcfs")
    ap.add_argument("--chunked-prefill", type=int, default=0)
    ap.add_argument("--train-episodes", type=int, default=8)
    args = ap.parse_args()
    if args.calibrate:
        sys.exit(run_calibrate(args))
    if args.mode == "sim":
        serve_sim(args)
    elif args.mode == "gateway":
        serve_gateway(args)
    else:
        serve_engine(args)


if __name__ == "__main__":
    main()
