"""Serving launcher: intelligent-router cluster over real (reduced) JAX
instances or the calibrated simulator.

  # simulator cluster (paper experiments scale)
  PYTHONPATH=src python -m repro.launch.serve --mode sim --requests 400

  # real tiny engines on CPU
  PYTHONPATH=src python -m repro.launch.serve --mode engine --requests 12
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import impact, rl_router as rl
from repro.core.cluster_manager import ManagedCluster, ManagedClusterConfig
from repro.core.profiles import V100_LLAMA2_7B
from repro.core.workload import generate, to_requests
from repro.models import params as params_lib
from repro.serving.engine import LLMInstance
from repro.serving.request import Request, summarize
from repro.serving.scheduler import get_scheduler


def serve_sim(args):
    cfg = rl.RouterConfig(variant="guided", n_instances=args.instances,
                          q_arch="decomposed", seed=0,
                          explore_episodes=max(args.train_episodes - 3, 1),
                          scheduler=args.scheduler,
                          chunked_prefill=args.chunked_prefill)
    out = rl.train(cfg, V100_LLAMA2_7B,
                   lambda ep: to_requests(generate(args.requests, seed=ep),
                                          rate=args.rate, seed=ep + 50),
                   n_episodes=args.train_episodes)
    mgr = ManagedCluster(ManagedClusterConfig(n_instances=args.instances),
                         cfg, V100_LLAMA2_7B, out["agent"])
    reqs = to_requests(generate(args.requests, seed=991), rate=args.rate,
                       seed=992)
    stats = mgr.serve(reqs)
    print(f"served n={stats['n']} e2e={stats['e2e_mean']:.2f}s "
          f"ttft={stats['ttft_mean']:.2f}s "
          f"preemptions={stats['preemptions']}")


def serve_engine(args):
    cfg = get_config(args.arch).reduced()
    prof = dataclasses.replace(V100_LLAMA2_7B, capacity_tokens=400)
    params = params_lib.init_params(jax.random.PRNGKey(0), cfg)
    insts = [LLMInstance(cfg, params, prof,
                         get_scheduler(args.scheduler), n_slots=4,
                         cache_len=128, instance_id=i)
             for i in range(args.instances)]
    rng = np.random.default_rng(0)
    reqs = [Request(prompt_tokens=int(rng.integers(10, 80)),
                    decode_tokens=int(rng.integers(5, 60)))
            for _ in range(args.requests)]
    for r in reqs:   # impact-heuristic routing (Eq. 1-2)
        scores = impact.mixing_per_instance(
            prof, r.prompt_tokens, r.decode_tokens,
            [i.resident_tokens() for i in insts])
        insts[int(np.argmax(scores))].submit(r)
        for inst in insts:
            inst.step()
    while sum(len(i.completed) for i in insts) < len(reqs):
        if not any(inst.queue or any(inst.slots) for inst in insts):
            break
        for inst in insts:
            inst.step()
    print(summarize(reqs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=("sim", "engine"), default="sim")
    ap.add_argument("--arch", default="llama-2-7b")
    ap.add_argument("--instances", type=int, default=4)
    ap.add_argument("--requests", type=int, default=400)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--scheduler", default="fcfs")
    ap.add_argument("--chunked-prefill", type=int, default=0)
    ap.add_argument("--train-episodes", type=int, default=8)
    args = ap.parse_args()
    if args.mode == "sim":
        serve_sim(args)
    else:
        serve_engine(args)


if __name__ == "__main__":
    main()
