"""Compressed gradient all-reduce (int8 quantized) for data parallelism.

Under pjit, XLA inserts the DP gradient all-reduce automatically in f32/bf16.
For bandwidth-bound interconnects this module provides an explicit
shard_map'd DP step whose gradient reduction is int8-quantized:

    g_int8 = round(g / scale),  scale = max|g| / 127   (per-tensor)
    psum(g_int8 as int32) * scale_combined / n_shards

This is a 4x reduction in collective bytes vs f32 (2x vs bf16) at <1e-2
relative error -- recorded as a §Perf lever for collective-bound cells.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.common import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_psum(tree, axes):
    """int8-quantized psum over mesh axes (call inside shard_map)."""
    def one(g):
        gf = g.astype(jnp.float32)
        amax = jnp.max(jnp.abs(gf))
        # share a single scale: take the max across shards first (cheap:
        # one scalar all-reduce) so quantization grids line up.
        amax = jax.lax.pmax(amax, axes)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        total = jax.lax.psum(q.astype(jnp.int32), axes)
        n = jax.lax.psum(jnp.ones((), jnp.int32), axes)
        return (total.astype(jnp.float32) * scale
                / n.astype(jnp.float32)).astype(g.dtype)
    return jax.tree.map(one, tree)


def make_compressed_dp_grad_fn(loss_fn: Callable, mesh, batch_axes,
                               batch_spec_tree) -> Callable:
    """grad_fn(params, batch) -> grads, with per-shard grads reduced via the
    int8 collective.  Params replicated; batch sharded over batch_axes."""
    axes = tuple(batch_axes)

    def local_grads(params, batch):
        g = jax.grad(lambda p: loss_fn(p, batch))(params)
        return quantize_psum(g, axes)

    def grad_fn(params, batch):
        in_specs = (jax.tree.map(lambda _: P(), params),
                    batch_spec_tree)
        out_specs = jax.tree.map(lambda _: P(), params)
        return compat.shard_map(local_grads, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
                                 params, batch)

    return grad_fn
