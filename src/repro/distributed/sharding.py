"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Every parameter leaf is matched by its tree path to a rule that assigns mesh
axes to tensor dims, with divisibility fallbacks (e.g. starcoder2's 36 heads
do not divide a 16-way "model" axis, so TP falls back to the 128-wide
head_dim).  Rules differ between train (FSDP over "data"/"pod") and serve
(weights replicated across instances -- the paper's homogeneous-instance
setting -- except EP expert shards).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import ModelConfig


def _fits(dim: int, mesh, axes) -> bool:
    if dim is None or not axes:
        return False
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def _axis(mesh, dim_size: int, axes) -> Any:
    """Return axes (tuple or single name) if divisible, else None."""
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in mesh.axis_names)
    if not axes:
        return None
    return axes if _fits(dim_size, mesh, axes) else None


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(f"[{k.idx}]")
    return tuple(names)


def param_spec(names: Tuple[str, ...], shape: Tuple[int, ...],
               cfg: ModelConfig, mesh, mode: str) -> P:
    """PartitionSpec for one parameter leaf.

    mode: "train" (FSDP over data axes) | "serve" (replicated weights)."""
    fsdp = tuple(a for a in ("pod", "data") if a in mesh.axis_names) \
        if mode == "train" else ()
    tp = "model"
    name = names[-1]
    stacked = "layers" in names            # leading n_periods dim
    off = 1 if stacked else 0

    def spec(*dims):
        full = (None,) * off + dims
        full = full[:len(shape)] + (None,) * (len(shape) - len(full))
        return P(*full)

    dims = shape[off:]

    def fsdp_ax(i):
        return _axis(mesh, dims[i], fsdp) if fsdp else None

    def tp_ax(i):
        return _axis(mesh, dims[i], tp)

    in_moe = "routed" in names
    if in_moe:
        # EP storage: experts over "data"; f over "model"; d gets no FSDP
        ep = _axis(mesh, dims[0], "data") if cfg.moe and \
            cfg.moe.impl == "ep" else fsdp_ax(0)
        if name in ("w_up", "w_gate"):      # [E, d, f]
            return spec(ep, None, tp_ax(2))
        if name == "w_down":                # [E, f, d]
            return spec(ep, tp_ax(1), None)
    if name == "router":
        return spec(None, None)
    if name == "embed":                     # [V, d]
        # d stays UNSHARDED: the lookup output is [B(data), S, d] -- an
        # fsdp('data') sharding on d conflicts with the batch axis and
        # makes SPMD replicate the gather (tens of GiB at 150k vocab).
        return spec(tp_ax(0), None)
    if name == "lm_head":                   # [d, V]
        return spec(fsdp_ax(0), tp_ax(1))
    if name == "vision_proj":
        return spec(None, fsdp_ax(1))
    if name in ("wq", "wk", "wv"):          # [d, H, hd]
        # heads over "model" where divisible; NEVER shard head_dim (RoPE
        # splits hd in half, which conflicts with an hd sharding and
        # triggers involuntary full rematerialization in SPMD).
        return spec(fsdp_ax(0), tp_ax(1), None)
    if name == "wo":                        # [H, hd, d]
        return spec(tp_ax(0), None, fsdp_ax(2))
    if name == "wq_b":                      # [r, H, qk]
        return spec(None, tp_ax(1), None)
    if name == "wkv_b":                     # [r, H, nope+v]
        return spec(None, tp_ax(1), None)
    if name in ("wq_a", "wkv_a"):           # [d, r]
        return spec(fsdp_ax(0), None)
    if name in ("w_up", "w_gate"):          # [d, f]
        return spec(fsdp_ax(0), tp_ax(1))
    if name == "w_down":                    # [f, d]
        return spec(tp_ax(0), fsdp_ax(1))
    if name == "in_proj":                   # [d, 2*di]
        return spec(fsdp_ax(0), tp_ax(1))
    if name in ("conv_w",):                 # [dc, di]
        return spec(None, tp_ax(1))
    if name in ("conv_b", "dt_bias", "D"):  # [di]
        return spec(tp_ax(0))
    if name == "x_proj":                    # [di, dtr+2ds]
        return spec(tp_ax(0), None)
    if name == "dt_proj":                   # [dtr, di]
        return spec(None, tp_ax(1))
    if name == "A_log":                     # [di, ds]
        return spec(tp_ax(0), None)
    if name == "out_proj":                  # [di, d]
        return spec(tp_ax(0), fsdp_ax(1))
    # norms, gates, scalars
    return P(*((None,) * len(shape)))


def params_shardings(cfg: ModelConfig, params_tree, mesh, mode: str):
    """NamedSharding tree mirroring a params (or opt-state slot) tree."""
    def one(path, leaf):
        spec = param_spec(_path_names(path), leaf.shape, cfg, mesh, mode)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params_tree)


# ---------------------------------------------------------------------------
# activations / inputs / caches
# ---------------------------------------------------------------------------

def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_spec(mesh, batch_size: int, extra_dims: int = 1) -> P:
    ax = _axis(mesh, batch_size, _batch_axes(mesh))
    return P(ax, *((None,) * extra_dims))


def input_shardings(cfg: ModelConfig, mesh, tree):
    """Shardings for a batch dict of ShapeDtypeStructs: dim0 = batch."""
    def one(leaf):
        ax = _axis(mesh, leaf.shape[0], _batch_axes(mesh))
        return NamedSharding(mesh, P(ax, *((None,) * (leaf.ndim - 1))))
    return jax.tree.map(one, tree)


def cache_shardings(cfg: ModelConfig, mesh, cache_tree, seq_parallel: bool):
    """Decode-cache shardings.

    Normal decode: batch over data axes, kv-heads (or latent/channel dims)
    over "model" where divisible.  long-context (seq_parallel): the KV
    sequence axis shards over the data axes instead (context parallelism);
    SSM channel state shards over "model".
    """
    batch_ax = _batch_axes(mesh)

    def one(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = leaf.shape
        if name == "pos":
            return NamedSharding(mesh, P(None))
        stacked = "layers" in names
        off = 1 if stacked else 0
        dims = shape[off:]
        lead = (None,) * off
        b_ax = _axis(mesh, dims[0], batch_ax)
        if name in ("k", "v"):                   # [B,S,KV,hd]
            if seq_parallel and b_ax is None:
                s_ax = _axis(mesh, dims[1], batch_ax)
                return NamedSharding(
                    mesh, P(*lead, None, s_ax, None,
                            _axis(mesh, dims[3], "model")))
            kv_ax = _axis(mesh, dims[2], "model")
            hd_ax = None if kv_ax is not None else _axis(
                mesh, dims[3], "model")
            return NamedSharding(mesh, P(*lead, b_ax, None, kv_ax, hd_ax))
        if name == "ckv":                        # [B,S,r]
            if seq_parallel and b_ax is None:
                return NamedSharding(
                    mesh, P(*lead, None, _axis(mesh, dims[1], batch_ax),
                            None))
            return NamedSharding(mesh, P(*lead, b_ax, None,
                                         _axis(mesh, dims[2], "model")))
        if name == "kr":                         # [B,S,rope] rope is tiny
            if seq_parallel and b_ax is None:
                return NamedSharding(
                    mesh, P(*lead, None, _axis(mesh, dims[1], batch_ax),
                            None))
            return NamedSharding(mesh, P(*lead, b_ax, None, None))
        if name == "ssm":                        # [B,di,ds]
            return NamedSharding(
                mesh, P(*lead, b_ax, _axis(mesh, dims[1], "model"), None))
        if name == "conv":                       # [B,dc-1,di]
            return NamedSharding(
                mesh, P(*lead, b_ax, None, _axis(mesh, dims[2], "model")))
        return NamedSharding(mesh, P(*lead, b_ax,
                                     *((None,) * (len(dims) - 1))))

    return jax.tree_util.tree_map_with_path(one, cache_tree)
