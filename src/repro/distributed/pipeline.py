"""GPipe-style pipeline parallelism with collective_permute (TPU-idiomatic).

Each device along the "pipe" mesh axis owns one stage's parameters; the
schedule runs n_micro + n_stages - 1 ticks; activations hop stage->stage
with ppermute so compute and the (tiny) boundary transfer overlap under
XLA's latency-hiding scheduler.  This is the optional PP mode for depth
scaling beyond what DP x TP covers; the production dry-run meshes use
DP x TP (+EP), so PP is exercised by its own test/bench on a host mesh.
"""
from __future__ import annotations

from typing import Callable

import jax

from repro.common import compat
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, mesh, axis: str, stage_params,
                   x_micro: jax.Array) -> jax.Array:
    """Run x_micro [n_micro, mb, ...] through n_stages pipeline stages.

    stage_fn(params_slice, x [mb, ...]) -> [mb, ...]
    stage_params: pytree with leading dim n_stages (sharded over `axis`).
    Returns [n_micro, mb, ...] outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    total = n_micro + n_stages - 1
    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, x_local):
        # params_local: leading dim 1 (this stage); x_local [n_micro, mb,...]
        params_here = jax.tree.map(lambda w: w[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        mb_shape = x_local.shape[1:]
        buf = jnp.zeros(mb_shape, x_local.dtype)       # activation in flight
        outs = jnp.zeros_like(x_local)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any left)
            idx = jnp.minimum(t, n_micro - 1)
            fresh = x_local[idx]
            buf = jnp.where(stage_id == 0,
                            jnp.where(t < n_micro, fresh, buf), buf)
            y = stage_fn(params_here, buf)
            # last stage emits microbatch t - (n_stages - 1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            emit = jnp.logical_and(stage_id == n_stages - 1,
                                   t >= n_stages - 1)
            outs = jnp.where(emit,
                             outs.at[out_idx].set(y), outs)
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total, tick, (buf, outs))
        # only the last stage holds real outputs; broadcast them
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    p_specs = jax.tree.map(lambda _: P(axis), stage_params)
    return compat.shard_map(
        local, mesh=mesh,
        in_specs=(p_specs, P()), out_specs=P(),
        check_vma=False)(stage_params, x_micro)
