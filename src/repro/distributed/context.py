"""Ambient parallelism context.

Launchers (dryrun/train/serve) install the active mesh + axis-role mapping
here so model code (e.g. the expert-parallel MoE shard_map) can find it
without threading mesh objects through every scan body.  When no context is
set, model code falls back to single-device implementations -- which is what
CPU smoke tests want.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass
class ParallelContext:
    mesh: object = None                      # jax Mesh or None
    batch_axes: Tuple[str, ...] = ()     # axes the global batch shards over
    model_axis: Optional[str] = None         # TP axis name
    ep_axes: Tuple[str, ...] = ()            # expert-parallel axes
    seq_axis: Optional[str] = None           # SP axis (long-context)


_CURRENT = ParallelContext()


def get() -> ParallelContext:
    return _CURRENT


def set_context(ctx: ParallelContext) -> None:
    global _CURRENT
    _CURRENT = ctx


@contextlib.contextmanager
def use(ctx: ParallelContext):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = ctx
    try:
        yield ctx
    finally:
        _CURRENT = prev


def tp_size() -> int:
    ctx = get()
    if ctx.mesh is None or ctx.model_axis is None:
        return 1
    return ctx.mesh.shape[ctx.model_axis]


def constrain_heads(x, head_dim: int = 2, batch_dim: int = 0):
    """Shard dim ``head_dim`` over the model axis (+ batch over batch
    axes) when divisible; no-op otherwise."""
    import math
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    ctx = get()
    if ctx.mesh is None or ctx.model_axis is None:
        return x
    tp = ctx.mesh.shape[ctx.model_axis]
    if x.shape[head_dim] % tp != 0:
        return x
    dims = [None] * x.ndim
    dims[head_dim] = ctx.model_axis
    if ctx.batch_axes and x.shape[batch_dim] % math.prod(
            ctx.mesh.shape[a] for a in ctx.batch_axes) == 0:
        dims[batch_dim] = ctx.batch_axes
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, PartitionSpec(*dims)))


def constrain_batch(x, batch_dim: int = 0):
    """with_sharding_constraint: shard dim ``batch_dim`` over the batch
    axes, everything else replicated.  No-op without an ambient mesh.
    Used at layer boundaries -- SPMD propagation through rematted
    scan-in-scan bodies otherwise drops the batch sharding and silently
    replicates activations."""
    import jax
    from jax.sharding import NamedSharding, PartitionSpec
    ctx = get()
    if ctx.mesh is None or not ctx.batch_axes:
        return x
    if x.shape[batch_dim] % max(
            1, __import__("math").prod(
                ctx.mesh.shape[a] for a in ctx.batch_axes)) != 0:
        return x
    dims = [None] * x.ndim
    dims[batch_dim] = ctx.batch_axes
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, PartitionSpec(*dims)))


def data_shards() -> int:
    """Size of the expert-parallel axis product (0 if no context)."""
    ctx = get()
    if ctx.mesh is None:
        return 0
    n = 1
    for a in ctx.ep_axes:
        n *= ctx.mesh.shape[a]
    return n
